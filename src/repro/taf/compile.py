"""Whole-plan compilation: one jitted device dispatch per temporal query.

The staged ``PlanExecutor`` crosses the host/device boundary per stage; a
T-point temporal query pays a Python loop (or at best one numpy pass)
per operator.  This module lowers the terminal stage of a validated Plan
— ``Slice`` / ``Compute`` / ``Evolution`` — into ONE jitted JAX program
over the batched-replay arrays:

* ``Slice([t1..tT])``                — the device ``state_at_many``: per-
  node presence/attrs at every timepoint from ``SoN.padded_events()``
  (searchsorted + cumulative last-write index per row), bit-identical to
  the host replay engine;
* ``Compute(style="temporal", fn=<FusedOp>)`` — the temporal-analytics
  kernel family (``pagerank``/``components``/``triangles``) over
  ``EdgeReplay``'s pair table, exported once per operand via
  ``EdgeReplay.device_export()`` and kept device-resident;
* ``Evolution(fn=<FusedScalarOp>)``  — the same per-node programs with a
  per-timepoint reduction folded into the jit.

Programs are cached keyed on plan *shape* — stage kind, op identity and
static params, operand array shapes/dtypes, and T — so repeated queries
re-trace zero times (``STATS["traces"]`` counts actual traces; tests and
the ``fusion`` bench assert cache hits).  Uncovered plan shapes fall
back transparently to the staged executor; ``PlanResult.notes`` records
which path ran and why.

Every ``FusedOp`` carries a numpy ``host`` implementation with identical
semantics — it IS the staged path for the same plan (the op is a
vectorized temporal compute fn), which is what the randomized parity
tests compare against: bit-identical for the integer-valued ops
(components, triangles, slice), float32-vs-float64 tolerance for
PageRank (documented in docs/api.md).

Aggregate runs as a host epilogue over the device series (the staged
``_aggregate`` code verbatim), keeping aggregated results bit-identical
between paths; the T-point temporal body is the single device dispatch.

jax imports are deferred into the lowering path so plans that fall back
never pay them.
"""
from __future__ import annotations

import contextlib
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.events import NATTR_SET, NODE_ADD, NODE_DEL
from repro.taf import operators as ops
from repro.taf import replay
from repro.taf.son import SoN, SoTS

# sentinel distinguishing "not covered -> run staged" from a fused value
MISS = object()

# fuse a terminal Slice only past this many timepoints: below it the host
# numpy replay wins and the executor's replay LRU already dedups repeats
MIN_FUSE_T = 16

# dense-adjacency budget (elements) for the triangle program: T*N^2 above
# this falls back to the staged path rather than materializing the stack
DENSE_BUDGET = 64_000_000

ENABLED = True

STATS: Dict[str, int] = {
    "traces": 0,           # actual jit traces (cache misses that compiled)
    "compile_hits": 0,     # program served from the compile cache
    "compile_misses": 0,
    "fused_runs": 0,
    "fallback_runs": 0,
    "operand_uploads": 0,  # device-resident operand exports built
}

_PROGRAM_CACHE_MAX = 64
_programs: "OrderedDict[Tuple, Any]" = OrderedDict()

# device-resident operand arrays, keyed (operand_key(son), flavor) and
# weakref-guarded against id() recycling like the executor's ReplayCache
_operands = replay.ReplayCache(maxsize=16)


def clear_cache() -> None:
    _programs.clear()
    _operands.clear()


def cache_stats() -> Dict[str, int]:
    return dict(STATS, programs=len(_programs), operands=len(_operands))


@contextlib.contextmanager
def disabled():
    """Force the staged executor path (tests / staged-vs-fused benches)."""
    global ENABLED
    prev, ENABLED = ENABLED, False
    try:
        yield
    finally:
        ENABLED = prev


# ---------------------------------------------------------------------------
# Fused ops: host semantics + device lowering under one object
# ---------------------------------------------------------------------------


def _host_edges(sots: SoTS, ts, present) -> Tuple[np.ndarray, np.ndarray,
                                                  np.ndarray]:
    """Canonical undirected edge list + per-timepoint liveness (host).

    Edges join member *rows* (non-member neighbors drop out, self-loops
    drop out); the two directed pair rows of one undirected edge are
    OR-folded.  An edge is live at t iff its pair exists and BOTH
    endpoints are present.  The device programs implement the identical
    semantics from ``EdgeReplay.device_export()``.
    """
    N, T = present.shape
    er = replay.edge_replay(sots)
    exist = er.exist_matrix(ts)  # (P, T)
    v = replay.member_rows(er.pair_other, sots.node_ids)
    u = er.pair_center.astype(np.int64)
    valid = (v >= 0) & (u != v)
    cu = np.minimum(u[valid], v[valid].astype(np.int64))
    cv = np.maximum(u[valid], v[valid].astype(np.int64))
    key = cu * max(N, 1) + cv
    uniq, inv = (np.unique(key, return_inverse=True) if len(key)
                 else (np.empty(0, np.int64), np.empty(0, np.int64)))
    live = np.zeros((len(uniq), T), bool)
    if len(uniq):
        np.logical_or.at(live, inv, exist[valid] == 1)
    eu = (uniq // max(N, 1)).astype(np.int64)
    ev = (uniq % max(N, 1)).astype(np.int64)
    live &= (present[eu] == 1) & (present[ev] == 1)
    return eu, ev, live


class FusedOp:
    """A temporal-analytics op the plan compiler can lower.

    Doubles as a vectorized temporal compute fn: the staged executor
    calls ``__call__(present, attrs, son, t)`` (numpy, the reference
    semantics); the compiler recognizes the instance and runs
    ``device()`` inside one jitted program instead.
    """

    vectorized = True
    name = "fused"

    def params(self) -> Tuple:
        return ()

    def __call__(self, present, attrs, son, t, **kw):
        ts = np.atleast_1d(np.asarray(t, np.int64))
        present = np.asarray(present).reshape(len(son), len(ts))
        return self.host(son, ts, present)

    def host(self, sots: SoTS, ts, present) -> np.ndarray:
        raise NotImplementedError

    def device(self, jnp_mod, arrs, act, live):
        """(N, T) series from device arrays: ``act (T, N)`` f32 presence,
        ``live (T, E)`` f32 edge liveness, ``arrs`` the operand export."""
        raise NotImplementedError


class PageRankOp(FusedOp):
    """Temporal PageRank: damped power iteration (fixed ``iters``,
    uniform dangling-mass redistribution, inactive nodes pinned to 0)
    per timepoint.  Host math runs in float64, the device program in
    float32 — parity within documented tolerance."""

    name = "pagerank"

    def __init__(self, damping: float = 0.85, iters: int = 20):
        self.damping = float(damping)
        self.iters = int(iters)

    def params(self):
        return (self.damping, self.iters)

    def host(self, sots, ts, present):
        u, v, live = _host_edges(sots, ts, present)
        N, T = present.shape
        out = np.zeros((N, T))
        for j in range(T):
            m = live[:, j]
            uj, vj = u[m], v[m]
            act = (present[:, j] == 1).astype(np.float64)
            n = max(act.sum(), 1.0)
            deg = np.zeros(N)
            np.add.at(deg, uj, 1.0)
            np.add.at(deg, vj, 1.0)
            r = act / n
            dmask = act * (deg == 0)
            for _ in range(self.iters):
                contrib = np.where(deg > 0, r / np.maximum(deg, 1.0), 0.0)
                nxt = np.zeros(N)
                np.add.at(nxt, vj, contrib[uj])
                np.add.at(nxt, uj, contrib[vj])
                dangling = float((r * dmask).sum())
                r = act * ((1.0 - self.damping) / n
                           + self.damping * (nxt + dangling / n))
            out[:, j] = r
        return out

    def device(self, jnp, arrs, act, live):
        frow, fcol, feid = arrs["frow"], arrs["fcol"], arrs["feid"]
        live2 = live[feid]  # (2E, T) contiguous rows
        deg = jnp.zeros(act.shape, jnp.float32).at[frow].add(
            live2, indices_are_sorted=True, mode="drop")
        n = jnp.maximum(jnp.sum(act, axis=0, keepdims=True), 1.0)
        r = act / n
        dmask = act * (deg == 0).astype(jnp.float32)
        for _ in range(self.iters):
            contrib = jnp.where(deg > 0, r / jnp.maximum(deg, 1.0), 0.0)
            nxt = jnp.zeros(act.shape, jnp.float32).at[frow].add(
                contrib[fcol] * live2, indices_are_sorted=True, mode="drop")
            dangling = jnp.sum(r * dmask, axis=0, keepdims=True)
            r = act * ((1.0 - self.damping) / n
                       + self.damping * (nxt + dangling / n))
        return r  # (N, T) f32


class ComponentsOp(FusedOp):
    """Temporal connected components: bounded min-label propagation
    (``iters`` rounds; exact for components of diameter <= iters).
    Labels are min member-row indices, -1 on absent nodes — integer, so
    host and device are bit-identical."""

    name = "components"

    def __init__(self, iters: int = 32):
        self.iters = int(iters)

    def params(self):
        return (self.iters,)

    def host(self, sots, ts, present):
        u, v, live = _host_edges(sots, ts, present)
        N, T = present.shape
        act = present == 1
        labels = np.where(act, np.arange(N, dtype=np.int64)[:, None], N)
        for _ in range(self.iters):
            lu = np.where(live, labels[u], N)
            lv = np.where(live, labels[v], N)
            new = labels.copy()
            if len(u):
                np.minimum.at(new, u, lv)
                np.minimum.at(new, v, lu)
            labels = new
        return np.where(act, labels, -1).astype(np.float64)

    def device(self, jnp, arrs, act, live):
        import jax

        frow, fcol, feid = arrs["frow"], arrs["fcol"], arrs["feid"]
        N, T = act.shape
        on = act > 0
        iota = jax.lax.broadcasted_iota(jnp.int32, (N, T), 0)
        labels = jnp.where(on, iota, N)
        alive = live[feid] > 0  # (2E, T)
        for _ in range(self.iters):
            msgs = jnp.where(alive, labels[fcol], N)
            labels = labels.at[frow].min(
                msgs, indices_are_sorted=True, mode="drop")
        return jnp.where(on, labels, -1)  # (N, T) int32


class TrianglesOp(FusedOp):
    """Temporal triangle participation per node (diag(A^3)/2), over the
    packed pair table's live edges.  Integer counts — host and device
    are bit-identical (f32 accumulation is exact below 2^24)."""

    name = "triangles"

    def host(self, sots, ts, present):
        u, v, live = _host_edges(sots, ts, present)
        N, T = present.shape
        out = np.zeros((N, T))
        for j in range(T):
            m = live[:, j]
            a = np.zeros((N, N), np.float32)
            a[u[m], v[m]] = 1.0
            a[v[m], u[m]] = 1.0
            a2 = a @ a
            out[:, j] = np.round((a2 * a).sum(0) * 0.5)
        return out

    def device(self, jnp, arrs, act, live):
        from repro.kernels.temporal_motif import ops as motif_ops

        u, v = arrs["edge_u"], arrs["edge_v"]
        N, T = act.shape
        live_t = live.T  # (T, E)
        adj = (jnp.zeros((T, N, N), jnp.float32)
               .at[:, u, v].max(live_t).at[:, v, u].max(live_t))
        # pallas natively on TPU, the identical jnp math elsewhere
        tri = motif_ops.temporal_motif(adj, use_pallas=motif_ops._on_tpu())
        return tri.T  # (N, T) int32


class FusedScalarOp:
    """Evolution-stage wrapper: a FusedOp's per-node series reduced to a
    scalar per timepoint, on both paths.  Usable directly as a
    vectorized evolution fn (the staged host path)."""

    vectorized = True

    def __init__(self, base: FusedOp, reduce: str):
        self.base = base
        self.reduce = reduce
        self.name = f"{base.name}.{reduce}"

    def params(self):
        return (self.reduce,) + tuple(self.base.params())

    def __call__(self, son, ts):
        ts = np.asarray(ts, np.int64).ravel()
        present, _ = replay.state_at_many(son, ts)
        series = self.base.host(son, ts, present)
        return self._reduce_host(series, present)

    def _reduce_host(self, series, present):
        N, T = series.shape
        if self.reduce == "sum3":  # per-node triangle counts -> totals
            return series.sum(axis=0) / 3.0
        if self.reduce == "count_components":
            own = series == np.arange(N, dtype=np.float64)[:, None]
            return (own & (present == 1)).sum(axis=0).astype(np.float64)
        if self.reduce == "max":
            return series.max(axis=0, initial=0.0)
        raise ValueError(self.reduce)

    def reduce_device(self, jnp, series_nt, act):
        """(T,) device reduction; integer reducers stay exact and finish
        their float math on the host (``epilogue``)."""
        if self.reduce == "sum3":
            return jnp.sum(series_nt.astype(jnp.int32), axis=0)
        if self.reduce == "count_components":
            import jax

            N, T = act.shape
            iota = jax.lax.broadcasted_iota(jnp.int32, (N, T), 0)
            own = (series_nt == iota) & (act > 0)
            return jnp.sum(own.astype(jnp.int32), axis=0)
        if self.reduce == "max":
            return jnp.max(series_nt, axis=0, initial=0.0)
        raise ValueError(self.reduce)

    def epilogue(self, reduced: np.ndarray) -> np.ndarray:
        if self.reduce == "sum3":
            return reduced.astype(np.float64) / 3.0
        return reduced.astype(np.float64)


def pagerank(damping: float = 0.85, iters: int = 20) -> PageRankOp:
    return PageRankOp(damping=damping, iters=iters)


def components(iters: int = 32) -> ComponentsOp:
    return ComponentsOp(iters=iters)


def triangles() -> TrianglesOp:
    return TrianglesOp()


def triangle_count() -> FusedScalarOp:
    """Evolution fn: total triangles per timepoint."""
    return FusedScalarOp(TrianglesOp(), "sum3")


def component_count(iters: int = 32) -> FusedScalarOp:
    """Evolution fn: number of connected components per timepoint."""
    return FusedScalarOp(ComponentsOp(iters=iters), "count_components")


def max_pagerank(damping: float = 0.85, iters: int = 20) -> FusedScalarOp:
    """Evolution fn: the top PageRank score per timepoint."""
    return FusedScalarOp(PageRankOp(damping=damping, iters=iters), "max")


# ---------------------------------------------------------------------------
# Device operand export (uploaded once per operand, weakref-guarded)
# ---------------------------------------------------------------------------


def _node_arrays(son: SoN):
    key = (replay.operand_key(son), "node")
    hit = _operands.get(key, owner=son)
    if hit is not None:
        return hit
    import jax.numpy as jnp

    STATS["operand_uploads"] += 1
    pads = son.padded_events()
    arrs = {
        "ev_t": jnp.asarray(pads["t"]),
        "ev_kind": jnp.asarray(pads["kind"].astype(np.int32)),
        "ev_key": jnp.asarray(pads["key"].astype(np.int32)),
        "ev_val": jnp.asarray(pads["val"]),
        "init_present": jnp.asarray(son.init_present.astype(np.int32)),
        "init_attrs": jnp.asarray(son.init_attrs),
    }
    _operands.put(key, arrs, owner=son)
    return arrs


def _edge_arrays(sots: SoTS):
    key = (replay.operand_key(sots), "edge")
    hit = _operands.get(key, owner=sots)
    if hit is not None:
        return hit
    import jax.numpy as jnp

    STATS["operand_uploads"] += 1
    N = len(sots)
    er = replay.edge_replay(sots)
    exp = er.device_export()
    flip_t, flip_s, base = exp["flip_t"], exp["flip_s"], exp["base"]
    if er.n_pairs == 0:  # dummy never-existing pair keeps gathers in-bounds
        flip_t = np.zeros((1, 1), np.int64)
        flip_s = np.full((1, 1), -1, np.int8)
        base = np.zeros(1, np.int8)
    v = replay.member_rows(exp["pair_other"], sots.node_ids).astype(np.int64)
    u = exp["pair_center"].astype(np.int64)
    valid = (v >= 0) & (u != v)
    cu = np.minimum(u[valid], v[valid])
    cv = np.maximum(u[valid], v[valid])
    ekey = cu * max(N, 1) + cv
    uniq = np.unique(ekey) if len(ekey) else np.empty(0, np.int64)
    E = max(len(uniq), 1)
    eu = np.zeros(E, np.int32)
    ev_ = np.zeros(E, np.int32)
    eu[: len(uniq)] = uniq // max(N, 1)
    ev_[: len(uniq)] = uniq % max(N, 1)
    # the <=2 directed pair rows per canonical edge (OR-folded by gather,
    # not scatter: contiguous T-rows are cheap, scatters are not)
    pair_a = np.zeros(E, np.int32)
    pair_b = np.zeros(E, np.int32)
    edge_valid = np.zeros(E, np.float32)
    if len(uniq):
        rows = np.nonzero(valid)[0]
        order = np.argsort(ekey, kind="stable")
        srt_keys, srt_rows = ekey[order], rows[order]
        first = np.searchsorted(srt_keys, uniq, side="left")
        last = np.searchsorted(srt_keys, uniq, side="right") - 1
        pair_a[: len(uniq)] = srt_rows[first]
        pair_b[: len(uniq)] = srt_rows[last]
        edge_valid[: len(uniq)] = 1.0
    # flat incidence (2E,) sorted by node: one contiguous-row scatter per
    # propagation step instead of two scalar-indexed ones
    frow = np.concatenate([eu, ev_]).astype(np.int64)
    fcol = np.concatenate([ev_, eu])
    feid = np.concatenate([np.arange(E), np.arange(E)]).astype(np.int32)
    o = np.argsort(frow, kind="stable")
    arrs = {
        "flip_t": jnp.asarray(flip_t),
        "flip_s": jnp.asarray(flip_s.astype(np.int32)),
        "base": jnp.asarray(base.astype(np.int32)),
        "edge_u": jnp.asarray(eu),
        "edge_v": jnp.asarray(ev_),
        "pair_a": jnp.asarray(pair_a),
        "pair_b": jnp.asarray(pair_b),
        "edge_valid": jnp.asarray(edge_valid),
        "frow": jnp.asarray(frow[o].astype(np.int32)),
        "fcol": jnp.asarray(fcol[o]),
        "feid": jnp.asarray(feid[o]),
        "n_real_edges": len(uniq),
    }
    _operands.put(key, arrs, owner=sots)
    return arrs


# ---------------------------------------------------------------------------
# Device programs (jnp; shared by every covered plan shape)
# ---------------------------------------------------------------------------


def _dev_presence(jnp, node, tsv):
    """(N, T) int32 presence — the device ``state_at_many`` presence
    half.  Pad slots are re-sentineled in-dtype (the host int64-max pad
    wraps under jax's default int32, as in ``degree_series_kernel``)."""
    import jax

    ev_t, kind = node["ev_t"], node["ev_kind"]
    big = jnp.iinfo(ev_t.dtype).max
    ev_t_s = jnp.where(kind < 0, big, ev_t)
    cnt = jax.vmap(lambda row: jnp.searchsorted(row, tsv, side="right"))(ev_t_s)
    E = ev_t.shape[1]
    rank = jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32)[None, :],
                            ev_t.shape)
    pmask = (kind == NODE_ADD) | (kind == NODE_DEL) | (kind == NATTR_SET)
    plast = jax.lax.cummax(jnp.where(pmask, rank, -1), axis=1)
    pidx = jnp.take_along_axis(plast, jnp.maximum(cnt - 1, 0), axis=1)
    pidx = jnp.where(cnt > 0, pidx, -1)
    kind_at = jnp.take_along_axis(kind, jnp.maximum(pidx, 0), axis=1)
    return jnp.where(pidx >= 0, (kind_at != NODE_DEL).astype(jnp.int32),
                     node["init_present"][:, None])


def _dev_attrs(jnp, node, tsv, cnt_cache=None):
    """(N, T, K) int32 attrs — last write per (node, key) with NODE_DEL
    clearing every key, exactly the host replay semantics."""
    import jax

    ev_t, kind = node["ev_t"], node["ev_kind"]
    ekey, eval_ = node["ev_key"], node["ev_val"]
    big = jnp.iinfo(ev_t.dtype).max
    ev_t_s = jnp.where(kind < 0, big, ev_t)
    cnt = jax.vmap(lambda row: jnp.searchsorted(row, tsv, side="right"))(ev_t_s)
    E = ev_t.shape[1]
    K = node["init_attrs"].shape[1]
    rank = jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32)[None, :],
                            ev_t.shape)
    cols = []
    for k in range(K):  # K is small and static
        wmask = ((kind == NATTR_SET) & (ekey == k)) | (kind == NODE_DEL)
        wlast = jax.lax.cummax(jnp.where(wmask, rank, -1), axis=1)
        widx = jnp.take_along_axis(wlast, jnp.maximum(cnt - 1, 0), axis=1)
        widx = jnp.where(cnt > 0, widx, -1)
        kind_at = jnp.take_along_axis(kind, jnp.maximum(widx, 0), axis=1)
        val_at = jnp.take_along_axis(eval_, jnp.maximum(widx, 0), axis=1)
        col = jnp.where(widx >= 0,
                        jnp.where(kind_at == NODE_DEL, -1, val_at),
                        node["init_attrs"][:, k][:, None])
        cols.append(col)
    return jnp.stack(cols, axis=-1)


def _dev_edge_live(jnp, edge, act, tsv):
    """(E, T) f32 edge liveness from the padded flip table: pair state at
    each timepoint (searchsorted per row), the <=2 directed pair rows
    OR-folded by contiguous-row gather, masked by both endpoints'
    presence.  ``act`` is (N, T) f32 — everything stays (entity, T)-major
    so propagation scatters move whole contiguous T-rows."""
    import jax

    flip_t, flip_s = edge["flip_t"], edge["flip_s"]
    big = jnp.iinfo(flip_t.dtype).max
    flip_t_s = jnp.where(flip_s < 0, big, flip_t)
    cnt = jax.vmap(lambda row: jnp.searchsorted(row, tsv, side="right"))(
        flip_t_s)  # (P, T)
    st_at = jnp.take_along_axis(flip_s, jnp.maximum(cnt - 1, 0), axis=1)
    exist = jnp.where(cnt > 0, st_at, edge["base"][:, None])  # (P, T)
    pair_live = (exist == 1).astype(jnp.float32)
    el = jnp.maximum(pair_live[edge["pair_a"]], pair_live[edge["pair_b"]])
    el = el * edge["edge_valid"][:, None]
    return el * act[edge["edge_u"]] * act[edge["edge_v"]]


# ---------------------------------------------------------------------------
# Program cache + lowering
# ---------------------------------------------------------------------------


def _shape_sig(arrs) -> Tuple:
    return tuple(sorted(
        (k, tuple(v.shape), str(v.dtype))
        for k, v in arrs.items() if hasattr(v, "shape")))


def _get_program(key, builder):
    prog = _programs.get(key)
    if prog is None:
        STATS["compile_misses"] += 1
        prog = builder()
        _programs[key] = prog
        while len(_programs) > _PROGRAM_CACHE_MAX:
            _programs.popitem(last=False)
    else:
        STATS["compile_hits"] += 1
        _programs.move_to_end(key)
    return prog


def _build_slice_program():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def prog(node, tsv):
        STATS["traces"] += 1  # runs at trace time only
        return _dev_presence(jnp, node, tsv), _dev_attrs(jnp, node, tsv)

    return prog


def _build_series_program(op: FusedOp):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def prog(node, edge, tsv):
        STATS["traces"] += 1
        act = _dev_presence(jnp, node, tsv).astype(jnp.float32)  # (N, T)
        live = _dev_edge_live(jnp, edge, act, tsv)
        return op.device(jnp, edge, act, live)

    return prog


def _build_evolution_program(sop: FusedScalarOp):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def prog(node, edge, tsv):
        STATS["traces"] += 1
        act = _dev_presence(jnp, node, tsv).astype(jnp.float32)
        live = _dev_edge_live(jnp, edge, act, tsv)
        series = sop.base.device(jnp, edge, act, live)
        return sop.reduce_device(jnp, series, act)

    return prog


def _tsv(ts) -> "Any":
    import jax.numpy as jnp

    return jnp.asarray(np.asarray(ts, np.int64))


# ---------------------------------------------------------------------------
# Entry point (called by PlanExecutor for every terminal stage)
# ---------------------------------------------------------------------------


def try_fused(operand, stage, replay_cache=None):
    """Run one terminal stage fused if its shape is covered.

    Returns ``(value, notes)``; ``value is MISS`` means "not covered,
    run the staged path" with notes carrying the reason.
    """
    if not ENABLED:
        return MISS, ("compile: staged (fusion disabled)",)
    if operand is None or len(operand) == 0:
        return MISS, ("compile: staged (empty operand)",)
    k = stage.kind
    try:
        if k == "slice":
            return _fused_slice(operand, stage, replay_cache)
        if k == "compute":
            if stage.style == "temporal" and isinstance(stage.fn, FusedOp):
                return _fused_compute(operand, stage)
            return MISS, (f"compile: staged compute (style={stage.style!r}, "
                          "fn is not a FusedOp)",)
        if k == "evolution":
            if isinstance(stage.fn, FusedScalarOp):
                return _fused_evolution(operand, stage)
            return MISS, ("compile: staged evolution (fn is not a "
                          "FusedScalarOp)",)
    except ImportError as e:  # pragma: no cover - jax missing
        return MISS, (f"compile: staged (device backend unavailable: {e})",)
    return MISS, (f"compile: staged ({k})",)


def _fused_slice(operand, stage, replay_cache):
    if np.isscalar(stage.ts):
        return MISS, ("compile: staged slice (scalar timepoint)",)
    ts = np.asarray(list(stage.ts), np.int64).ravel()
    T = len(ts)
    if T < MIN_FUSE_T:
        return MISS, (f"compile: staged slice (T={T} < MIN_FUSE_T="
                      f"{MIN_FUSE_T})",)
    # share the executor's replay LRU: a repeated fused slice re-dispatches
    # nothing, and a fused slice never poisons the staged cache (values are
    # bit-identical by construction)
    ckey = (replay.operand_key(operand),
            ("multi", tuple(int(x) for x in ts)))
    if replay_cache is not None:
        hit = replay_cache.get(ckey, owner=operand)
        if hit is not None:
            value = {kk: (vv.copy() if isinstance(vv, np.ndarray) else vv)
                     for kk, vv in hit.items()}
            return value, ("compile: fused slice (replay-LRU hit)",)
    node = _node_arrays(operand)
    key = ("slice", _shape_sig(node), T)
    hit_before = key in _programs
    prog = _get_program(key, _build_slice_program)
    pres, attrs = prog(node, _tsv(ts))
    value = {
        "present": np.asarray(pres).astype(operand.init_present.dtype),
        "attrs": np.asarray(attrs).astype(operand.init_attrs.dtype),
        "t": ts,
    }
    if replay_cache is not None:
        replay_cache.put(ckey, value, owner=operand)
        value = {kk: (vv.copy() if isinstance(vv, np.ndarray) else vv)
                 for kk, vv in value.items()}
    STATS["fused_runs"] += 1
    note = (f"compile: fused slice (T={T}, "
            f"{'cache hit' if hit_before else 'traced'})")
    return value, (note,)


def _check_sots(operand):
    if not isinstance(operand, SoTS):
        raise ValueError(
            "fused temporal-analytics ops need a SoTS operand (adjacency); "
            "fetch with subgraphs()/build_sots")


def _fused_compute(operand, stage):
    _check_sots(operand)
    op: FusedOp = stage.fn
    ts = ops.eval_points(operand, stage.points).astype(np.int64)
    T = len(ts)
    miss = _budget_miss(op, operand, T)
    if miss is not None:
        return miss
    node = _node_arrays(operand)
    edge = _edge_arrays(operand)
    key = ("compute", op.name, op.params(), _shape_sig(node),
           _shape_sig(edge), T)
    hit_before = key in _programs
    prog = _get_program(key, lambda: _build_series_program(op))
    series = prog(node, edge, _tsv(ts))
    out = np.asarray(series, np.float64).reshape(len(operand), T)
    STATS["fused_runs"] += 1
    note = (f"compile: fused compute[{op.name}] (T={T}, "
            f"{'cache hit' if hit_before else 'traced'})")
    return (ts, out), (note,)


def _fused_evolution(operand, stage):
    _check_sots(operand)
    sop: FusedScalarOp = stage.fn
    if stage.points is None:
        ts = np.linspace(operand.t0, operand.t1,
                         stage.n_samples).astype(np.int64)
    else:
        ts = ops.eval_points(operand, stage.points).astype(np.int64)
    T = len(ts)
    miss = _budget_miss(sop.base, operand, T)
    if miss is not None:
        return miss
    node = _node_arrays(operand)
    edge = _edge_arrays(operand)
    key = ("evolution", sop.name, sop.params(), _shape_sig(node),
           _shape_sig(edge), T)
    hit_before = key in _programs
    prog = _get_program(key, lambda: _build_evolution_program(sop))
    reduced = prog(node, edge, _tsv(ts))
    series = sop.epilogue(np.asarray(reduced))
    STATS["fused_runs"] += 1
    note = (f"compile: fused evolution[{sop.name}] (T={T}, "
            f"{'cache hit' if hit_before else 'traced'})")
    return (ts, series), (note,)


def _budget_miss(op: FusedOp, operand, T: int):
    """Dense-adjacency programs refuse shapes whose (T, N, N) stack
    would blow the budget — the staged path handles them instead."""
    if isinstance(op, TrianglesOp) and T * len(operand) ** 2 > DENSE_BUDGET:
        return MISS, (f"compile: staged compute[{op.name}] (dense stack "
                      f"T*N^2={T * len(operand) ** 2} exceeds budget)",)
    return None
