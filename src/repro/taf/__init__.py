from repro.taf import analytics, operators, replay
from repro.taf.plan import Plan, PlanExecutor, PlanResult
from repro.taf.query import HistoricalGraphStore, TemporalQuery
from repro.taf.son import SoN, SoTS, build_son, build_sots

__all__ = [
    "HistoricalGraphStore", "TemporalQuery", "Plan", "PlanExecutor",
    "PlanResult", "analytics", "operators", "replay", "SoN", "SoTS",
    "build_son", "build_sots",
]
