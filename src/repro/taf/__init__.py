from repro.taf import analytics, compile, operators, replay
from repro.taf.plan import Plan, PlanExecutor, PlanResult
from repro.taf.query import HistoricalGraphStore, TemporalQuery
from repro.taf.son import SoN, SoTS, build_son, build_sots

__all__ = [
    "HistoricalGraphStore", "TemporalQuery", "Plan", "PlanExecutor",
    "PlanResult", "analytics", "compile", "operators", "replay", "SoN",
    "SoTS", "build_son", "build_sots",
]
