from repro.taf import analytics, operators
from repro.taf.son import SoN, SoTS, build_son, build_sots

__all__ = ["analytics", "operators", "SoN", "SoTS", "build_son", "build_sots"]
