"""Batched multi-timepoint temporal replay engine.

The Kairos insight (arXiv 2401.02563), applied to the TAF compute layer:
when a query evaluates T timepoints over the same operand, share ONE
chronological pass over the event log across all of them instead of
rescanning per timepoint.  Every event is assigned the *first* query
timepoint it applies to (a searchsorted against the sorted timepoints);
last-write-wins per (entity, timepoint-bucket) plus a forward-fill along
the time axis then yields the state at every timepoint in O(E + N·T)
instead of O(E·T).

Three engines live here:

* ``state_at_many``  — node presence/attrs at T timepoints in one pass
                       (the batched generalization of
                       ``operators._state_at``; bit-identical to the
                       ``_state_at_ref`` loop, property-tested);
* ``EdgeReplay``     — a per-SoTS (center, neighbor) pair table built
                       once from the initial adjacency + edge events;
                       answers ``exist_matrix``/``degree_series``/
                       ``neighbors_at``/``csr_at`` at any set of
                       timepoints without re-touching the event log;
* ``graph_at_many``  — materialized ``GraphState`` per timepoint riding
                       both engines (the state extraction under
                       density/LCC/PageRank-over-time series).

``ReplayCache`` is the small LRU the plan executor keys on
``(operand identity, timepoints)`` so repeated slices of the same
operand don't replay at all.  ``STATS`` counts engine invocations —
tests use it to assert a multi-timepoint plan issues exactly one replay.
"""
from __future__ import annotations

import threading
import weakref
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.events import EDGE_ADD, EDGE_DEL, NATTR_SET, NODE_ADD, NODE_DEL
from repro.core.snapshot import GraphState, pack_edge_key
from repro.taf.son import SoN, SoTS

# engine invocation counters (reset freely in tests)
STATS: Dict[str, int] = {
    "state_at_many": 0,
    "edge_tables_built": 0,
    "exist_matrix": 0,
}

_T_NEG_INF = np.iinfo(np.int64).min


def _sorted_axis(ts) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(ts, ts_sorted, order) with ts int64 1-D.  Results are computed on
    the sorted axis and scattered back through ``order`` so callers keep
    their requested timepoint order (duplicates allowed)."""
    ts = np.asarray(ts, np.int64).ravel()
    order = np.argsort(ts, kind="stable")
    return ts, ts[order], order


def _ffill_last_write(written: np.ndarray, values: np.ndarray,
                      base: np.ndarray) -> np.ndarray:
    """Row-wise forward-fill of sparse writes along the last axis.

    ``written``  (..., T) bool  — a write landed in this column;
    ``values``   (..., T)       — the written value (garbage where not);
    ``base``     (...,)         — the value before the first write.
    """
    T = written.shape[-1]
    col = np.arange(T, dtype=np.int32)
    idx = np.where(written, col, np.int32(-1))
    np.maximum.accumulate(idx, axis=-1, out=idx)
    filled = np.take_along_axis(values, np.maximum(idx, 0), axis=-1)
    return np.where(idx >= 0, filled, base[..., None])


# ---------------------------------------------------------------------------
# Node state at many timepoints (one sorted-event pass)
# ---------------------------------------------------------------------------


def state_at_many(son: SoN, ts) -> Tuple[np.ndarray, np.ndarray]:
    """Presence/attrs of every node at every timepoint in ONE pass.

    Returns ``(present (N, T), attrs (N, T, K))`` with column j equal to
    ``operators._state_at_ref(son, ts[j])`` bit-for-bit.  Each event is
    bucketed to the first timepoint it applies to; last-write-wins per
    (node, bucket) [presence] / (node, key, bucket) [attrs] + a forward
    fill along the sorted time axis replaces the per-timepoint rescan.
    """
    STATS["state_at_many"] += 1
    N = len(son)
    K = son.init_attrs.shape[1]
    ts, tss, order = _sorted_axis(ts)
    T = len(ts)
    if T == 0:
        return (np.empty((N, 0), son.init_present.dtype),
                np.empty((N, 0, K), son.init_attrs.dtype))
    if not len(son.ev_t):
        return (np.repeat(son.init_present[:, None], T, axis=1),
                np.repeat(son.init_attrs[:, None, :], T, axis=1))

    # bucket = first sorted timepoint the event applies to (ev_t <= t)
    bkt_all = np.searchsorted(tss, son.ev_t, side="left")
    idx = np.nonzero(bkt_all < T)[0]  # events beyond every timepoint drop out
    nodes = son.node_of_events()[idx]
    kind = son.ev_kind[idx]
    bkt = bkt_all[idx]

    # --- presence: last node-state event per (node, bucket) wins ---
    pm = (kind == NODE_ADD) | (kind == NODE_DEL) | (kind == NATTR_SET)
    if pm.any():
        pn, pb = nodes[pm], bkt[pm]
        pv = (kind[pm] != NODE_DEL).astype(np.int8)
        # CSR order is chronological within a node, and buckets are
        # monotone in time, so group-last is a boundary test
        last = np.r_[(pn[1:] != pn[:-1]) | (pb[1:] != pb[:-1]), True]
        upd = np.full((N, T), -1, np.int8)
        upd[pn[last], pb[last]] = pv[last]
        present_s = _ffill_last_write(
            upd >= 0, upd, son.init_present.astype(np.int8)
        ).astype(son.init_present.dtype)
    else:
        present_s = np.repeat(son.init_present[:, None], T, axis=1)

    # --- attrs: last write per (node, key, bucket) wins; a NODE_DEL is
    # a write of -1 to every key ---
    am = kind == NATTR_SET
    dm = kind == NODE_DEL
    if am.any() or dm.any():
        seq = idx  # chronological rank within each node's run
        an, ak = nodes[am], son.ev_key[idx][am].astype(np.int64)
        ab, av, aseq = bkt[am], son.ev_val[idx][am], seq[am]
        dn, db, dseq = nodes[dm], bkt[dm], seq[dm]
        karr = np.arange(K, dtype=np.int64)
        wn = np.concatenate([an, np.repeat(dn, K)])
        wk = np.concatenate([ak, np.tile(karr, len(dn))])
        wb = np.concatenate([ab, np.repeat(db, K)])
        wv = np.concatenate([av, np.full(len(dn) * K, -1, son.init_attrs.dtype)])
        ws = np.concatenate([aseq, np.repeat(dseq, K)])
        o2 = np.lexsort((ws, wb, wk, wn))
        wn, wk, wb, wv = wn[o2], wk[o2], wb[o2], wv[o2]
        last = np.r_[(wn[1:] != wn[:-1]) | (wk[1:] != wk[:-1])
                     | (wb[1:] != wb[:-1]), True]
        vals = np.zeros((N, K, T), son.init_attrs.dtype)
        written = np.zeros((N, K, T), bool)
        vals[wn[last], wk[last], wb[last]] = wv[last]
        written[wn[last], wk[last], wb[last]] = True
        attrs_s = _ffill_last_write(written, vals, son.init_attrs)
        attrs_s = np.ascontiguousarray(attrs_s.transpose(0, 2, 1))  # (N, T, K)
    else:
        attrs_s = np.repeat(son.init_attrs[:, None, :], T, axis=1)

    # scatter back to the caller's timepoint order
    present = np.empty_like(present_s)
    attrs = np.empty_like(attrs_s)
    present[:, order] = present_s
    attrs[:, order] = attrs_s
    return present, attrs


# ---------------------------------------------------------------------------
# Edge replay: (center, neighbor) pair table over a SoTS
# ---------------------------------------------------------------------------


class EdgeReplay:
    """One-pass edge-event replay table for a SoTS.

    Built once per operand: every (center row, neighbor id) pair that
    ever exists — from the initial 1-hop adjacency or an EDGE_ADD/DEL
    event — becomes one row of a sorted table carrying its chronological
    state flips.  Any set of timepoints is then answered with a single
    searchsorted + last-state-per-bucket + forward-fill, replacing the
    per-(node, t) Python-set loops of the old ``neighbors_at``/``graph``.
    """

    def __init__(self, sots: SoTS):
        STATS["edge_tables_built"] += 1
        N = len(sots)
        em = (sots.ev_kind == EDGE_ADD) | (sots.ev_kind == EDGE_DEL)
        eidx = np.nonzero(em)[0]
        en = sots.node_of_events()[eidx]
        eo = sots.ev_other[eidx].astype(np.int64)
        et = sots.ev_t[eidx]
        es = (sots.ev_kind[eidx] == EDGE_ADD).astype(np.int8)
        i0 = np.repeat(np.arange(N, dtype=np.int64),
                       sots.adj_indptr[1:] - sots.adj_indptr[:-1])
        v0 = sots.adj_nbr.astype(np.int64)

        c = np.concatenate([i0, en])
        o = np.concatenate([v0, eo])
        # init entries sort before every event of their pair (seq -1) and
        # apply at every timepoint (t = -inf)
        seq = np.concatenate([np.full(len(i0), -1, np.int64), eidx])
        st = np.concatenate([np.ones(len(i0), np.int8), es])
        tt = np.concatenate([np.full(len(i0), _T_NEG_INF, np.int64), et])
        ordr = np.lexsort((seq, o, c))
        self.c = c[ordr]
        self.o = o[ordr]
        self.seq = seq[ordr]
        self.st = st[ordr]
        self.t = tt[ordr]

        if len(self.c):
            newp = np.r_[True, (self.c[1:] != self.c[:-1])
                         | (self.o[1:] != self.o[:-1])]
        else:
            newp = np.empty(0, bool)
        self.pair_id = np.cumsum(newp) - 1 if len(newp) else np.empty(0, np.int64)
        self.n_pairs = int(self.pair_id[-1]) + 1 if len(self.pair_id) else 0
        first = np.nonzero(newp)[0]
        self.pair_center = self.c[first].astype(np.int64)  # row index into sots
        self.pair_other = self.o[first].astype(np.int64)  # global node id
        # pair existed in the initial adjacency (baseline before events)
        self.base = (self.seq[first] == -1).astype(np.int8)
        self.n_rows = N

    def exist_matrix(self, ts) -> np.ndarray:
        """(n_pairs, T) int8 — pair existence at each requested timepoint
        (columns follow the caller's ``ts`` order)."""
        STATS["exist_matrix"] += 1
        ts, tss, order = _sorted_axis(ts)
        T = len(ts)
        if self.n_pairs == 0 or T == 0:
            return np.zeros((self.n_pairs, T), np.int8)
        evm = self.seq >= 0
        b = np.searchsorted(tss, self.t[evm], side="left")
        keep = b < T
        p = self.pair_id[evm][keep]
        bb = b[keep]
        ss = self.st[evm][keep]
        upd = np.full((self.n_pairs, T), -1, np.int8)
        if len(p):
            # entries are (pair-major, chronological); buckets monotone
            last = np.r_[(p[1:] != p[:-1]) | (bb[1:] != bb[:-1]), True]
            upd[p[last], bb[last]] = ss[last]
        exist_s = _ffill_last_write(upd >= 0, upd, self.base).astype(np.int8)
        exist = np.empty_like(exist_s)
        exist[:, order] = exist_s
        return exist

    def degree_series(self, ts) -> np.ndarray:
        """(N, T) neighbor-set size of every center at every timepoint —
        the batched replacement for ``len(neighbors_at(i, t))`` loops."""
        exist = self.exist_matrix(ts)
        deg = np.zeros((self.n_rows, exist.shape[1]), np.int64)
        np.add.at(deg, self.pair_center, exist.astype(np.int64))
        return deg

    def neighbors_at(self, i: int, t: int) -> np.ndarray:
        """Sorted neighbor ids of center row i at time t (single-pair
        query path: touches only row i's slice of the table)."""
        lo, hi = np.searchsorted(self.c, [i, i + 1])
        if lo == hi:
            return np.empty(0, np.int32)
        ok = np.nonzero(self.t[lo:hi] <= t)[0]
        if not len(ok):
            return np.empty(0, np.int32)
        p = self.pair_id[lo:hi][ok]
        last = np.r_[p[1:] != p[:-1], True]
        sel = ok[last]
        alive = self.st[lo:hi][sel] == 1
        return self.o[lo:hi][sel][alive].astype(np.int32)  # o-sorted already

    def csr_at(self, t: int) -> Tuple[np.ndarray, np.ndarray]:
        """(indptr (N+1), neighbors) adjacency snapshot at time t."""
        exist = self.exist_matrix([int(t)])[:, 0] == 1
        centers = self.pair_center[exist]
        nbrs = self.pair_other[exist].astype(np.int32)
        indptr = np.searchsorted(centers, np.arange(self.n_rows + 1))
        return indptr.astype(np.int64), nbrs

    def device_export(self) -> Dict[str, np.ndarray]:
        """Device-friendly padded flip table (cached per EdgeReplay).

        The variable-length per-pair event runs become dense
        ``flip_t (n_pairs, F)`` / ``flip_s (n_pairs, F)`` arrays (F = max
        flips per pair, pad ``flip_s = -1``, pad ``flip_t = int64 max``),
        chronological within each row.  Pair existence at any timepoint is
        then one searchsorted per row — the layout the whole-plan compiler
        (repro.taf.compile) uploads once per operand and reuses for every
        jitted dispatch.  ``base``/``pair_center``/``pair_other`` ride
        along so a device program can rebuild adjacency without touching
        the host table again.
        """
        cached = getattr(self, "_device_export", None)
        if cached is not None:
            return cached
        evm = self.seq >= 0
        p = self.pair_id[evm]
        counts = (np.bincount(p, minlength=self.n_pairs).astype(np.int64)
                  if self.n_pairs else np.zeros(0, np.int64))
        F = max(int(counts.max()) if len(counts) else 0, 1)
        flip_t = np.full((self.n_pairs, F), np.iinfo(np.int64).max, np.int64)
        flip_s = np.full((self.n_pairs, F), -1, np.int8)
        if len(p):
            # table order is (pair-major, chronological): column index is
            # the event's rank within its pair's run
            col = np.arange(len(p)) - np.r_[0, np.cumsum(counts)][p]
            flip_t[p, col] = self.t[evm]
            flip_s[p, col] = self.st[evm]
        cached = {
            "flip_t": flip_t, "flip_s": flip_s,
            "base": self.base.astype(np.int8),
            "pair_center": self.pair_center.astype(np.int32),
            "pair_other": self.pair_other.copy(),
        }
        self._device_export = cached
        return cached


def member_rows(other: np.ndarray, node_ids: np.ndarray) -> np.ndarray:
    """Map global node ids to row indices into ``node_ids`` (-1 for ids
    outside the member set) — the pair-table -> adjacency hop the device
    programs need (``pair_other`` is a global id, not a row)."""
    other = np.asarray(other, np.int64)
    node_ids = np.asarray(node_ids, np.int64)
    if not len(node_ids):
        return np.full(len(other), -1, np.int32)
    pos = np.clip(np.searchsorted(node_ids, other), 0, len(node_ids) - 1)
    return np.where(node_ids[pos] == other, pos, -1).astype(np.int32)


def edge_replay(sots: SoTS) -> EdgeReplay:
    """The operand's cached EdgeReplay (built on first use; SoN/SoTS
    operands are immutable once fetched, so the table stays valid)."""
    cached = getattr(sots, "_edge_replay", None)
    if cached is None or cached.n_rows != len(sots):
        cached = EdgeReplay(sots)
        sots._edge_replay = cached
    return cached


def degree_series(sots: SoTS, ts) -> np.ndarray:
    """(N, T) degree of every member at every timepoint, one pass."""
    return edge_replay(sots).degree_series(ts)


def neighbors_at_many(sots: SoTS, i: int, ts) -> List[np.ndarray]:
    """Neighbor sets of center i at each timepoint (shared table)."""
    er = edge_replay(sots)
    return [er.neighbors_at(int(i), int(t)) for t in np.asarray(ts).ravel()]


# ---------------------------------------------------------------------------
# Materialized graphs at many timepoints
# ---------------------------------------------------------------------------


def graph_at_many(sots: SoTS, ts) -> List[GraphState]:
    """GraphState of the SoTS members at each timepoint.  Node state and
    edge existence each come from one batched pass; per-timepoint work is
    only the cheap assembly.  Semantics match ``operators.graph``: edges
    need both endpoints in the member set and a present center."""
    ts = np.asarray(ts, np.int64).ravel()
    K = sots.init_attrs.shape[1]
    n = int(sots.node_ids.max()) + 1 if len(sots) else 0
    present, attrs = state_at_many(sots, ts)
    er = edge_replay(sots)
    exist = er.exist_matrix(ts)
    member_ok = np.isin(er.pair_other, sots.node_ids.astype(np.int64))
    out: List[GraphState] = []
    for j in range(len(ts)):
        g = GraphState.empty(n, K)
        g.present[sots.node_ids] = present[:, j]
        g.attrs[sots.node_ids] = attrs[:, j]
        sel = (exist[:, j] == 1) & member_ok & (present[er.pair_center, j] == 1)
        if sel.any():
            u = sots.node_ids[er.pair_center[sel]].astype(np.int64)
            v = er.pair_other[sel]
            keys = np.unique(pack_edge_key(np.minimum(u, v), np.maximum(u, v)))
            g.edge_key = keys
            g.edge_val = np.full(len(keys), -1, np.int32)
        out.append(g)
    return out


# ---------------------------------------------------------------------------
# LRU replay cache (plan-executor seam)
# ---------------------------------------------------------------------------


def operand_key(son: SoN) -> Tuple:
    """Cheap identity key for an operand (id + shape fields)."""
    return (id(son), son.t0, son.t1, len(son), len(son.ev_t))


class ReplayCache:
    """Small LRU for replayed timeslices/snapshots, keyed on
    ``(operand_key(son), timepoints)`` by the plan executor.

    ``id()`` can be recycled after gc, so every entry also carries a
    weakref to its owning operand; a hit is only served when the owner
    is literally the same live object (a dead or recycled owner entry
    is evicted on lookup).

    Instances are shared class-level by the executor and hit from
    arbitrary query threads, so every dict operation holds an internal
    lock (values are treated as immutable once inserted)."""

    def __init__(self, maxsize: int = 32):
        self.maxsize = maxsize
        # key -> (owner weakref | None, value)
        self._d: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key, owner=None) -> Optional[object]:
        with self._lock:
            entry = self._d.get(key)
            if entry is None:
                self.misses += 1
                return None
            wr, val = entry
            if wr is not None and wr() is not owner:
                del self._d[key]  # stale: owner died / address recycled
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return val

    def put(self, key, value, owner=None) -> None:
        wr = weakref.ref(owner) if owner is not None else None
        with self._lock:
            self._d[key] = (wr, value)
            self._d.move_to_end(key)
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)
