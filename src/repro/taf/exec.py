"""Distributed TAF execution (paper §5.2: Spark workers -> shard_map).

Two pieces:

* ``parallel_fetch`` — the paper's Fig.-10 protocol: the analytics side
  asks the TGI query planner for placement chunks, each *worker* (device)
  pulls only its horizontal-partition slice directly from storage (no
  master bottleneck), and the SoN lands already sharded over the node
  axis.
* ``sharded_node_compute`` — NodeCompute/Timeslice-style kernels run
  under shard_map over a 'workers' mesh axis; metrics requiring global
  reductions (density, max-LCC) psum/pmax inside.  On this 1-device
  container the mesh has one worker; tests/test_taf_distributed.py
  re-runs with 8 placeholder devices in a subprocess to prove the
  distribution path.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.taf import replay
from repro.taf.son import SoN, build_son

STATS = {
    "operand_transfers": 0,   # host->device uploads of a padded operand
    "operand_cache_hits": 0,  # style="kernel" runs served device-resident
}

# device-resident padded operands for style="kernel" computes, keyed
# (operand_key(son), worker count) and weakref-guarded like the replay
# LRU: re-running a kernel (or a different kernel) over the same operand
# re-transfers nothing
_OPERAND_CACHE = replay.ReplayCache(maxsize=16)

# jitted shard_map programs keyed on (kernel compile identity, workers,
# operand shapes): repeated runs skip re-trace.  Kernel factories tag
# their closures with ``compile_key`` so equal-parameter kernels share
# one program; untagged kernels key on object identity.
_FN_CACHE: Dict = {}
_FN_CACHE_MAX = 32


def clear_device_caches() -> None:
    _OPERAND_CACHE.clear()
    _FN_CACHE.clear()


def make_worker_mesh():
    n = len(jax.devices())
    try:  # axis_types landed after jax 0.4.x; plain mesh is equivalent here
        return jax.make_mesh((n,), ("workers",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    except (AttributeError, TypeError):
        return jax.make_mesh((n,), ("workers",))


def parallel_fetch(tgi, t0: int, t1: int, c: int = 1) -> SoN:
    """Deprecated: use ``HistoricalGraphStore.nodes(t0, t1, c=...)`` —
    kept as a thin shim over the same partition-parallel fetch."""
    warnings.warn(
        "parallel_fetch is deprecated; use HistoricalGraphStore.nodes()",
        DeprecationWarning, stacklevel=2,
    )
    with tgi.read_guard():  # snapshot + replay from one pinned epoch
        return build_son(tgi, t0, t1, c=max(c, tgi.cfg.n_shards))


def _pad_to_multiple(x: np.ndarray, mult: int, fill):
    n = len(x)
    pad = (-n) % mult
    if pad == 0:
        return x
    return np.concatenate([x, np.full((pad,) + x.shape[1:], fill, x.dtype)])


def sharded_node_compute(son: SoN, kernel: Callable, mesh=None,
                         extra_args: Dict = None) -> np.ndarray:
    """Run a vectorized per-node kernel under shard_map over workers.

    kernel(present (n,), attrs (n,K), ev_t (n,E), ev_kind (n,E),
    ev_val (n,E)) -> (n,) jnp array.  Padded nodes carry present = -1.
    """
    mesh = mesh or make_worker_mesh()
    W = mesh.devices.size
    okey = (replay.operand_key(son), W)
    operands = _OPERAND_CACHE.get(okey, owner=son)
    if operands is None:
        STATS["operand_transfers"] += 1
        pads = son.padded_events()
        operands = tuple(jnp.asarray(a) for a in (
            _pad_to_multiple(son.init_present.astype(np.int32), W, -1),
            _pad_to_multiple(son.init_attrs, W, -1),
            _pad_to_multiple(pads["t"], W, np.iinfo(np.int64).max),
            _pad_to_multiple(pads["kind"], W, -1),
            _pad_to_multiple(pads["val"], W, -1),
        ))
        _OPERAND_CACHE.put(okey, operands, owner=son)
    else:
        STATS["operand_cache_hits"] += 1

    from jax.sharding import PartitionSpec as P

    spec = P("workers")
    shard_map = jax.shard_map if hasattr(jax, "shard_map") else None
    if shard_map is None:
        from jax.experimental.shard_map import shard_map  # jax<0.7 fallback

    fkey = (getattr(kernel, "compile_key", None) or id(kernel),
            tuple(int(d.id) for d in mesh.devices.flat),
            tuple((a.shape, str(a.dtype)) for a in operands))
    fn = _FN_CACHE.get(fkey)
    if fn is None:
        fn = jax.jit(shard_map(
            lambda *a: kernel(*a),
            mesh=mesh,
            in_specs=(spec,) * 5,
            out_specs=spec,
        ))
        if len(_FN_CACHE) >= _FN_CACHE_MAX:
            _FN_CACHE.clear()
        _FN_CACHE[fkey] = fn
    out = fn(*operands)
    return np.asarray(out)[: len(son)]


def degree_at_kernel(t: int):
    """Example device kernel: degree at time t from edge events (init
    degree must be baked into attrs[..., -1] by the caller)."""
    from repro.core.events import EDGE_ADD, EDGE_DEL

    def kernel(present, attrs, ev_t, ev_kind, ev_val):
        upto = ev_t <= t
        add = jnp.sum(jnp.where(upto & (ev_kind == EDGE_ADD), 1, 0), axis=1)
        sub = jnp.sum(jnp.where(upto & (ev_kind == EDGE_DEL), 1, 0), axis=1)
        deg0 = attrs[:, -1]
        return jnp.where(present == 1, deg0 + add - sub, 0).astype(jnp.int32)

    kernel.compile_key = ("degree_at", int(t))
    return kernel


def degree_series_kernel(ts):
    """Time-batched device kernel: degree at EVERY t in ``ts`` from one
    pass over the padded event arrays — the device-side mirror of
    ``replay.degree_series``.  Returns (n, T) int32; init degree baked
    into attrs[..., -1] as in ``degree_at_kernel``."""
    from repro.core.events import EDGE_ADD, EDGE_DEL

    ts = tuple(int(t) for t in np.asarray(ts).ravel())

    def kernel(present, attrs, ev_t, ev_kind, ev_val):
        # O((E + T) per node) memory: cumulative add/del counts along the
        # (time-sorted, +inf-padded) event axis, gathered at each
        # timepoint's insertion index — NOT an (n, E, T) mask
        tsv = jnp.asarray(ts, ev_t.dtype)
        cum_add = jnp.cumsum((ev_kind == EDGE_ADD).astype(jnp.int32), axis=1)
        cum_del = jnp.cumsum((ev_kind == EDGE_DEL).astype(jnp.int32), axis=1)
        # re-sentinel the pad slots in-dtype: the host's int64-max pad
        # wraps negative under jax's default int32, breaking sortedness
        ev_t_s = jnp.where(ev_kind < 0, jnp.iinfo(ev_t.dtype).max, ev_t)
        idx = jax.vmap(
            lambda row: jnp.searchsorted(row, tsv, side="right")
        )(ev_t_s)  # (n, T) — count of events with t <= each timepoint

        def gather(cum, ix):
            return jnp.where(ix > 0, cum[jnp.maximum(ix - 1, 0)], 0)

        add = jax.vmap(gather)(cum_add, idx)
        sub = jax.vmap(gather)(cum_del, idx)
        deg0 = attrs[:, -1:]
        return jnp.where((present == 1)[:, None],
                         deg0 + add - sub, 0).astype(jnp.int32)

    kernel.compile_key = ("degree_series", ts)
    return kernel


def sharded_degree_series(sots, ts, mesh=None) -> np.ndarray:
    """Degree series for every SoTS member at every t, computed on the
    device mesh in one time-batched kernel launch (the multi-timepoint
    counterpart of ``sharded_degree_at``)."""
    from repro.taf.query import TemporalQuery  # deferred: avoids cycle

    deg0 = (sots.adj_indptr[1:] - sots.adj_indptr[:-1]).astype(np.int32)
    patched = dataclasses.replace(
        sots, init_attrs=np.concatenate([sots.init_attrs, deg0[:, None]], axis=1)
    )
    return (TemporalQuery.over(patched)
            .node_compute(degree_series_kernel(ts), style="kernel", mesh=mesh,
                          label=f"degree_series@{len(np.asarray(ts).ravel())}")
            .execute())


def sharded_degree_at(sots, t: int, mesh=None) -> np.ndarray:
    """Degree-at-t for every SoTS member, computed on devices (a thin
    shim over the plan executor's style="kernel" compute path)."""
    from repro.taf.query import TemporalQuery  # deferred: avoids cycle

    deg0 = (sots.adj_indptr[1:] - sots.adj_indptr[:-1]).astype(np.int32)
    patched = dataclasses.replace(
        sots, init_attrs=np.concatenate([sots.init_attrs, deg0[:, None]], axis=1)
    )
    return (TemporalQuery.over(patched)
            .node_compute(degree_at_kernel(t), style="kernel", mesh=mesh,
                          label=f"degree@{t}")
            .execute())
