"""Compiled query plans over the TAF (the Kairos-style plan seam).

A lazy ``TemporalQuery`` (repro.taf.query) compiles into a ``Plan`` — a
linear chain of typed stages — and one ``PlanExecutor`` runs it:

* ``Fetch``       — SoN/SoTS retrieval from the TGI with the planner's
                    pushdowns applied: partition pruning (a node-set
                    selection fetches only the covering pids) and
                    attribute projection (attrs tiles skipped when no
                    stage reads them).  Cost is accounted per plan via
                    ``TGI.cost_scope``.
* ``Materialize`` — start from an operand already in memory (the shim
                    path for the legacy free functions).
* ``Select``      — entity-centric filter (operator 1).
* ``Slice``       — timeslice (operator 2); folded into a following
                    Compute when it only pins the evaluation points.
* ``Compute``     — NodeCompute/NodeComputeTemporal/NodeComputeDelta
                    (operators 4-6) on the vectorized numpy path, or a
                    device kernel under shard_map (style="kernel").
* ``Evolution``   — aggregate quantity over time (operator 8).
* ``Aggregate``   — temporal aggregation (operator 9).

Keeping the chain declarative until ``execute()`` is what lets fetch see
the whole query: selection and projection push below the storage reads,
and later PRs can fuse/cache/re-target stages without touching callers.

Multi-timepoint stages (a Slice with several ts, Compute(points=...),
Evolution) execute on the batched replay engine (repro.taf.replay): one
sorted-event pass over the operand serves every timepoint.  The executor
additionally keeps a small LRU of replayed timeslices keyed on
(operand identity, timepoints), so repeated slices of one operand cost
one replay total.

Plan selection is cost-based at run time: the Fetch stage re-decides
partition pruning against the TGI's byte estimates (real stored sizes
discounted by decoded-block-pool residency) and the snapshot LRU, and a
cross-plan fetch cache shares one fetched operand between plans over
the same interval/pushdowns (invalidated by ``TGI.read_epoch`` bumps).
``PlanResult.notes`` records every runtime decision.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import weakref
from typing import Any, Callable, Optional, Tuple

import numpy as np

from repro.core.tgi import FetchCost
from repro.taf import operators as ops
from repro.taf import replay
from repro.taf.son import SoN, build_son, build_sots


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Fetch:
    """Pull the operand from the TGI.  ``node_ids`` is the pushed-down
    node selection (None = all nodes at t0); ``projection`` the optional
    payload fields to read (None = everything)."""

    t0: int
    t1: int
    subgraph: bool = False
    node_ids: Optional[Tuple[int, ...]] = None
    projection: Optional[Tuple[str, ...]] = None
    c: int = 1
    kind = "fetch"

    def describe(self) -> str:
        bits = [f"t0={self.t0}", f"t1={self.t1}",
                "operand=SoTS" if self.subgraph else "operand=SoN"]
        if self.node_ids is not None:
            bits.append(f"nodes={len(self.node_ids)} (pruned)")
        if self.projection is not None:
            bits.append(f"projection={list(self.projection)}")
        if self.c != 1:
            bits.append(f"c={self.c}")
        return f"Fetch[{', '.join(bits)}]"


@dataclasses.dataclass(frozen=True)
class Materialize:
    """Operand already in memory (no storage reads, zero fetch cost)."""

    operand: SoN
    kind = "materialize"

    def describe(self) -> str:
        name = type(self.operand).__name__
        return f"Materialize[{name}, n={len(self.operand)}]"


@dataclasses.dataclass(frozen=True)
class Select:
    """Operator 1: pred(son) -> bool mask over nodes."""

    pred: Callable[[SoN], np.ndarray]
    label: str = "λ"
    kind = "select"

    def describe(self) -> str:
        return f"Select[{self.label}]"


@dataclasses.dataclass(frozen=True)
class Slice:
    """Operator 2: state at time(s) ts."""

    ts: Any
    kind = "slice"

    def describe(self) -> str:
        return f"Slice[ts={self.ts}]"


@dataclasses.dataclass(frozen=True)
class Compute:
    """Operators 4-6 / device kernels.

    style: "static" (one timepoint) | "temporal" (O(N·T) re-eval) |
    "delta" (O(N+T) incremental; needs f_delta) | "kernel" (vectorized
    jnp kernel run under shard_map on the device mesh).
    """

    fn: Callable
    style: str = "static"
    f_delta: Optional[Callable] = None
    points: Any = None
    t: Optional[int] = None
    mesh: Any = None
    label: Optional[str] = None
    kind = "compute"

    def describe(self) -> str:
        backend = "shard_map" if self.style == "kernel" else "numpy"
        name = self.label or getattr(self.fn, "__name__", "f")
        return f"Compute[{name}, style={self.style}, backend={backend}]"


@dataclasses.dataclass(frozen=True)
class Evolution:
    """Operator 8: scalar f(son, t) sampled over time."""

    fn: Callable
    points: Any = None
    n_samples: int = 10
    kind = "evolution"

    def describe(self) -> str:
        name = getattr(self.fn, "__name__", "f")
        return f"Evolution[{name}, n_samples={self.n_samples}]"


@dataclasses.dataclass(frozen=True)
class Aggregate:
    """Operator 9 over the preceding stage's timeseries."""

    op: str
    kind = "aggregate"

    def describe(self) -> str:
        return f"Aggregate[{self.op}]"


SOURCE_KINDS = ("fetch", "materialize")
TERMINAL_KINDS = ("slice", "compute", "evolution")


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Plan:
    stages: Tuple[Any, ...]

    @property
    def kinds(self) -> Tuple[str, ...]:
        return tuple(s.kind for s in self.stages)

    def validate(self) -> "Plan":
        kinds = self.kinds
        if not kinds or kinds[0] not in SOURCE_KINDS:
            raise ValueError("plan must start with a Fetch/Materialize stage")
        if sum(k in SOURCE_KINDS for k in kinds) != 1:
            raise ValueError("plan must have exactly one source stage")
        seen_terminal = False
        seen_series = False  # compute/evolution produce an aggregatable series
        for k in kinds[1:]:
            if k in SOURCE_KINDS:
                raise ValueError("source stage must come first")
            if k == "select" and seen_terminal:
                raise ValueError("Select must precede Slice/Compute/Evolution")
            if k in TERMINAL_KINDS:
                if seen_terminal:
                    raise ValueError("only one Slice/Compute/Evolution per plan")
                seen_terminal = True
                seen_series = k in ("compute", "evolution")
            if k == "aggregate" and not seen_series:
                raise ValueError("Aggregate needs a preceding Compute/Evolution "
                                 "(a bare Slice yields a state dict, not a series)")
        return self

    def describe(self) -> str:
        return "Plan\n" + "\n".join(f"  {s.describe()}" for s in self.stages)


@dataclasses.dataclass
class PlanResult:
    value: Any
    cost: FetchCost
    operand: Optional[SoN]
    plan: Plan
    # runtime plan-selection decisions (cost-based fetch choices, fetch-
    # cache hits) — what ``explain()`` could not know at compile time
    notes: Tuple[str, ...] = ()


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------


class PlanExecutor:
    """Runs a Plan: one fetch (pushdowns applied + runtime cost-based
    source selection), then vectorized host operators or shard_map
    device kernels over the operand."""

    # shared across executors: TemporalQuery.run() builds a fresh
    # executor per plan, but repeated slices of one materialized operand
    # should still hit the cache
    _replay_cache = replay.ReplayCache(maxsize=32)

    # cross-plan fetch sharing: plans over the same (tgi, interval,
    # pushdowns) reuse one fetched operand — multi-timepoint plans that
    # hit the same (span, leaf) groups pay one fetch total (finer
    # cross-plan sharing, different t in the same span, is the decoded-
    # block pool's job one layer down).  Entries key on TGI.read_epoch,
    # so any ingest/compaction invalidates them; the weakref guards
    # against id() recycling.  Logical FetchCost is replayed on hits.
    FETCH_CACHE_MAX = 8
    _fetch_cache: "collections.OrderedDict" = collections.OrderedDict()
    # the cache is class-level and executors run on arbitrary query
    # threads: every probe/insert holds this lock (entries are immutable
    # once inserted, so readers only need the dict ops protected)
    _fetch_lock = threading.Lock()

    def __init__(self, tgi=None):
        self.tgi = tgi

    @classmethod
    def clear_fetch_cache(cls) -> None:
        with cls._fetch_lock:
            cls._fetch_cache.clear()

    def run(self, plan: Plan) -> PlanResult:
        plan.validate()
        operand: Optional[SoN] = None
        value: Any = None
        cost = FetchCost()
        notes: Tuple[str, ...] = ()
        for stage in plan.stages:
            k = stage.kind
            if k == "fetch":
                operand, cost, notes = self._fetch(stage)
                value = operand
            elif k == "materialize":
                operand = stage.operand
                value = operand
            elif k == "select":
                operand = ops.selection(operand, stage.pred)
                value = operand
            elif k in TERMINAL_KINDS:
                value, tnotes = self._terminal(operand, stage)
                notes = notes + tnotes
            elif k == "aggregate":
                value = self._aggregate(value, stage.op)
            else:  # pragma: no cover
                raise ValueError(f"unknown stage kind {k!r}")
        return PlanResult(value=value, cost=cost, operand=operand, plan=plan,
                          notes=notes)

    # ---- stage implementations ----

    def _terminal(self, operand: SoN, stage) -> Tuple[Any, Tuple[str, ...]]:
        """Run the terminal stage: whole-plan-compiled when the shape is
        covered (repro.taf.compile, one jitted device dispatch), staged
        otherwise.  Notes record which path ran and why."""
        from repro.taf import compile as taf_compile  # deferred: light plans

        value, cnotes = taf_compile.try_fused(
            operand, stage, replay_cache=self._replay_cache)
        if value is not taf_compile.MISS:
            return value, cnotes
        taf_compile.STATS["fallback_runs"] += 1
        if stage.kind == "slice":
            return self._timeslice_cached(operand, stage.ts), cnotes
        if stage.kind == "compute":
            return self._compute(operand, stage), cnotes
        return ops.evolution(operand, stage.fn, points=stage.points,
                             n_samples=stage.n_samples), cnotes

    def _timeslice_cached(self, son: SoN, ts) -> Any:
        """Operator 2 through the executor's LRU: a repeated slice of the
        same operand at the same timepoint(s) replays zero events."""
        if np.isscalar(ts):
            tkey: Tuple = ("scalar", int(ts))
        else:
            tkey = ("multi", tuple(int(x) for x in np.asarray(ts).ravel()))
        key = (replay.operand_key(son), tkey)
        hit = self._replay_cache.get(key, owner=son)
        if hit is None:
            hit = ops.timeslice(son, ts)
            self._replay_cache.put(key, hit, owner=son)
        # hand out copies: callers may mutate their result in place, and
        # that must not poison the cached arrays
        return {k: (v.copy() if isinstance(v, np.ndarray) else v)
                for k, v in hit.items()}

    def _fetch(self, stage: Fetch) -> Tuple[SoN, FetchCost, Tuple[str, ...]]:
        if self.tgi is None:
            raise ValueError("Fetch stage requires a TGI-backed executor")
        # one read guard around source selection + cache probe + build:
        # every read (cost estimate, snapshot, event replay) sees the
        # same pinned epoch, and the cache key carries that epoch — a
        # concurrent maintenance publish can neither tear the operand
        # nor serve it to a reader of a different epoch
        with self.tgi.read_guard() as _view:
            return self._fetch_guarded(stage, _view)

    def _fetch_guarded(self, stage: Fetch, view,
                       ) -> Tuple[SoN, FetchCost, Tuple[str, ...]]:
        node_ids = None
        pids = None
        notes = []
        if stage.node_ids is not None:
            node_ids = np.unique(np.asarray(stage.node_ids, np.int32))
            pids = self.tgi.pids_for_nodes(node_ids, stage.t0)
            # cost-based source selection: compile-time pushdown said
            # "prune", but runtime state can beat it —
            # (a) the selection covers every partition: pruning buys
            #     nothing and costs the eventlist re-filter;
            # (b) a warm full snapshot sits in the snapshot LRU and the
            #     pruned keys are mostly cold (pool-discounted byte
            #     estimate): the LRU hit costs zero storage bytes while
            #     the pruned read would pay real decodes.
            if len(pids) >= self.tgi.cfg.n_parts:
                pids = None
                notes.append("fetch: pruned->full (selection covers "
                             "every partition)")
            elif self.tgi.has_cached_snapshot(stage.t0, stage.projection,
                                              stage.c):
                est = self.tgi.estimate_fetch_cost(stage.t0, pids)
                if est["physical_raw_bytes"] > 0.5 * max(est["raw_bytes"], 1):
                    pids = None
                    notes.append(
                        "fetch: pruned->full (warm snapshot LRU beats a "
                        f"mostly-cold pruned read of "
                        f"~{int(est['physical_raw_bytes'])}B)")
        notes.append(f"fetch: pinned read epoch {view.epoch}")
        ck = (id(self.tgi), view.epoch, stage.t0, stage.t1,
              stage.subgraph, stage.node_ids, stage.projection, stage.c,
              None if pids is None else tuple(pids))
        with self._fetch_lock:
            hit = self._fetch_cache.get(ck)
            if hit is not None and hit[0]() is self.tgi:
                self._fetch_cache.move_to_end(ck)
                hit_operand, hit_cost = hit[1], hit[2].copy()
            else:
                hit = None
        if hit is not None:
            notes.append("fetch: shared across plans (fetch-cache hit, "
                         "logical cost replayed)")
            return hit_operand, hit_cost, tuple(notes)
        build = build_sots if stage.subgraph else build_son
        with self.tgi.cost_scope() as acc:
            operand = build(self.tgi, stage.t0, stage.t1, node_ids=node_ids,
                            c=stage.c, pids=pids, projection=stage.projection)
        if node_ids is not None:
            # parity with the post-fetch Select spelling: the query's node
            # universe is the t0 snapshot, so drop requested ids that are
            # not alive at t0 (build_son materializes them regardless)
            operand = operand.subset(np.nonzero(operand.init_present == 1)[0])
        with self._fetch_lock:
            self._fetch_cache[ck] = (weakref.ref(self.tgi), operand,
                                     acc.copy())
            while len(self._fetch_cache) > self.FETCH_CACHE_MAX:
                self._fetch_cache.popitem(last=False)
        return operand, acc, tuple(notes)

    def _compute(self, son: SoN, stage: Compute) -> Any:
        if stage.style == "static":
            return ops.node_compute(son, stage.fn, t=stage.t)
        if stage.style == "temporal":
            return ops.node_compute_temporal(son, stage.fn, points=stage.points)
        if stage.style == "delta":
            if stage.f_delta is None:
                raise ValueError('style="delta" requires f_delta')
            return ops.node_compute_delta(son, stage.fn, stage.f_delta,
                                          points=stage.points)
        if stage.style == "kernel":
            from repro.taf import exec as taf_exec  # deferred: pulls in jax

            return taf_exec.sharded_node_compute(son, stage.fn, mesh=stage.mesh)
        raise ValueError(f"unknown compute style {stage.style!r}")

    @staticmethod
    def _aggregate(value: Any, op: str) -> Any:
        if isinstance(value, tuple) and len(value) == 2:
            ts, series = value
            series = np.asarray(series)
            if series.ndim == 2:  # (N, T) node series -> per-node reduction
                if op not in ("max", "min", "mean", "sum", "std"):
                    raise ValueError(
                        f"aggregate {op!r} needs a scalar timeseries; "
                        "got per-node series")
                return getattr(series, op)(axis=1)
            return ops.temp_aggregate(series, op, t=np.asarray(ts))
        return ops.temp_aggregate(np.asarray(value), op)
