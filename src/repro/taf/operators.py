"""Temporal graph operators (paper §5.1, operators 1-9).

The operand is a SoN/SoTS; operators are vectorized over the node axis
(vmap/shard_map on device — see taf.exec — or numpy on host).  The two
evaluation styles the paper benchmarks (Fig. 17):

* ``node_compute_temporal``: re-evaluate f on every materialized version
  — O(N·T);
* ``node_compute_delta``: evaluate f once on the initial state, then fold
  f_delta over events with carried auxiliary state — O(N+T).

Multi-timepoint evaluation rides the batched replay engine
(``repro.taf.replay``): one sorted-event pass serves every requested
timepoint, and setting ``f.vectorized`` (plus ``f_delta.vectorized`` for
the incremental style) unlocks fully array-level evaluation with zero
per-node Python.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.events import (
    EDGE_ADD,
    EDGE_DEL,
    EATTR_SET,
    NATTR_SET,
    NODE_ADD,
    NODE_DEL,
)
from repro.core.snapshot import GraphState
from repro.taf import replay
from repro.taf.son import SoN, SoTS


# ---------------------------------------------------------------------------
# 1. Selection
# ---------------------------------------------------------------------------


def selection(son: SoN, pred: Callable[[SoN], np.ndarray]) -> SoN:
    """Entity-centric filter; pred receives the SoN and returns a boolean
    mask over nodes (vectorized — no per-node python)."""
    mask = np.asarray(pred(son), bool)
    return son.subset(np.nonzero(mask)[0])


# ---------------------------------------------------------------------------
# 2. Timeslice
# ---------------------------------------------------------------------------


def _state_at_ref(son: SoN, t: int):
    """Reference per-event replay (the pre-vectorization semantics the
    fast path below is property-tested against)."""
    N = len(son)
    present = son.init_present.copy()
    attrs = son.init_attrs.copy()
    upto = son.ev_t <= t
    node_of_ev = np.repeat(np.arange(N), son.ev_indptr[1:] - son.ev_indptr[:-1])
    sel = np.nonzero(upto)[0]
    for j in sel:  # per-node chronological; bounded by |events <= t|
        i = node_of_ev[j]
        k = son.ev_kind[j]
        if k == NODE_ADD:
            present[i] = 1
        elif k == NODE_DEL:
            present[i] = 0
            attrs[i] = -1
        elif k == NATTR_SET:
            present[i] = 1
            attrs[i, son.ev_key[j]] = son.ev_val[j]
    return present, attrs


def _state_at(son: SoN, t: int):
    """Vectorized last-write-wins replay of per-node events up to t over
    the initial state.  Returns (present (N,), attrs (N,K)).

    The CSR event arrays are grouped by node and chronological within a
    node, so "last entry of each group" is exactly the replay result:
    presence takes the final NODE_ADD/NODE_DEL/NATTR_SET per node; attrs
    take the final write per (node, key), where a NODE_DEL counts as
    writing -1 to every key.
    """
    N = len(son)
    present = son.init_present.copy()
    attrs = son.init_attrs.copy()
    K = attrs.shape[1]
    if not len(son.ev_t):
        return present, attrs
    idx = np.nonzero(son.ev_t <= t)[0]
    if not len(idx):
        return present, attrs
    node_of_ev = np.repeat(np.arange(N), son.ev_indptr[1:] - son.ev_indptr[:-1])
    nodes = node_of_ev[idx]
    kind = son.ev_kind[idx]

    # --- presence: last node-state event per node wins ---
    pm = (kind == NODE_ADD) | (kind == NODE_DEL) | (kind == NATTR_SET)
    if pm.any():
        pn, pk = nodes[pm], kind[pm]
        last = np.r_[pn[1:] != pn[:-1], True]
        present[pn[last]] = (pk[last] != NODE_DEL).astype(present.dtype)

    # --- attrs: last write per (node, key) wins ---
    am = kind == NATTR_SET
    dm = kind == NODE_DEL
    if am.any() or dm.any():
        seq = np.arange(len(idx))  # chronological rank within the replay
        an, ak = nodes[am], son.ev_key[idx][am].astype(np.int64)
        av, aseq = son.ev_val[idx][am], seq[am]
        dn, dseq = nodes[dm], seq[dm]
        # a NODE_DEL clears every attribute slot: expand it to K writes
        wn = np.concatenate([an, np.repeat(dn, K)])
        wk = np.concatenate([ak, np.tile(np.arange(K, dtype=np.int64), len(dn))])
        wv = np.concatenate([av, np.full(len(dn) * K, -1, attrs.dtype)])
        ws = np.concatenate([aseq, np.repeat(dseq, K)])
        order = np.lexsort((ws, wk, wn))
        wn, wk, wv = wn[order], wk[order], wv[order]
        last = np.r_[(wn[1:] != wn[:-1]) | (wk[1:] != wk[:-1]), True]
        attrs[wn[last], wk[last]] = wv[last]
    return present, attrs


def timeslice(son: SoN, ts) -> Dict[str, np.ndarray]:
    """State of each node at time(s) ts.  Returns dict with 'present'
    (N,[T]) and 'attrs' (N,[T],K).  Multi-timepoint requests run ONE
    batched replay (``replay.state_at_many``), not T rescans."""
    if np.isscalar(ts):
        p, a = _state_at(son, int(ts))
        return {"present": p, "attrs": a, "t": np.asarray([int(ts)])}
    ts = np.asarray(list(ts), np.int64)
    p, a = replay.state_at_many(son, ts)
    return {"present": p, "attrs": a, "t": ts}


def _neighbors_at_ref(sots: SoTS, i: int, t: int) -> np.ndarray:
    """Reference per-event set replay (the pre-vectorization semantics
    ``replay.EdgeReplay`` is property-tested against)."""
    nbr0, _ = sots.neighbors_of(i)
    cur = set(int(x) for x in nbr0)
    evs = sots.events_of(i)
    for j in range(len(evs["t"])):
        if evs["t"][j] > t:
            break
        if evs["kind"][j] == EDGE_ADD:
            cur.add(int(evs["other"][j]))
        elif evs["kind"][j] == EDGE_DEL:
            cur.discard(int(evs["other"][j]))
    return np.asarray(sorted(cur), np.int32)


def neighbors_at(sots: SoTS, i: int, t: int) -> np.ndarray:
    """Neighbor set of node i at time t (initial adjacency + edge events,
    answered from the operand's cached ``EdgeReplay`` pair table)."""
    return replay.edge_replay(sots).neighbors_at(int(i), int(t))


# ---------------------------------------------------------------------------
# 3. Graph
# ---------------------------------------------------------------------------


def graph(sots: SoTS, t: Optional[int] = None) -> GraphState:
    """In-memory GraphS of the SoTS members (edges with both endpoints in
    the set), optionally timesliced at t.  Runs on the vectorized CSR
    path (``replay.graph_at_many``); edge keys use the guarded int64
    shift packing of ``repro.core.snapshot.pack_edge_key``."""
    t = t if t is not None else sots.t0
    return replay.graph_at_many(sots, [int(t)])[0]


def graph_at_many(sots: SoTS, ts) -> List[GraphState]:
    """Batched ``graph``: the GraphS at each timepoint from one shared
    replay pass (state + edge-existence tables built once)."""
    return replay.graph_at_many(sots, ts)


# ---------------------------------------------------------------------------
# 4-6. NodeCompute / NodeComputeTemporal / NodeComputeDelta
# ---------------------------------------------------------------------------


def node_compute(son: SoN, f: Callable, t: Optional[int] = None) -> np.ndarray:
    """Map f over the (timesliced) static nodes.  f receives dict(state)
    for one node and returns a scalar; or set f.vectorized = True to
    receive the whole arrays."""
    t = t if t is not None else son.t0
    present, attrs = _state_at(son, t)
    if getattr(f, "vectorized", False):
        return f(present=present, attrs=attrs, son=son, t=t)
    return np.asarray([
        f(present=present[i], attrs=attrs[i], son=son, i=i, t=t)
        for i in range(len(son))
    ])


def eval_points(son: SoN, points=None) -> np.ndarray:
    """Default: all change points (paper: 'evaluated at all the points of
    change'); points may be an array or a callable(son) -> array."""
    if points is None:
        return son.change_points()
    if callable(points):
        return np.asarray(points(son))
    return np.asarray(points)


def node_compute_temporal(son: SoN, f: Callable, points=None) -> Tuple[np.ndarray, np.ndarray]:
    """f evaluated afresh at every point.  Returns (points (T,),
    values (N, T)).

    States at every point come from ONE batched replay
    (``replay.state_at_many``) instead of T rescans.  With
    ``f.vectorized`` set, f is called once with the full ``present
    (N, T)`` / ``attrs (N, T, K)`` arrays and ``t`` the (T,) points —
    zero per-node Python (the fast path the paper's Fig.-17 temporal
    curve rides); otherwise f is still invoked per (node, point), the
    O(N·T) baseline semantics.
    """
    ts = eval_points(son, points)
    N = len(son)
    present, attrs = replay.state_at_many(son, ts)
    if getattr(f, "vectorized", False):
        out = f(present=present, attrs=attrs, son=son, t=ts)
        return ts, np.asarray(out, np.float64).reshape(N, len(ts))
    out = np.empty((N, len(ts)), np.float64)
    for j, t in enumerate(ts):
        pj, aj = present[:, j], attrs[:, j]
        for i in range(N):
            out[i, j] = f(present=pj[i], attrs=aj[i], son=son, i=i, t=int(t))
    return ts, out


def node_compute_delta(son: SoN, f: Callable, f_delta: Callable,
                       points=None) -> Tuple[np.ndarray, np.ndarray]:
    """Incremental evaluation (paper operator 6): f once on the initial
    state, then f_delta(aux, value, event) -> (aux, value) folded over
    each node's events — O(N + T).

    Returns (points, values (N, T)) sampled at the same points as the
    temporal variant (value carried forward between events).

    When BOTH ``f.vectorized`` and ``f_delta.vectorized`` are set the
    fold is batched: f returns ``(aux, values (N,))`` for the whole set,
    and f_delta is called once per inter-point window with the window's
    event arrays (``node`` row indices, ``kind``, ``key``, ``val_``,
    ``other``) — T vectorized steps instead of N·E Python iterations.
    """
    ts = eval_points(son, points)
    N = len(son)
    out = np.empty((N, len(ts)), np.float64)
    if getattr(f, "vectorized", False) and getattr(f_delta, "vectorized", False):
        aux, val = f(present=son.init_present, attrs=son.init_attrs,
                     son=son, init=True)
        val = np.asarray(val, np.float64).copy()
        order = np.argsort(ts, kind="stable")
        tss = ts[order]
        bkt = np.searchsorted(tss, son.ev_t, side="left")
        node_of_ev = son.node_of_events()
        for pj in range(len(tss)):
            w = np.nonzero(bkt == pj)[0]  # CSR order within the window
            if len(w):
                aux, val = f_delta(
                    aux, val, node=node_of_ev[w], kind=son.ev_kind[w],
                    key=son.ev_key[w], val_=son.ev_val[w],
                    other=son.ev_other[w], son=son,
                )
                val = np.asarray(val, np.float64)
            out[:, order[pj]] = val
        return ts, out
    for i in range(N):
        aux, val = f(present=son.init_present[i], attrs=son.init_attrs[i],
                     son=son, i=i, init=True)
        evs = son.events_of(i)
        ne = len(evs["t"])
        j = 0  # event cursor
        for pj, t in enumerate(ts):
            while j < ne and evs["t"][j] <= t:
                aux, val = f_delta(
                    aux, val,
                    kind=evs["kind"][j], key=evs["key"][j],
                    val_=evs["val"][j], other=evs["other"][j], i=i, son=son,
                )
                j += 1
            out[i, pj] = val
    return ts, out


# ---------------------------------------------------------------------------
# 7-9. Compare / Evolution / TempAggregation
# ---------------------------------------------------------------------------


def compare(son_a: SoN, son_b: SoN, f: Callable, points=None):
    """Scalar f over both operands; returns (node_ids, difference) for the
    common ids (paper operator 7)."""
    common = np.intersect1d(son_a.node_ids, son_b.node_ids)
    ia = np.searchsorted(son_a.node_ids, common)
    ib = np.searchsorted(son_b.node_ids, common)
    va = node_compute(son_a, f)
    vb = node_compute(son_b, f)
    return common, va[ia] - vb[ib]


def compare_timeslices(son: SoN, f: Callable, t_a: int, t_b: int):
    """The paper's single-operand variant: compare f at two timepoints
    (both states come from one batched replay)."""
    present, attrs = replay.state_at_many(son, np.asarray([t_a, t_b], np.int64))
    pa, aa = present[:, 0], attrs[:, 0]
    pb, ab = present[:, 1], attrs[:, 1]
    va = np.asarray([f(present=pa[i], attrs=aa[i], son=son, i=i, t=t_a)
                     for i in range(len(son))])
    vb = np.asarray([f(present=pb[i], attrs=ab[i], son=son, i=i, t=t_b)
                     for i in range(len(son))])
    return son.node_ids, va - vb


def evolution(son: SoN, f: Callable, points=None, n_samples: int = 10):
    """Aggregate quantity f(son, t) sampled over time (paper operator 8).
    Default points: n_samples uniform over [t0, t1].  With
    ``f.vectorized`` set, f is called once with the whole (T,) points
    array and must return the (T,) series (one shared replay pass)."""
    if points is None:
        points = np.linspace(son.t0, son.t1, n_samples).astype(np.int64)
    else:
        points = eval_points(son, points)
    if getattr(f, "vectorized", False):
        return points, np.asarray(f(son, np.asarray(points, np.int64)))
    return points, np.asarray([f(son, int(t)) for t in points])


def temp_aggregate(series: np.ndarray, op: str, t: Optional[np.ndarray] = None):
    """Max/Min/Mean/Peak/Saturate over a scalar timeseries (operator 9)."""
    series = np.asarray(series, np.float64)
    if op == "max":
        return float(series.max())
    if op == "min":
        return float(series.min())
    if op == "mean":
        return float(series.mean())
    if op == "peak":
        # indices of strict local maxima (eventful timepoints)
        if len(series) < 3:
            return np.empty(0, np.int64)
        mid = (series[1:-1] > series[:-2]) & (series[1:-1] > series[2:])
        idx = np.nonzero(mid)[0] + 1
        return (t[idx] if t is not None else idx)
    if op == "saturate":
        final = series[-1]
        if final == 0:
            return t[0] if t is not None else 0
        # sign-aware band around the final value: |s - final| within 5%
        # of |final|.  (The old ``series >= 0.95 * final`` test inverted
        # for negative-valued series — e.g. difference series from
        # ``compare`` — where -0.1 >= 0.95 * -1.0 holds at t=0.)
        reached = np.nonzero(np.abs(series - final) <= 0.05 * abs(final))[0]
        i = int(reached[0]) if len(reached) else len(series) - 1
        return t[i] if t is not None else i
    raise ValueError(op)
