"""Unified query surface: ``HistoricalGraphStore`` + lazy ``TemporalQuery``.

One object wraps the whole stack (DeltaStore -> TGI -> TAF) and one
builder expresses every workload:

    store = HistoricalGraphStore.build(events, n_shards=4)
    ts, deg = (store.nodes(t0, t1)
                    .filter(lambda s: s.init_attrs[:, 0] == 0)
                    .node_compute(f, style="delta", f_delta=f_d)
                    .execute())

Nothing runs until ``execute()``: the chain compiles to a ``Plan``
(repro.taf.plan) whose Fetch stage carries the pushdowns — a node-set
``filter`` prunes the partitions read from storage, ``project`` drops
attribute tiles — so unneeded shards and columns are never pulled.  The
fetch cost of the last executed plan is on ``store.last_cost``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Optional, Tuple

import numpy as np

from repro.core.events import EventLog
from repro.core.tgi import TGI, TGIConfig, FetchCost
from repro.storage.kvstore import DeltaStore
from repro.taf.plan import (
    Aggregate,
    Compute,
    Evolution,
    Fetch,
    Materialize,
    Plan,
    PlanExecutor,
    PlanResult,
    Select,
    Slice,
)
from repro.taf.son import SoN, SoTS


def _compile_cache_stats() -> dict:
    from repro.taf import compile as taf_compile  # deferred

    return taf_compile.cache_stats()


class HistoricalGraphStore:
    """Facade over DeltaStore + TGI + TAF.

    Construction:  ``build(events, ...)`` indexes an event history into a
    fresh (or supplied) DeltaStore; ``from_tgi(tgi)`` wraps an existing
    index.  Retrieval primitives (Algorithms 1-5) pass through; temporal
    analytics start from ``nodes()`` / ``subgraphs()`` which return lazy
    TemporalQuery builders.
    """

    def __init__(self, tgi: TGI):
        self.tgi = tgi
        self.last_cost = FetchCost()  # cost of the last executed plan

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------

    @classmethod
    def build(cls, events: EventLog, cfg: Optional[TGIConfig] = None,
              store: Optional[DeltaStore] = None,
              **cfg_kw) -> "HistoricalGraphStore":
        if cfg is None:
            cfg = TGIConfig(**cfg_kw)
        elif cfg_kw:  # kwargs override fields of the supplied config
            cfg = dataclasses.replace(cfg, **cfg_kw)
        store = store or DeltaStore(m=cfg.n_shards, r=1, backend="mem")
        return cls(TGI.build(events, cfg, store))

    @classmethod
    def from_tgi(cls, tgi: TGI) -> "HistoricalGraphStore":
        return cls(tgi)

    @property
    def cfg(self) -> TGIConfig:
        return self.tgi.cfg

    @property
    def store(self) -> DeltaStore:
        return self.tgi.store

    def update(self, new_events: EventLog) -> None:
        """Append a batch of new events to the index (synchronous: every
        event is sealed into spans before this returns)."""
        self.tgi.update(new_events)

    def append(self, new_events: EventLog) -> None:
        """Streaming ingest: buffer events, sealing spans as thresholds
        are crossed (``events_per_span`` / ``cfg.span_seal_time``).
        Queries issued mid-stream stay correct — reads past the sealed
        history overlay the buffer's live events."""
        self.tgi.append(new_events)

    def flush(self) -> None:
        """Seal every buffered (appended) event into spans."""
        self.tgi.flush()

    def compact(self, min_run: int = 2, wait: bool = True):
        """Merge runs of adjacent micro-spans accreted by small
        update/append batches and GC the superseded store keys.  Runs on
        the background maintenance thread; queries and ingest keep
        serving concurrently (readers pin their epoch, the new layout
        lands in one atomic publish).  With ``wait=True`` (default)
        blocks and returns ``CompactionStats`` — the fetch cost of
        compaction's own reads lands on ``last_cost`` (its write/delete
        I/O is in the stats' byte counters); with ``wait=False`` returns
        a ``concurrent.futures.Future`` of the stats immediately."""
        out = self.tgi.compact(min_run=min_run, wait=wait)
        if wait:
            self.last_cost = out.cost
        return out

    def read_guard(self):
        """Pin the current read epoch for a block of multiple reads (see
        ``TGI.read_guard``): every query inside observes one immutable
        layout, regardless of concurrent ingest or compaction."""
        return self.tgi.read_guard()

    def time_range(self) -> Tuple[int, int]:
        return self.tgi.time_range()

    def index_size_bytes(self) -> int:
        return self.tgi.index_size_bytes()

    def storage_report(self) -> dict:
        """Index size by component (eventlists / hierarchy / aux
        replicas), raw vs. encoded — see ``TGI.storage_report``."""
        return self.tgi.storage_report()

    # ------------------------------------------------------------------
    # Retrieval primitives (paper Algorithms 1-5)
    # ------------------------------------------------------------------

    def snapshot(self, t: int, c: int = 1, **kw):
        with self.tgi.cost_scope() as acc:
            g = self.tgi.get_snapshot(t, c=c, **kw)
        self.last_cost = acc
        return g

    def snapshots(self, ts, c: int = 1, **kw):
        """Batched Algorithm 1: snapshots at every t in ``ts``, sharing
        the hierarchy-path and eventlist fetches per (span, checkpoint)
        group (see ``TGI.get_snapshots``)."""
        with self.tgi.cost_scope() as acc:
            gs = self.tgi.get_snapshots(ts, c=c, **kw)
        self.last_cost = acc
        return gs

    def node_history(self, nid: int, t0: int, t1: int, c: int = 1):
        # cost_scope: these retrievals issue several get_* calls, each of
        # which resets tgi.last_cost — the scope totals the whole query
        with self.tgi.cost_scope() as acc:
            out = self.tgi.get_node_history(nid, t0, t1, c=c)
        self.last_cost = acc
        return out

    def k_hop(self, nid: int, t: int, k: int, c: int = 1, method: str = "auto"):
        """Algorithms 3/4.  ``method="auto"`` is cost-based: it compares
        the physical raw bytes a full-snapshot fetch vs an expanding
        partition fetch would decode (real stored sizes, discounted by
        decoded-block-pool residency) — see ``explain_k_hop``."""
        with self.tgi.cost_scope() as acc:
            g = self.tgi.get_k_hop(nid, t, k, c=c, method=method)
        self.last_cost = acc
        return g

    def explain_k_hop(self, nid: int, t: int, k: int) -> dict:
        """The byte estimates behind ``k_hop(method="auto")``."""
        return self.tgi.explain_k_hop(nid, t, k)

    def cache_stats(self) -> dict:
        """Caching-layers overview (see docs/api.md): the snapshot LRU
        (whole reconstructed snapshots), the plan-layer fetch cache
        (operands shared across plans), the executor's replay cache
        (timeslices of one operand), and the storage-layer decoded-block
        pool (columns shared across everything above)."""
        return {
            "snapshot_lru_entries": len(self.tgi._snap_cache),
            "fetch_cache_entries": len(PlanExecutor._fetch_cache),
            "replay_cache_entries": len(PlanExecutor._replay_cache),
            "block_pool": self.store.pool_stats(),
            # replica-level resilience counters (nonzero only when a
            # storage node was down or unreachable during reads)
            "failovers": self.store.stats.failovers,
            "hedged_reads": self.store.stats.hedged_reads,
            # wire-transport view: mux in-flight depth + pipelined/
            # serial round-trip counters ({} for local backends)
            "transport": self.store.transport_stats(),
            "plan_compile": _compile_cache_stats(),
            # MVCC observability: the published epoch, who's pinned
            # below it, and how many superseded keys await GC
            "read_epoch": self.tgi.read_epoch,
            "pinned_epochs": self.tgi.pinned_epochs(),
            "gc_pending_keys": self.store.gc_pending(),
        }

    def node_1hop_history(self, nid: int, t0: int, t1: int, c: int = 1):
        with self.tgi.cost_scope() as acc:
            out = self.tgi.get_node_1hop_history(nid, t0, t1, c=c)
        self.last_cost = acc
        return out

    # ------------------------------------------------------------------
    # Lazy query surface
    # ------------------------------------------------------------------

    def nodes(self, t0: int, t1: int, c: int = 1) -> "TemporalQuery":
        """Lazy SoN query over the interval [t0, t1)."""
        return TemporalQuery(store=self, t0=t0, t1=t1, c=c)

    def subgraphs(self, t0: int, t1: int, c: int = 1) -> "TemporalQuery":
        """Lazy SoTS query (1-hop star subgraphs) — ``nodes().khop(1)``."""
        return self.nodes(t0, t1, c=c).khop(1)

    # ------------------------------------------------------------------
    # Analytics conveniences (the paper's worked examples)
    # ------------------------------------------------------------------

    def max_lcc(self, t0: int, t1: int, t: Optional[int] = None):
        from repro.taf import analytics

        sots = self.subgraphs(t0, t1).materialize().operand
        return analytics.max_lcc(sots, t)

    def density_evolution(self, t0: int, t1: int, n_samples: int = 10):
        from repro.taf import analytics

        sots = self.subgraphs(t0, t1).materialize().operand
        return analytics.density_evolution(sots, n_samples=n_samples)

    def pagerank_over_time(self, t0: int, t1: int, points, **kw):
        from repro.taf import analytics

        sots = self.subgraphs(t0, t1).materialize().operand
        return analytics.pagerank_over_time(sots, points, **kw)


@dataclasses.dataclass(frozen=True)
class TemporalQuery:
    """Lazy, composable temporal query.

    Built from ``store.nodes()/subgraphs()`` (fetched at execute time,
    with pushdown) or ``TemporalQuery.over(operand)`` (already-fetched
    SoN/SoTS).  Builder methods return new queries; ``plan()`` compiles
    the chain; ``execute()`` runs it and returns the value; ``run()``
    additionally returns fetch cost + operand (PlanResult).
    """

    store: Optional[HistoricalGraphStore] = None
    t0: int = 0
    t1: int = 0
    c: int = 1
    subgraph: bool = False
    node_ids: Optional[Tuple[int, ...]] = None  # pushdown selection
    projection: Optional[Tuple[str, ...]] = None  # pushdown projection
    operand: Optional[SoN] = None  # materialized source (no fetch)
    stages: Tuple[Any, ...] = ()  # post-source stages

    # ------------------------------------------------------------------
    # Sources
    # ------------------------------------------------------------------

    @classmethod
    def over(cls, operand: SoN) -> "TemporalQuery":
        """Query over an in-memory operand (zero fetch cost)."""
        return cls(operand=operand, t0=operand.t0, t1=operand.t1,
                   subgraph=isinstance(operand, SoTS))

    # ------------------------------------------------------------------
    # Builder methods (each returns a new query)
    # ------------------------------------------------------------------

    def _with(self, **kw) -> "TemporalQuery":
        return dataclasses.replace(self, **kw)

    def _append(self, stage) -> "TemporalQuery":
        return self._with(stages=self.stages + (stage,))

    def filter(self, pred: Optional[Callable[[SoN], np.ndarray]] = None, *,
               node_ids: Optional[Iterable[int]] = None,
               label: str = "λ") -> "TemporalQuery":
        """Selection (operator 1).  ``pred`` is a vectorized callable
        son -> bool mask; ``node_ids`` is a structured node-set predicate
        that the compiler pushes down into the fetch (partition pruning),
        so unneeded shards are never read."""
        q = self
        if node_ids is not None:
            ids = tuple(int(i) for i in np.asarray(list(node_ids)).ravel())
            if q.operand is not None or q.stages:
                # too late to push below the fetch — apply as a Select
                arr = np.asarray(ids, np.int32)
                q = q._append(Select(
                    lambda s, _a=arr: np.isin(s.node_ids, _a),
                    label=f"node_ids({len(ids)})"))
            else:
                merged = ids if q.node_ids is None else tuple(
                    sorted(set(q.node_ids) & set(ids)))
                q = q._with(node_ids=merged)
        if pred is not None:
            q = q._append(Select(pred, label=label))
        return q

    def khop(self, k: int = 1) -> "TemporalQuery":
        """Expand the operand to k-hop star subgraphs (SoTS).  Must come
        before any timeslice/compute — adjacency is part of the fetch."""
        if k != 1:
            raise ValueError("k-hop SoTS composes 1-hop stars (paper §5.1)")
        if self.operand is not None:
            if not isinstance(self.operand, SoTS):
                raise ValueError("operand-backed query cannot add adjacency; "
                                 "fetch with subgraphs()/build_sots instead")
            return self
        if any(s.kind != "select" for s in self.stages):
            raise ValueError("khop() must precede timeslice/compute stages")
        return self._with(subgraph=True)

    def project(self, attrs: bool = True) -> "TemporalQuery":
        """Attribute projection pushdown: ``project(attrs=False)`` skips
        the attrs tiles at fetch time (init_attrs will read as unset)."""
        proj = ("attrs",) if attrs else ()
        return self._with(projection=proj)

    def timeslice(self, ts) -> "TemporalQuery":
        """Operator 2.  Standalone it yields the sliced state dict; before
        a node_compute it pins the compute's evaluation point(s)."""
        return self._append(Slice(ts))

    def node_compute(self, fn: Callable, style: str = "static",
                     f_delta: Optional[Callable] = None, points=None,
                     t: Optional[int] = None, mesh=None,
                     label: Optional[str] = None) -> "TemporalQuery":
        """Operators 4-6 (style = static | temporal | delta) or a device
        kernel under shard_map (style = kernel)."""
        return self._append(Compute(fn=fn, style=style, f_delta=f_delta,
                                    points=points, t=t, mesh=mesh, label=label))

    def evolution(self, fn: Callable, points=None,
                  n_samples: int = 10) -> "TemporalQuery":
        """Operator 8: scalar fn(son, t) sampled over time."""
        return self._append(Evolution(fn=fn, points=points, n_samples=n_samples))

    def aggregate(self, op: str) -> "TemporalQuery":
        """Operator 9 over the preceding stage's series."""
        return self._append(Aggregate(op))

    # ------------------------------------------------------------------
    # Compile & run
    # ------------------------------------------------------------------

    def plan(self) -> Plan:
        """Compile the chain into a validated Plan.  Pushdowns (node-set
        selection, projection) are already on the source; a Slice that
        only pins evaluation points is fused into the following Compute."""
        if self.operand is not None:
            source: Any = Materialize(self.operand)
        else:
            source = Fetch(t0=self.t0, t1=self.t1, subgraph=self.subgraph,
                           node_ids=self.node_ids, projection=self.projection,
                           c=self.c)
        stages = [source]
        pending = list(self.stages)
        i = 0
        while i < len(pending):
            s = pending[i]
            nxt = pending[i + 1] if i + 1 < len(pending) else None
            if (s.kind == "slice" and nxt is not None and nxt.kind == "compute"
                    and nxt.points is None and nxt.t is None):
                # fuse: the slice's timepoint(s) become the compute's
                # evaluation points (one pass instead of two)
                ts = np.atleast_1d(np.asarray(s.ts)).astype(np.int64)
                if nxt.style == "kernel":
                    raise ValueError(
                        "timeslice cannot pin evaluation points for a "
                        'style="kernel" compute; bake t into the kernel')
                if nxt.style == "static":
                    if ts.size != 1:
                        raise ValueError(
                            "timeslice with multiple points needs "
                            'style="temporal" or "delta", not "static"')
                    fused = dataclasses.replace(nxt, t=int(ts[0]))
                else:
                    fused = dataclasses.replace(nxt, points=ts)
                stages.append(fused)
                i += 2
                continue
            stages.append(s)
            i += 1
        return Plan(tuple(stages)).validate()

    def explain(self) -> str:
        return self.plan().describe()

    def run(self) -> PlanResult:
        """Compile + execute; returns PlanResult (value, cost, operand)."""
        tgi = self.store.tgi if self.store is not None else None
        result = PlanExecutor(tgi).run(self.plan())
        if self.store is not None:
            self.store.last_cost = result.cost
        return result

    def execute(self) -> Any:
        """Compile + execute; returns the result value."""
        return self.run().value

    def materialize(self) -> "TemporalQuery":
        """Execute the fetch/select prefix now and return a query over the
        materialized operand — reuse one fetch across many computes."""
        n_prefix = 0
        for s in self.stages:
            if s.kind != "select":
                break
            n_prefix += 1
        prefix = self._with(stages=self.stages[:n_prefix])
        result = prefix.run()
        return dataclasses.replace(
            TemporalQuery.over(result.operand),
            stages=self.stages[n_prefix:], store=self.store)
