"""SoN / SoTS operands (paper §5.1, Def. 6-7).

A temporal node is stored exactly as the paper prescribes for NodeT: the
*initial snapshot* of the node at t0 followed by its *chronologically
sorted events* in (t0, t1] — CSR over the node set, with padded dense
views for vectorized/TPU execution (the SoA answer to Spark's
RDD<NodeT>).  SoTS adds the initial 1-hop adjacency, making SubgraphT a
star-subgraph sequence (k-hop via composition, as in the paper).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.events import (
    EDGE_ADD,
    EDGE_DEL,
    EATTR_SET,
    NATTR_SET,
    NODE_ADD,
    NODE_DEL,
    EventLog,
)
from repro.core.snapshot import GraphState


def _field_dict(x) -> Dict:
    """Declared dataclass fields only (``vars()`` would also leak lazily
    cached attributes like ``_node_of_ev`` into constructor kwargs)."""
    return {f.name: getattr(x, f.name) for f in dataclasses.fields(x)}


@dataclasses.dataclass
class SoN:
    """Set of Temporal Nodes over [t0, t1)."""

    node_ids: np.ndarray  # (N,) int32
    t0: int
    t1: int
    init_present: np.ndarray  # (N,) int8 — state at t0
    init_attrs: np.ndarray  # (N, K) int32
    ev_indptr: np.ndarray  # (N+1,) int64 — per-node event runs
    ev_t: np.ndarray
    ev_kind: np.ndarray
    ev_key: np.ndarray
    ev_val: np.ndarray
    ev_other: np.ndarray  # edge partner (-1 for node events)

    def __len__(self):
        return len(self.node_ids)

    def n_events(self, i: int) -> int:
        return int(self.ev_indptr[i + 1] - self.ev_indptr[i])

    def events_of(self, i: int):
        lo, hi = int(self.ev_indptr[i]), int(self.ev_indptr[i + 1])
        return {
            "t": self.ev_t[lo:hi], "kind": self.ev_kind[lo:hi],
            "key": self.ev_key[lo:hi], "val": self.ev_val[lo:hi],
            "other": self.ev_other[lo:hi],
        }

    def change_points(self) -> np.ndarray:
        """All distinct event times in the set (default evaluation points
        of the temporal operators)."""
        return np.unique(self.ev_t)

    def node_of_events(self) -> np.ndarray:
        """Row index (into this SoN) of every CSR event — the inverse of
        ``ev_indptr``.  Cached: the replay engine asks repeatedly."""
        cached = getattr(self, "_node_of_ev", None)
        if cached is None or len(cached) != len(self.ev_t):
            cached = np.repeat(
                np.arange(len(self), dtype=np.int64),
                self.ev_indptr[1:] - self.ev_indptr[:-1],
            )
            self._node_of_ev = cached
        return cached

    def subset(self, idx: np.ndarray) -> "SoN":
        idx = np.asarray(idx)
        counts = (self.ev_indptr[1:] - self.ev_indptr[:-1])[idx]
        indptr = np.r_[0, np.cumsum(counts)]
        take = np.concatenate([
            np.arange(self.ev_indptr[i], self.ev_indptr[i + 1]) for i in idx
        ]) if len(idx) else np.empty(0, np.int64)
        take = take.astype(np.int64)
        return SoN(
            node_ids=self.node_ids[idx], t0=self.t0, t1=self.t1,
            init_present=self.init_present[idx], init_attrs=self.init_attrs[idx],
            ev_indptr=indptr, ev_t=self.ev_t[take], ev_kind=self.ev_kind[take],
            ev_key=self.ev_key[take], ev_val=self.ev_val[take],
            ev_other=self.ev_other[take],
        )

    def padded_events(self, emax: Optional[int] = None):
        """Dense (N, Emax) views (pad t = +inf sentinel) for vmap paths."""
        counts = self.ev_indptr[1:] - self.ev_indptr[:-1]
        emax = emax or (int(counts.max()) if len(counts) else 0)
        emax = max(emax, 1)
        N = len(self)
        t = np.full((N, emax), np.iinfo(np.int64).max, np.int64)
        kind = np.full((N, emax), -1, np.int8)
        key = np.full((N, emax), -1, np.int16)
        val = np.full((N, emax), -1, np.int32)
        other = np.full((N, emax), -1, np.int32)
        for i in range(N):
            lo, hi = int(self.ev_indptr[i]), int(self.ev_indptr[i + 1])
            n = min(hi - lo, emax)
            t[i, :n] = self.ev_t[lo : lo + n]
            kind[i, :n] = self.ev_kind[lo : lo + n]
            key[i, :n] = self.ev_key[lo : lo + n]
            val[i, :n] = self.ev_val[lo : lo + n]
            other[i, :n] = self.ev_other[lo : lo + n]
        return {"t": t, "kind": kind, "key": key, "val": val, "other": other}


@dataclasses.dataclass
class SoTS(SoN):
    """Set of Temporal Subgraphs (1-hop stars; k-hop by composition)."""

    adj_indptr: np.ndarray = None  # (N+1,) initial neighbors at t0
    adj_nbr: np.ndarray = None
    adj_val: np.ndarray = None

    def neighbors_of(self, i: int):
        lo, hi = int(self.adj_indptr[i]), int(self.adj_indptr[i + 1])
        return self.adj_nbr[lo:hi], self.adj_val[lo:hi]

    def subset(self, idx: np.ndarray) -> "SoTS":
        idx = np.asarray(idx)
        base = SoN.subset(self, idx)
        counts = (self.adj_indptr[1:] - self.adj_indptr[:-1])[idx]
        indptr = np.r_[0, np.cumsum(counts)].astype(np.int64)
        take = np.concatenate([
            np.arange(self.adj_indptr[i], self.adj_indptr[i + 1]) for i in idx
        ]).astype(np.int64) if len(idx) else np.empty(0, np.int64)
        return SoTS(
            **_field_dict(base),
            adj_indptr=indptr,
            adj_nbr=self.adj_nbr[take],
            adj_val=self.adj_val[take],
        )


# ---------------------------------------------------------------------------
# Construction from TGI (the paper's parallel-fetch path, §5.2)
# ---------------------------------------------------------------------------


def _per_node_events(events: EventLog, node_ids: np.ndarray):
    """CSR of events per node (an event touching both endpoints appears in
    both nodes' runs, mirroring NodeT semantics)."""
    nid = np.concatenate([events.src, events.dst[events.dst >= 0]])
    rep_idx = np.concatenate([
        np.arange(len(events)), np.nonzero(events.dst >= 0)[0]
    ])
    other = np.concatenate([
        np.where(events.dst >= 0, events.dst, -1),
        events.src[events.dst >= 0],
    ])
    sel = np.isin(nid, node_ids)
    nid, rep_idx, other = nid[sel], rep_idx[sel], other[sel]
    order = np.lexsort((events.t[rep_idx], nid))
    nid, rep_idx, other = nid[order], rep_idx[order], other[order]
    # map nid -> dense index
    pos = np.searchsorted(node_ids, nid)
    indptr = np.searchsorted(pos, np.arange(len(node_ids) + 1))
    return (
        indptr.astype(np.int64),
        events.t[rep_idx],
        events.kind[rep_idx],
        events.key[rep_idx],
        events.val[rep_idx],
        other.astype(np.int32),
    )


def build_son(tgi, t0: int, t1: int, node_ids: Optional[np.ndarray] = None,
              c: int = 1, pids: Optional[np.ndarray] = None,
              projection=None, snap: Optional[GraphState] = None) -> SoN:
    """Fetch a SoN from the TGI: Timeslice-at-t0 snapshot + event runs.

    The snapshot fetch is partition-parallel (paper Fig. 10): each QP
    reads only its placement chunks; `c` is the parallel fetch factor.
    ``pids``/``projection`` are the planner's pushdown hooks: a partition
    subset known to cover ``node_ids`` (pruned fetch) and the optional
    payload fields actually needed (attribute projection).  ``snap`` lets
    a caller that already fetched the t0 snapshot (build_sots) reuse it.

    The whole build runs under one ``tgi.read_guard()``: the t0 snapshot
    and the (t0, t1] event replay come from the same pinned epoch, so a
    concurrent ingest or background compaction can't tear the operand.
    """
    with tgi.read_guard() as view:
        if snap is None:
            snap = tgi.get_snapshot(t0, c=c, pids=pids, projection=projection)
        if node_ids is None:
            node_ids = snap.node_ids()
        node_ids = np.unique(np.asarray(node_ids, np.int32))
        ev = view.events
        sel = (ev.t > t0) & (ev.t <= t1)
        ev = ev.take(np.nonzero(sel)[0])
        indptr, t, kind, key, val, other = _per_node_events(ev, node_ids)
        snap.grow(int(node_ids.max()) + 1 if len(node_ids) else 0)
        return SoN(
            node_ids=node_ids, t0=t0, t1=t1,
            init_present=snap.present[node_ids],
            init_attrs=snap.attrs[node_ids],
            ev_indptr=indptr, ev_t=t, ev_kind=kind, ev_key=key, ev_val=val,
            ev_other=other,
        )


def build_sots(tgi, t0: int, t1: int, node_ids: Optional[np.ndarray] = None,
               k: int = 1, c: int = 1, pids: Optional[np.ndarray] = None,
               projection=None) -> SoTS:
    """SoTS = SoN + initial 1-hop adjacency (k>1 composes neighborhoods).

    Pruned fetches stay exact: snapshot deltas mirror every edge under
    both endpoints' slots, so a partition subset covering the member
    nodes carries their complete initial adjacency.
    """
    assert k == 1, "k-hop SoTS composes 1-hop stars (paper §5.1)"
    # one guard around snapshot + SoN build: nested guards reuse the
    # outer pinned epoch, so the adjacency and the event runs agree
    with tgi.read_guard():
        snap = tgi.get_snapshot(t0, c=c, pids=pids, projection=projection)
        if node_ids is None:
            node_ids = snap.node_ids()
        son = build_son(tgi, t0, t1, node_ids, c=c, pids=pids,
                        projection=projection, snap=snap)
    src, dst, val = snap.edges()
    # adjacency restricted to son.node_ids as center
    both_src = np.concatenate([src, dst])
    both_dst = np.concatenate([dst, src])
    both_val = np.concatenate([val, val])
    sel = np.isin(both_src, son.node_ids)
    bs, bd, bv = both_src[sel], both_dst[sel], both_val[sel]
    order = np.lexsort((bd, bs))
    bs, bd, bv = bs[order], bd[order], bv[order]
    pos = np.searchsorted(son.node_ids, bs)
    indptr = np.searchsorted(pos, np.arange(len(son.node_ids) + 1)).astype(np.int64)
    return SoTS(
        **_field_dict(son),
        adj_indptr=indptr, adj_nbr=bd.astype(np.int32), adj_val=bv.astype(np.int32),
    )
