"""Network-science analytics over SoN/SoTS — the paper's worked examples:
highest local clustering coefficient (Fig. 7a), community comparison
(7b), network-density evolution (7c), incremental label counting (Fig. 8),
plus degree series and PageRank-over-time.

These are thin shims over the unified query layer: each series function
builds a ``TemporalQuery`` over its operand and executes the compiled
plan (repro.taf.query is the preferred surface for new code).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.events import EDGE_ADD, EDGE_DEL, NATTR_SET
from repro.core.snapshot import GraphState
from repro.taf import operators as ops
from repro.taf import replay
from repro.taf.query import TemporalQuery
from repro.taf.son import SoN, SoTS


# ---------------------------------------------------------------------------
# Local clustering coefficient (paper Fig. 7a)
# ---------------------------------------------------------------------------


def local_clustering(g: GraphState) -> Dict[int, float]:
    """LCC per present node of an in-memory GraphS."""
    src, dst, _ = g.edges()
    nbrs: Dict[int, set] = {}
    for u, v in zip(src.tolist(), dst.tolist()):
        nbrs.setdefault(u, set()).add(v)
        nbrs.setdefault(v, set()).add(u)
    out = {}
    for u in np.nonzero(g.present)[0].tolist():
        ns = nbrs.get(u, set())
        k = len(ns)
        if k < 2:
            out[u] = 0.0
            continue
        links = 0
        ns_l = list(ns)
        for i in range(k):
            links += len(nbrs.get(ns_l[i], set()) & ns)
        out[u] = links / (k * (k - 1))
    return out


def max_lcc(sots: SoTS, t: Optional[int] = None) -> Tuple[int, float]:
    """Paper Fig. 7a: node with the highest LCC at a timeslice."""
    g = ops.graph(sots, t)
    lcc = local_clustering(g)
    if not lcc:
        return -1, 0.0
    nid = max(lcc, key=lcc.get)
    return int(nid), float(lcc[nid])


# ---------------------------------------------------------------------------
# Density evolution (paper Fig. 7c)
# ---------------------------------------------------------------------------


def density_evolution(sots: SoTS, n_samples: int = 10):
    def density(son, ts):
        # vectorized over timepoints: all graphs from one replay pass
        out = np.empty(len(ts), np.float64)
        for j, g in enumerate(ops.graph_at_many(sots, ts)):
            n = int(g.present.sum())
            e = len(g.edge_key)
            out[j] = 0.0 if n < 2 else 2.0 * e / (n * (n - 1))
        return out

    density.vectorized = True
    return TemporalQuery.over(sots).evolution(density, n_samples=n_samples).execute()


# ---------------------------------------------------------------------------
# Degree series — both evaluation styles (the Fig. 17 benchmark pair)
# ---------------------------------------------------------------------------


def degree_series_temporal(sots: SoTS, points=None):
    """Per-version recompute (Fig. 17's temporal curve), fully batched:
    one ``state_at_many`` pass for presence + one ``EdgeReplay`` pass for
    all neighbor-set sizes — no per-(node, t) Python."""

    def f(present, attrs, son, t, **kw):
        ts = np.atleast_1d(np.asarray(t, np.int64))
        deg = replay.degree_series(sots, ts).astype(np.float64)
        return np.where(present.reshape(len(sots), len(ts)) == 1, deg, 0.0)

    f.vectorized = True
    return (TemporalQuery.over(sots)
            .node_compute(f, style="temporal", points=points, label="degree")
            .execute())


def degree_series_delta(sots: SoTS, points=None):
    """Incremental evaluation (Fig. 17's delta curve) on the vectorized
    window fold: init degrees once, then one array update per
    inter-point window."""

    def f(present, attrs, son, init, **kw):
        deg = (son.adj_indptr[1:] - son.adj_indptr[:-1]).astype(np.float64)
        return None, np.where(present == 1, deg, 0.0)

    def f_delta(aux, val, node, kind, key, val_, other, son, **kw):
        np.add.at(val, node[kind == EDGE_ADD], 1.0)
        np.add.at(val, node[kind == EDGE_DEL], -1.0)
        return aux, val

    f.vectorized = True
    f_delta.vectorized = True
    return (TemporalQuery.over(sots)
            .node_compute(f, style="delta", f_delta=f_delta, points=points,
                          label="degree")
            .execute())


# ---------------------------------------------------------------------------
# Label counting in neighborhoods (paper Fig. 8) — temporal vs delta
# ---------------------------------------------------------------------------


def label_count_temporal(sots: SoTS, label: int, attr_key: int = 0, points=None):
    """Count neighbors carrying `label` at every version — O(N·T)."""
    label_of = _label_lookup(sots, attr_key)

    def f(present, attrs, son, i, t):
        if not present:
            return 0.0
        nbrs = ops.neighbors_at(sots, i, t)
        return float(sum(1 for v in nbrs if label_of(int(v), t) == label))

    return (TemporalQuery.over(sots)
            .node_compute(f, style="temporal", points=points,
                          label=f"label_count({label})")
            .execute())


def label_count_delta(sots: SoTS, label: int, attr_key: int = 0, points=None):
    """Incremental variant: auxiliary state = current neighbor set; each
    edge event adjusts the count in O(1) (paper Fig. 8b)."""
    label_of = _label_lookup(sots, attr_key)

    def f(present, attrs, son, i, init):
        nbrs, _ = sots.neighbors_of(i)
        cnt = float(sum(1 for v in nbrs if label_of(int(v), sots.t0) == label))
        return set(int(v) for v in nbrs), cnt

    def f_delta(aux, val, kind, key, val_, other, i, son):
        if kind == EDGE_ADD and int(other) not in aux:
            aux.add(int(other))
            if label_of(int(other), None) == label:
                val += 1.0
        elif kind == EDGE_DEL and int(other) in aux:
            aux.discard(int(other))
            if label_of(int(other), None) == label:
                val -= 1.0
        return aux, val

    return (TemporalQuery.over(sots)
            .node_compute(f, style="delta", f_delta=f_delta, points=points,
                          label=f"label_count({label})")
            .execute())


def _label_lookup(sots: SoTS, attr_key: int):
    """label_of(nid, t): node label; labels in our streams are written
    once at node birth, so the t argument may be None (delta path)."""
    ids = sots.node_ids
    init = dict(zip(ids.tolist(), sots.init_attrs[:, attr_key].tolist()))
    # fold NATTR events (first write wins = birth label)
    for i in range(len(sots)):
        evs = sots.events_of(i)
        for j in range(len(evs["t"])):
            if evs["kind"][j] == NATTR_SET and evs["key"][j] == attr_key:
                nid = int(ids[i])
                if init.get(nid, -1) == -1:
                    init[nid] = int(evs["val"][j])
                break

    def label_of(nid: int, t):
        return init.get(nid, -1)

    return label_of


# ---------------------------------------------------------------------------
# PageRank over time (warm-started power iteration per timeslice)
# ---------------------------------------------------------------------------


def pagerank_over_time(sots: SoTS, points, damping: float = 0.85,
                       iters: int = 30, warm_start: bool = True):
    """PageRank at each timepoint; warm-starting from the previous
    timeslice's ranks cuts iterations on slowly-changing graphs (the
    incremental-computation theme of §5.2 applied to a global metric)."""
    ranks = None
    out = []
    iters_used = []
    # state extraction for ALL timepoints rides one batched replay pass
    graphs = ops.graph_at_many(sots, np.asarray(list(points), np.int64))
    for g in graphs:
        nids = np.nonzero(g.present)[0]
        n = len(nids)
        if n == 0:
            out.append({})
            iters_used.append(0)
            continue
        pos = {int(v): i for i, v in enumerate(nids)}
        src, dst, _ = g.edges()
        r = np.full(n, 1.0 / n)
        if warm_start and ranks:
            for v, i in pos.items():
                if v in ranks:
                    r[i] = ranks[v]
            r /= r.sum()
        deg = np.zeros(n)
        su = np.array([pos[int(u)] for u in src], int) if len(src) else np.empty(0, int)
        dv = np.array([pos[int(v)] for v in dst], int) if len(dst) else np.empty(0, int)
        np.add.at(deg, su, 1)
        np.add.at(deg, dv, 1)
        used = iters
        for it in range(iters):
            contrib = np.where(deg > 0, r / np.maximum(deg, 1), 0.0)
            nxt = np.zeros(n)
            np.add.at(nxt, dv, contrib[su])
            np.add.at(nxt, su, contrib[dv])
            dangling = r[deg == 0].sum()
            nxt = (1 - damping) / n + damping * (nxt + dangling / n)
            if np.abs(nxt - r).sum() < 1e-10:
                used = it + 1
                r = nxt
                break
            r = nxt
        iters_used.append(used)
        ranks = {int(v): float(r[i]) for v, i in pos.items()}
        out.append(ranks)
    return out, iters_used
