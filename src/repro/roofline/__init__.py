from repro.roofline.hlo_analysis import parse_collectives, summarize_collectives
from repro.roofline.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    compute_roofline,
    model_flops,
)

__all__ = [
    "parse_collectives",
    "summarize_collectives",
    "Roofline",
    "compute_roofline",
    "model_flops",
    "PEAK_FLOPS",
    "HBM_BW",
    "ICI_BW",
]
