"""Collective-traffic extraction from compiled SPMD HLO text.

``cost_analysis()`` does not expose collective bytes, so we parse the
per-device HLO module: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op contributes its *operand* bytes
(derived from the printed result type and the replica-group size), and a
ring-model "wire bytes" estimate:

    all-reduce        2 (n-1)/n * operand
    all-gather        (n-1)/n   * result        (result = n * operand)
    reduce-scatter    (n-1)/n   * operand       (operand = n * result)
    all-to-all        (n-1)/n   * operand
    collective-permute  1.0     * operand

Async pairs (`-start` / `-done`) are counted once, at the start op.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    operand_bytes: int
    wire_bytes: float
    group_size: int
    line: str
    computation: str = "ENTRY"
    multiplier: float = 1.0


# ---------------------------------------------------------------------------
# Computation-multiplier analysis: XLA prints each while body ONCE, but it
# executes trip-count times.  We reconstruct per-computation execution
# multiplicity so collective traffic inside lax.scan bodies is weighted
# correctly (compute costs use the unrolled probe instead — see dryrun).
# ---------------------------------------------------------------------------

_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\),.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(r"true_computation=%?([\w.\-]+),\s*false_computation=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    entry_name = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line)
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry_name = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    consts = [int(c) for l in cond_lines for c in _CONST_RE.findall(l)]
    return max(consts) if consts else 1


def computation_multipliers(hlo_text: str) -> Dict[str, float]:
    comps = split_computations(hlo_text)
    entry = comps.get("__entry__")
    if entry is None:
        return {}
    # name of entry computation
    entry_names = [k for k, v in comps.items() if v is entry and k != "__entry__"]
    mult: Dict[str, float] = {n: 1.0 for n in entry_names}
    work = list(entry_names)
    seen = set()
    while work:
        name = work.pop()
        if name in seen:
            continue
        seen.add(name)
        m = mult.get(name, 1.0)
        for line in comps.get(name, ()):
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                for child, cm in ((cond, m * (trips + 1)), (body, m * trips)):
                    if cm > mult.get(child, 0.0):
                        mult[child] = cm
                        seen.discard(child)
                    work.append(child)
                continue
            for cm_name in _CALLS_RE.findall(line):
                if cm_name in comps and m > mult.get(cm_name, 0.0):
                    mult[cm_name] = m
                    seen.discard(cm_name)
                    work.append(cm_name)
            bm = _BRANCHES_RE.search(line)
            names = []
            if bm:
                names = [s.strip().lstrip("%") for s in bm.group(1).split(",")]
            tf = _TF_RE.search(line)
            if tf:
                names += [tf.group(1), tf.group(2)]
            for child in names:
                if child in comps and m > mult.get(child, 0.0):
                    mult[child] = m
                    seen.discard(child)
                    work.append(child)
    return mult


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collectives(hlo_text: str, weighted: bool = True) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    mult = computation_multipliers(hlo_text) if weighted else {}
    cur_comp = "ENTRY"
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr:
            cur_comp = hdr.group(2)
            continue
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        result_type, opname = m.group(1), m.group(2)
        kind = None
        for c in COLLECTIVES:
            if opname == c or opname == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        if opname.endswith("-done"):
            continue
        n = max(_group_size(ls), 1)
        rb = _tensor_bytes(result_type)
        if opname.endswith("-start") and result_type.startswith("("):
            # tuple (operand_alias, destination, ...): use the largest
            parts = [p for p in re.findall(r"\w+\[[\d,]*\]", result_type)]
            rb = max((_tensor_bytes(p) for p in parts), default=rb)
        if kind == "all-gather":
            operand = rb // n if n else rb
            wire = rb * (n - 1) / max(n, 1)
        elif kind == "reduce-scatter":
            operand = rb * n
            wire = operand * (n - 1) / max(n, 1)
        elif kind == "all-reduce":
            operand = rb
            wire = 2.0 * rb * (n - 1) / max(n, 1)
        elif kind == "all-to-all":
            operand = rb
            wire = rb * (n - 1) / max(n, 1)
        else:  # collective-permute
            operand = rb
            wire = float(rb)
        w = mult.get(cur_comp, 1.0) if weighted else 1.0
        ops.append(CollectiveOp(kind, rb, operand, wire, n, ls[:160], cur_comp, w))
    return ops


def summarize_collectives(hlo_text: str, weighted: bool = True) -> Dict:
    """Collective traffic summary; with weighted=True each op's bytes are
    multiplied by its computation's execution count (while trip counts)."""
    ops = parse_collectives(hlo_text, weighted=weighted)
    by_kind: Dict[str, Dict] = defaultdict(lambda: {"count": 0, "operand_bytes": 0, "wire_bytes": 0.0})
    cross_slow = 0.0  # groups of size 2 on the pod axis, or spanning >256
    for op in ops:
        d = by_kind[op.kind]
        d["count"] += op.multiplier
        d["operand_bytes"] += op.operand_bytes * op.multiplier
        d["wire_bytes"] += op.wire_bytes * op.multiplier
        if op.group_size in (2, 512) or op.group_size > 256:
            cross_slow += op.wire_bytes * op.multiplier
    total_operand = sum(d["operand_bytes"] for d in by_kind.values())
    total_wire = sum(d["wire_bytes"] for d in by_kind.values())
    return {
        "by_kind": dict(by_kind),
        "n_ops": len(ops),
        "operand_bytes": total_operand,
        "wire_bytes": total_wire,
        "cross_pod_wire_bytes": cross_slow,
    }


def count_remat_duplication(hlo_text: str) -> Dict[str, int]:
    """Rough remat indicator: count fusion/dot ops (duplicated op names
    signal recompute inserted by checkpointing)."""
    dots = len(re.findall(r"=\s*\S+\s+dot\(", hlo_text))
    fusions = len(re.findall(r"=\s*\S+\s+fusion\(", hlo_text))
    return {"dot_ops": dots, "fusion_ops": fusions}
