"""Three-term roofline from a compiled dry-run artifact.

Convention (stated once, used everywhere): ``cost_analysis()`` on the
compiled SPMD executable reports the PER-DEVICE program, so each term is
per-device time and the chips-denominator in the task formulas is already
applied.  MODEL_FLOPS is the textbook useful work (6·N·D train,
2·N·D forward) divided by chip count for comparability.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_dev: float
    hlo_flops_per_dev: float
    useful_ratio: float
    step_time_s: float  # max of the three (no-overlap bound)
    mfu: float  # model_flops / (step_time * PEAK)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def compute_roofline(
    cost: Dict,
    collective_wire_bytes: float,
    model_flops_total: float,
    n_chips: int,
) -> Roofline:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = collective_wire_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    model_dev = model_flops_total / max(n_chips, 1)
    step = max(compute_s, memory_s, collective_s)
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_per_dev=model_dev,
        hlo_flops_per_dev=flops_dev,
        useful_ratio=(model_dev / flops_dev) if flops_dev else 0.0,
        step_time_s=step,
        mfu=(model_dev / (step * PEAK_FLOPS)) if step else 0.0,
    )


def model_flops(kind: str, n_active_params: int, tokens: int) -> float:
    """6ND for train (fwd+bwd), 2ND for forward-only (prefill/decode)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens
