"""Analytic (napkin-math) FLOP model — independent cross-check of the
dry-run cost probe, and the source of corrections the probe cannot see
(the sLSTM per-timestep scan, whose while body XLA cost analysis counts
once).

Counting convention: 1 MAC = 2 FLOPs; matmul terms only (norms/gates/
rope are O(BSD) noise at these widths).  Forward counts; the caller
applies the train multiplier (3x for fwd+bwd, 4x for the scanned part
under full remat).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig
from repro.models.common import padded_vocab


def _attn_layer_flops(cfg, B, S, Sk_eff, enc_S=0) -> float:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    f = 2 * B * S * D * hd * (H + 2 * KV)  # qkv
    f += 4 * B * H * S * Sk_eff * hd  # scores + pv
    f += 2 * B * S * D * H * hd  # out proj
    if enc_S:  # cross attention (whisper decoder)
        f += 2 * B * S * D * hd * H + 2 * B * enc_S * D * hd * 2 * KV
        f += 4 * B * H * S * enc_S * hd
        f += 2 * B * S * D * H * hd
    return f


def _ffn_flops(cfg, B, S) -> float:
    D, F = cfg.d_model, cfg.d_ff
    if F == 0:
        return 0.0
    if cfg.is_moe:
        slots = B * S * cfg.top_k * cfg.capacity_factor
        return 2 * B * S * D * cfg.n_experts + 3 * 2 * slots * D * F
    n_mat = 3 if cfg.mlp_kind == "swiglu" else 2
    return n_mat * 2 * B * S * D * F


def _rec_layer_flops(cfg, B, S) -> float:
    D, W, H = cfg.d_model, cfg.resolved_rnn_width, cfg.n_heads
    f = 4 * B * S * D * W  # w_x + w_gate
    f += 2 * cfg.conv_width * B * S * W
    f += 2 * 2 * B * S * W * (W // H)  # block-diag gates
    f += 10 * B * S * W  # scan elementwise
    f += 2 * B * S * W * D  # out
    return f


def _mlstm_layer_flops(cfg, B, S) -> float:
    D = cfg.d_model
    F = 2 * D
    H = cfg.n_heads
    L = min(cfg.mlstm_chunk, S)
    f = 2 * B * S * D * 2 * F  # up
    f += 6 * B * S * F * F  # q,k,v projections (F -> F)
    f += 6 * B * S * L * F  # intra-chunk qk/pv/n
    f += 6 * B * S * F * F / H  # inter + state outer products
    f += 2 * B * S * F * D  # down
    return f


def _slstm_layer_flops(cfg, B, S) -> float:
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    Fs = ((4 * D // 3) + 127) // 128 * 128
    f = 2 * B * S * D * 4 * D  # input projections
    f += 2 * B * S * 4 * D * dh  # recurrent block-diag (the scan part)
    f += 6 * B * S * D * Fs  # gated FFN
    return f


def slstm_scan_correction(cfg, B, S) -> float:
    """The part of the sLSTM that lives inside the per-timestep while body
    (invisible to the cost probe): recurrent matmul + cell update."""
    if "slstm" not in cfg.resolved_pattern:
        return 0.0
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    n_slstm = sum(
        1
        for i in range(cfg.n_layers)
        if cfg.resolved_pattern[i % cfg.unit_len] == "slstm"
    )
    per_layer = 2 * B * S * 4 * D * dh + 30 * B * S * D
    return n_slstm * per_layer


def forward_flops(cfg: ModelConfig, B: int, S: int, Sk_eff: int = 0,
                  decode_cache: int = 0) -> Dict[str, float]:
    """Returns {'stem': ..., 'layers': ...} forward FLOPs.

    decode_cache > 0 => single-token decode against a cache of that size
    (S should be 1)."""
    Vp = padded_vocab(cfg.vocab_size)
    D = cfg.d_model
    Sk = decode_cache if decode_cache else (Sk_eff or S)
    if cfg.attn_kind in ("swa", "local") and cfg.window:
        Sk = min(Sk, cfg.window if decode_cache else S)
    stem = 2 * B * S * D * Vp  # logits
    layers = 0.0
    pattern = cfg.resolved_pattern
    for i in range(cfg.n_layers):
        kind = pattern[i % cfg.unit_len]
        if kind == "attn":
            layers += _attn_layer_flops(cfg, B, S, Sk, cfg.enc_seq if cfg.is_encdec else 0)
            layers += _ffn_flops(cfg, B, S)
        elif kind == "rec":
            layers += _rec_layer_flops(cfg, B, S)
            layers += _ffn_flops(cfg, B, S)
        elif kind == "mlstm":
            layers += _mlstm_layer_flops(cfg, B, S)
        elif kind == "slstm":
            layers += _slstm_layer_flops(cfg, B, S)
    if cfg.is_encdec:
        enc_cfg = cfg
        for _ in range(cfg.n_enc_layers):
            layers += _attn_layer_flops(enc_cfg, B, cfg.enc_seq, cfg.enc_seq)
            layers += _ffn_flops(enc_cfg, B, cfg.enc_seq)
    return {"stem": stem, "layers": layers}


def param_counts(cfg: ModelConfig) -> Dict[str, float]:
    """Analytic parameter counts: {'stem': embed(+head), 'layers': rest}."""
    Vp = padded_vocab(cfg.vocab_size)
    D, H, KV, hd, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                       cfg.resolved_head_dim, cfg.d_ff)
    stem = Vp * D * (1 if cfg.tie_embeddings else 2)
    if cfg.pos_kind == "learned":
        stem += 0  # shape-dependent; negligible vs embed

    def ffn_p():
        if F == 0:
            return 0
        if cfg.is_moe:
            return cfg.n_experts * 3 * D * F + D * cfg.n_experts
        return (3 if cfg.mlp_kind == "swiglu" else 2) * D * F

    W = cfg.resolved_rnn_width
    Fm = 2 * D
    dh = D // H
    Fs = ((4 * D // 3) + 127) // 128 * 128
    per = {
        "attn": D * hd * (H + 2 * KV) + H * hd * D + ffn_p(),
        "rec": 2 * D * W + cfg.conv_width * W + 2 * (W // H) * W + W * D
        + (0 if F == 0 else (3 if cfg.mlp_kind == "swiglu" else 2) * D * F),
        "mlstm": D * 2 * Fm + cfg.conv_width * Fm + 3 * Fm * Fm + 2 * Fm * H + Fm * D,
        "slstm": 4 * D * D + 4 * H * dh * dh + 3 * D * Fs,
    }
    layers = sum(per[cfg.resolved_pattern[i % cfg.unit_len]] for i in range(cfg.n_layers))
    if cfg.is_encdec:
        layers += cfg.n_enc_layers * (D * hd * (H + 2 * KV) + H * hd * D
                                      + (3 if cfg.mlp_kind == "swiglu" else 2) * D * F)
        layers += cfg.n_layers * (D * hd * (H + 2 * KV) + H * hd * D)  # cross attn
    return {"stem": stem, "layers": layers}


def step_bytes(cfg: ModelConfig, kind: str, B: int, S: int,
               dp: int = 16, tp: int = 16, chips: int = 256,
               fsdp: bool = True) -> Dict[str, float]:
    """Modeled per-device HBM traffic (bytes/step).

    Assumptions (documented in EXPERIMENTS.md §Roofline): TPU fusion keeps
    intra-layer temporaries in VMEM except the itemized majors; FSDP
    all-gathers materialize full bf16 weights per device per pass (3
    passes under full remat: fwd, remat-fwd, bwd); optimizer state is f32
    and fully sharded; the layer-scan carry is saved per unit.
    """
    P = param_counts(cfg)
    D = cfg.d_model
    Vp = padded_vocab(cfg.vocab_size)
    dp_total = max(chips // tp, 1)  # data-parallel degree incl. pod axis
    B_loc = max(B // dp_total, 1)
    D_loc = max(D // tp, 1)
    H_hd = cfg.n_heads * cfg.resolved_head_dim
    items: Dict[str, float] = {}
    if kind == "train":
        passes = 3 if cfg.remat == "full" else 2
        w_bf16 = 2 * (P["layers"] + P["stem"] / tp)
        items["weights"] = 2 * passes * w_bf16 if fsdp else 2 * passes * w_bf16 / dp
        # read p,m,v,g (4x4B) + write p,m,v (3x4B) + grad reduce-scatter r/w (~8B)
        items["optimizer"] = 36.0 * (P["layers"] + P["stem"]) / chips
        items["carry"] = 3 * 2 * B_loc * S * D_loc * 2  # save + bwd read + remat read
        per_layer_act = (4 * B_loc * S * H_hd / tp + 3 * B_loc * S * max(cfg.d_ff, 2 * D) / tp
                         + 2 * B_loc * S * D_loc) * 2
        items["layer_acts"] = passes * per_layer_act * cfg.n_layers
        items["logits"] = 4 * B_loc * S * (Vp / tp) * 4
    elif kind == "prefill":
        w_bf16 = 2 * (P["layers"] + P["stem"] / tp)
        items["weights"] = 2 * w_bf16 if fsdp else 2 * w_bf16 / dp
        per_layer_act = (4 * B_loc * S * H_hd / tp + 3 * B_loc * S * max(cfg.d_ff, 2 * D) / tp
                         + 2 * B_loc * S * D_loc) * 2
        items["layer_acts"] = per_layer_act * cfg.n_layers
        items["cache_write"] = 0.0  # counted in layer_acts kv terms
        items["logits"] = B_loc * 1 * (Vp / tp) * 4
    else:  # decode
        w_bf16 = 2 * (P["layers"] + P["stem"] / tp)
        items["weights"] = 2 * w_bf16 if fsdp else 2 * w_bf16 / dp
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.resolved_pattern[i % cfg.unit_len] == "attn")
        sc = min(cfg.window, S) if (cfg.attn_kind in ("swa", "local") and cfg.window) else S
        from repro.models.sharding import n_kv_virtual

        kvv = n_kv_virtual(cfg.n_heads, cfg.n_kv_heads, tp)
        cache_dev = 2 * B * sc * max(kvv // tp, 1) * cfg.resolved_head_dim * 2 * n_attn / dp
        items["cache_read"] = cache_dev
        items["logits"] = B_loc * (Vp / tp) * 4
    items["total"] = sum(items.values())
    return items


def step_flops(cfg: ModelConfig, kind: str, B: int, S: int) -> float:
    """Total per-step FLOPs for a cell (train includes bwd + remat)."""
    if kind == "train":
        f = forward_flops(cfg, B, S)
        layer_mult = 4.0 if cfg.remat == "full" else 3.0
        return 3.0 * f["stem"] + layer_mult * f["layers"]
    if kind == "prefill":
        f = forward_flops(cfg, B, S)
        return f["stem"] + f["layers"]
    # decode
    f = forward_flops(cfg, B, 1, decode_cache=S)
    return f["stem"] + f["layers"]
