"""Local service-plane cluster: N storage cells x r replication.

The launch harness for tests, benches, and docs.  Two modes:

* ``mode="subprocess"`` — each cell is a real OS process (``python -m
  repro.service.cell``), so kills are real crashes (SIGKILL: no
  goodbye, no flush) and restart exercises feed catch-up across
  process boundaries.  This is what the ``service`` bench and the
  chaos tests run.
* ``mode="thread"`` — cells run in-process on daemon threads: same
  wire protocol over loopback sockets, ~instant startup.  This is what
  the docs quickstart runs.

Cells keep their port across restarts (``SO_REUSEADDR``), so a
client's address table stays valid through a kill/restart cycle.  A
restarted cell is handed every other live cell as a catch-up peer; its
``feed_since`` pull filters to the keys whose replica chain includes
it (see ``StorageCell.catch_up``).
"""
from __future__ import annotations

import dataclasses
import os
import select
import subprocess
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.service.cell import StorageCell
from repro.service.client import RemoteDeltaStore


@dataclasses.dataclass
class ClusterSpec:
    n_cells: int = 3
    r: int = 2
    backend: str = "file"
    root: Optional[str] = None  # required for the file backend
    fmt: Optional[str] = None
    host: str = "127.0.0.1"
    # per-node environment overrides for subprocess cells (e.g. arm a
    # fault point in ONE cell: {1: {"REPRO_FAULTPOINTS": "cell.apply=
    # 5:kill"}}); merged over the inherited environment at spawn AND
    # respawn, so a restarted cell comes back with the same overrides
    cell_env: Optional[Dict[int, Dict[str, str]]] = None
    # cell serving knobs (see StorageCell): request-executor pool size,
    # per-connection in-flight cap, and the feed-records threshold that
    # arms ack-watermark truncation
    workers: int = 4
    inflight_cap: int = 32
    feed_keep: int = 256
    # writer-lease TTL (cells sweep expired lanes at ttl/2; clients
    # renew at ttl/3) and the optional shared wire-auth secret — both
    # flow to every cell AND to client() so the cluster stays coherent
    lease_ttl: float = 2.0
    auth_key: Optional[str] = None

    def cell_root(self, node: int) -> Optional[str]:
        if self.backend == "mem":
            return None
        return str(Path(self.root) / f"cell{node}")


class LocalCluster:
    def __init__(self, spec: ClusterSpec, mode: str = "subprocess"):
        assert mode in ("subprocess", "thread")
        assert spec.backend == "mem" or spec.root is not None
        self.spec = spec
        self.mode = mode
        self.ports: List[int] = [0] * spec.n_cells
        self._procs: List[Optional[subprocess.Popen]] = [None] * spec.n_cells
        self._cells: List[Optional[StorageCell]] = [None] * spec.n_cells

    # ---- lifecycle ----
    def start(self) -> "LocalCluster":
        for i in range(self.spec.n_cells):
            self._spawn(i, peers=[])
        return self

    def stop(self) -> None:
        for i in range(self.spec.n_cells):
            self._down(i, hard=False)

    def __enter__(self) -> "LocalCluster":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def addrs(self) -> List[Tuple[str, int]]:
        return [(self.spec.host, p) for p in self.ports]

    def client(self, **kw) -> RemoteDeltaStore:
        kw.setdefault("r", self.spec.r)
        kw.setdefault("fmt", self.spec.fmt)
        kw.setdefault("lease_ttl", self.spec.lease_ttl)
        kw.setdefault("auth_key", self.spec.auth_key)
        return RemoteDeltaStore(self.addrs, **kw)

    def kill(self, node: int) -> None:
        """Crash one cell (subprocess mode: SIGKILL — no flush, no
        goodbye; thread mode: sockets closed)."""
        self._down(node, hard=True)

    def restart(self, node: int) -> None:
        """Bring a killed cell back on its old port, with every other
        live cell as a catch-up peer."""
        peers = [(self.spec.host, p) for i, p in enumerate(self.ports)
                 if i != node and self._alive(i)]
        self._spawn(node, peers=peers, port=self.ports[node])

    def wipe(self, node: int) -> None:
        """Erase a (downed) cell's on-disk state — feed, checkpoint,
        chunks — simulating a disk loss.  On restart the fresh cell
        must bootstrap via full-state transfer from its peers."""
        assert not self._alive(node), "wipe requires the cell to be down"
        root = self.spec.cell_root(node)
        if root is None:
            return
        import shutil
        shutil.rmtree(root, ignore_errors=True)

    def _alive(self, node: int) -> bool:
        if self.mode == "thread":
            return self._cells[node] is not None
        p = self._procs[node]
        return p is not None and p.poll() is None

    # ---- internals ----
    def _down(self, node: int, hard: bool) -> None:
        if self.mode == "thread":
            cell = self._cells[node]
            if cell is not None:
                cell.stop()
                self._cells[node] = None
            return
        proc = self._procs[node]
        if proc is None or proc.poll() is not None:
            self._procs[node] = None
            return
        if hard:
            proc.kill()
        else:
            proc.terminate()
        proc.wait(timeout=10)
        self._procs[node] = None

    def _spawn(self, node: int, peers: List[Tuple[str, int]],
               port: int = 0) -> None:
        spec = self.spec
        if self.mode == "thread":
            cell = StorageCell(node_id=node, n_cells=spec.n_cells, r=spec.r,
                               backend=spec.backend,
                               root=spec.cell_root(node), fmt=spec.fmt,
                               host=spec.host, port=port,
                               workers=spec.workers,
                               inflight_cap=spec.inflight_cap,
                               feed_keep=spec.feed_keep,
                               lease_ttl=spec.lease_ttl,
                               auth_key=spec.auth_key)
            self.ports[node] = cell.start(peers=peers)
            self._cells[node] = cell
            return
        import repro  # namespace package: locate its src/ parent
        src = str(Path(next(iter(repro.__path__))).parent)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                     if p])
        if spec.cell_env and node in spec.cell_env:
            env.update(spec.cell_env[node])
        cmd = [sys.executable, "-m", "repro.service.cell",
               "--node-id", str(node), "--n-cells", str(spec.n_cells),
               "--replication", str(spec.r), "--backend", spec.backend,
               "--host", spec.host, "--port", str(port),
               "--workers", str(spec.workers),
               "--inflight-cap", str(spec.inflight_cap),
               "--feed-keep", str(spec.feed_keep),
               "--lease-ttl", str(spec.lease_ttl)]
        if spec.auth_key:
            cmd += ["--auth-key", spec.auth_key]
        if spec.backend == "file":
            cmd += ["--root", spec.cell_root(node)]
        if spec.fmt:
            cmd += ["--fmt", spec.fmt]
        if peers:
            cmd += ["--peers", ",".join(f"{h}:{p}" for h, p in peers)]
        proc = subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                                stderr=subprocess.DEVNULL, text=True)
        self._procs[node] = proc
        self.ports[node] = self._wait_ready(proc, node)

    @staticmethod
    def _wait_ready(proc: subprocess.Popen, node: int,
                    timeout: float = 30.0) -> int:
        """Parse the cell's ``CELL READY node=<i> port=<p>`` line —
        printed only after boot catch-up completed and the listen
        socket is bound."""
        deadline = time.monotonic() + timeout
        line = ""
        while time.monotonic() < deadline:
            if proc.poll() is not None:
                raise RuntimeError(
                    f"cell {node} exited rc={proc.returncode} before READY")
            rd, _, _ = select.select([proc.stdout], [], [], 0.25)
            if not rd:
                continue
            line = proc.stdout.readline()
            if line.startswith("CELL READY"):
                return int(line.strip().rsplit("port=", 1)[1])
        raise TimeoutError(f"cell {node} not READY within {timeout}s "
                           f"(last line: {line!r})")


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    import tempfile

    ap = argparse.ArgumentParser(
        description="Launch a local temporal-graph storage cluster.")
    ap.add_argument("--cells", type=int, default=3)
    ap.add_argument("--replication", type=int, default=2)
    ap.add_argument("--backend", default="file", choices=("mem", "file"))
    ap.add_argument("--root", default=None)
    ap.add_argument("--mode", default="subprocess",
                    choices=("subprocess", "thread"))
    args = ap.parse_args(argv)
    root = args.root or (tempfile.mkdtemp(prefix="tg-cluster-")
                         if args.backend == "file" else None)
    spec = ClusterSpec(n_cells=args.cells, r=args.replication,
                       backend=args.backend, root=root)
    cluster = LocalCluster(spec, mode=args.mode).start()
    print(f"cluster up: {args.cells} cells x r={args.replication} "
          f"({args.backend}) root={root}")
    for i, (host, port) in enumerate(cluster.addrs):
        print(f"  cell {i}: {host}:{port}")
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        pass
    cluster.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
