"""Temporal graph service plane: ``DeltaStore`` promoted to a served
system.  A ``StorageCell`` owns one storage node's chunk/extent files
and serves them over a length-prefixed binary wire protocol
(``wire``); ``RemoteDeltaStore`` is a drop-in ``DeltaStore`` whose
nodes are cells reached over sockets — TGI, the PlanExecutor fetch
stage, and the decoded-block pool run unchanged on top of it.  An
append-only change feed per cell (``feed_since``) drives replica
catch-up after a crash.  ``LocalCluster`` spins up N cells x r
replicas in threads or subprocesses for tests, benches, and docs."""
from repro.service.cell import FeedTruncated, StorageCell
from repro.service.client import RemoteDeltaStore
from repro.service.cluster import ClusterSpec, LocalCluster

__all__ = ["StorageCell", "RemoteDeltaStore", "ClusterSpec", "LocalCluster",
           "FeedTruncated"]
