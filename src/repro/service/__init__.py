"""Temporal graph service plane: ``DeltaStore`` promoted to a served
system.  A ``StorageCell`` owns one storage node's chunk/extent files
and serves them over a length-prefixed binary wire protocol
(``wire``); ``RemoteDeltaStore`` is a drop-in ``DeltaStore`` whose
nodes are cells reached over sockets — TGI, the PlanExecutor fetch
stage, and the decoded-block pool run unchanged on top of it.  An
append-only change feed per cell (``feed_since``) drives replica
catch-up after a crash.  Writers are lease-fenced: each holds a
time-bounded lease under a monotonic fencing epoch, stale-epoch writes
are rejected with the typed ``LeaseFenced``, dead writers' lanes are
sealed by orphan-seq reconciliation, and a writer that loses its cell
quorum degrades to read-only (``WriteUnavailable``) until it returns.
``LocalCluster`` spins up N cells x r replicas in threads or
subprocesses for tests, benches, and docs."""
from repro.service.cell import FeedTruncated, StorageCell
from repro.service.client import Backoff, RemoteDeltaStore
from repro.service.cluster import ClusterSpec, LocalCluster
from repro.service.wire import AuthFailed, LeaseFenced
from repro.storage.kvstore import WriteUnavailable

__all__ = ["StorageCell", "RemoteDeltaStore", "ClusterSpec", "LocalCluster",
           "FeedTruncated", "LeaseFenced", "AuthFailed", "WriteUnavailable",
           "Backoff"]
