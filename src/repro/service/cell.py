"""StorageCell: one storage node served over the wire protocol.

A cell owns one node's chunk/extent files through a private
single-node ``DeltaStore`` (m=1, r=1, no decoded-block pool — decoding
is the *client's* job; the cell ships encoded columns verbatim via
``get_encoded``/``assemble_block``, so a projected GET costs the cell
only the projected columns' file bytes).

Writes are change-feed records: every ``put``/``delete`` is stamped
with a *vseq* — the writer's fencing **epoch** and its lane-local
**seq** packed into one u64 (``kvstore.make_vseq``) — and fanned out
to the key's replica cells.  Each cell appends applied records to an
append-only ``feed.log`` (and an in-memory tail) — the cell's write
history in arrival order.  One epoch is one writer incarnation's
*lane*: seqs are monotone within a lane, and the u64 vseq order is the
cluster-wide (epoch, seq) total order, so N concurrent writers merge
deterministically — every per-key conflict resolves to the max vseq
whatever the arrival order, and a canonical vacuum pass
(``MAINT_CANON``) orders each chunk's live records by record key,
making the on-disk bytes a pure function of the applied record *set*.
Duplicate deliveries (client retries, catch-up racing a live write)
are dropped by vseq: every applied vseq — including those replayed
from ``feed.log`` at boot — lives in an applied set, so catch-up can
refetch the peer feed and repair interior gaps, not just the tail.  A
per-key max-vseq guard keeps an out-of-order repair from regressing a
key past a newer applied write.

**Writer leases and fencing.**  A writer attaches by acquiring a
time-bounded lease (``MSG_LEASE`` acquire, granted iff the proposed
epoch exceeds every epoch this cell has seen — monotonic fencing;
the *client* requires a cell quorum of grants).  Every accepted write
in lane ``e`` refreshes lane ``e``'s lease — the heartbeat is
piggybacked on the write path, so a busy writer never expires.  When a
lease expires un-renewed (hard-killed writer), the cell's lease
sweeper runs **orphan-seq reconciliation**: it queries every peer for
the lane's high-water mark (aborting if any peer still sees a live
lease, or any cell is unreachable — sealing is only safe when every
replica can be brought to the same record set), anti-entropies its own
gaps via a normal feed pull, then *seals* the lane at the max
replica-acked record and broadcasts the seal (``MSG_RECONCILE``).  A
sealed lane is the fence: a wire write into lane ``e`` above its seal
is rejected with the typed ``LEASE_FENCED`` error — never silently
applied — while writes at or below the seal remain accepted (they are
duplicates or gap fills, deduped as always).  Internal applies
(catch-up, boot replay) bypass the fence and merge the seal upward, so
an acked record that outlived every live replica still converges when
its holder restarts.

**Feed compaction (per-lane ack coverage).**  The feed no longer grows
without bound: each writer piggybacks its lane's *ack watermark* on
PUT/DELETE/PING bodies — the highest lane seq it can prove every
owning cell has applied.  A lane's *coverage* is that watermark or, for
a sealed lane, the seal point — which is exactly what un-strands the
floor after a writer dies with queued redeliveries: reconciliation
seals the lane, coverage jumps to the seal, truncation resumes.  Once
at least ``feed_keep`` in-memory records sit at or below their lane's
coverage (or a forced MAINT pass asks), the cell checkpoints: it
writes ``feed.base`` (per-lane floor/ack/seal maps + per-key
size/vseq accounting, sorted for byte determinism), rewrites
``feed.log`` with only the uncovered records in vseq order, and drops
the truncated vseqs from the applied set — ``seq <= floor[lane]``
itself now certifies "applied".  The base is written *before* the log
is rewritten, so a crash between the two leaves stale records the boot
path skips by floor.  A *fresh* cell (wiped disk) facing a truncated
peer bootstraps by full-state transfer — ``MSG_PLACEMENTS`` +
``MSG_STATE_PULL`` copy a live replica's chunk and extent files
verbatim plus the per-key accounting, then a normal feed pull stamps
the records above the floors.  A fresh *mem* cell cannot be rebuilt
this way and fails with the typed ``FeedTruncated``.

**Opt-in shared-secret auth.**  A cell started with ``auth_key``
answers HELLO with an ``MSG_AUTH`` challenge (random nonce); the
client must reply with ``HMAC-SHA256(key, nonce)`` before any other
frame is served — wrong or missing gets the typed ``AUTH_FAILED`` and
a closed connection.  Cell-to-cell traffic (catch-up, reconciliation)
performs the same handshake.

**Pipelined serving.**  The per-connection read loop no longer
executes requests inline: frames are dispatched to a small cell-wide
worker pool (``workers``) under a per-connection in-flight cap
(``inflight_cap``, enforced by semaphore — a flooding client blocks in
its own read loop, which is TCP backpressure, not memory growth), and
replies are written under a per-connection send lock in completion
order — the ``req_id`` is the demux key, not arrival order.  HELLO and
PING are answered *inline on the read loop*, so a slow GET can never
head-of-line-block a health probe even with every worker busy.
MULTIGET replies stream one ``MSG_CHUNK`` frame per found key followed
by ``MSG_END``, so the client decodes early keys while the cell is
still reading later ones.

Run one cell per process via ``python -m repro.service.cell`` (prints
``CELL READY node=<i> port=<p>`` for the cluster harness) or
in-process via ``LocalCluster(mode="thread")``.
"""
from __future__ import annotations

import argparse
import hashlib
import hmac
import json
import os
import signal
import socket
import struct
import sys
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.core import faultpoints
from repro.service import wire
from repro.storage.kvstore import (DeltaStore, KeyMissing, make_vseq,
                                   replica_nodes, split_vseq)

_BASE_MAGIC = b"TGB3"  # feed.base format tag (v3: per-lane maps)


class FeedTruncated(wire.WireError):
    """Needed feed history predates a peer's truncation floor and no
    full-state transfer can cover it (mem backend, or no file-backed
    replica reachable)."""


class StorageCell:
    def __init__(self, node_id: int, n_cells: int, r: int,
                 backend: str = "file", root: Optional[str] = None,
                 fmt: Optional[str] = None, host: str = "127.0.0.1",
                 port: int = 0, workers: int = 4, inflight_cap: int = 32,
                 feed_keep: int = 256, lease_ttl: float = 2.0,
                 auth_key: Optional[str] = None):
        assert backend in ("mem", "file")
        self.node_id = node_id
        self.n_cells = n_cells
        self.r = r
        self.host = host
        self.port = port  # 0 -> ephemeral; real port known after start()
        self.workers = max(1, workers)
        self.inflight_cap = max(1, inflight_cap)
        self.feed_keep = max(1, feed_keep)
        self.lease_ttl = max(0.05, lease_ttl)
        self.auth_key = auth_key.encode() if auth_key else None
        self.root = Path(root) if root is not None else None
        if backend == "file":
            assert root is not None
            self.root.mkdir(parents=True, exist_ok=True)
        self.store = DeltaStore(m=1, r=1, backend=backend, root=root,
                                fmt=fmt, pool_bytes=0, seek=True)
        # change feed: in-memory tail above the truncation floors plus an
        # append-only feed.log (file backend).  _flock serializes
        # apply+append so the log can never disagree with the store.
        self._feed: List[wire.FeedRecord] = []
        self._flock = threading.Lock()
        # every vseq this cell has applied ABOVE its lane's floor
        # (rebuilt from feed.log at boot) — together with ``seq <=
        # floor[lane]`` this is the dedupe that lets catch-up refetch
        # the peer feed and repair interior gaps without double-applying
        self._applied: set = set()
        # per-key max applied vseq: an out-of-order gap repair must
        # never regress a key past a newer applied write
        self._key_seq: Dict[Tuple, int] = {}
        self.last_seq = 0  # max vseq seen (any lane)
        # per-lane write-plane state, all keyed by epoch:
        self._floors: Dict[int, int] = {}    # truncated up to (per lane)
        self._lane_ack: Dict[int, int] = {}  # writer-proven replica ack
        self._sealed: Dict[int, int] = {}    # fenced lanes: seal point
        self._lane_seq: Dict[int, int] = {}  # local lane high-water mark
        # epoch -> [writer_id|None, monotonic deadline]; None writer_id
        # is a wildcard installed by a write whose acquire this cell
        # missed (it was down) — adopted by the first renew/acquire
        self.leases: Dict[int, list] = {}
        self.max_epoch = 0  # highest epoch ever seen (monotonic fence)
        self.known_peers: List[Tuple[str, int]] = []
        self.truncations = 0  # completed feed truncation passes
        self.lease_grants = 0
        self.fenced_writes = 0  # wire writes refused with LEASE_FENCED
        self.reconciles = 0  # lanes this cell sealed (swept or told)
        self._load_feed()
        self._lsock: Optional[socket.socket] = None
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._conns: set = set()
        self._pool: Optional[ThreadPoolExecutor] = None
        # background store maintenance (chunk vacuum): one pass at a
        # time, triggered by MSG_MAINT; the cell keeps serving while it
        # runs (vacuum holds the store lock per chunk only)
        self._maint_lock = threading.Lock()
        self._maint_thread: Optional[threading.Thread] = None
        self.last_vacuum: Optional[Dict] = None

    # ---- lane bookkeeping (caller holds _flock unless noted) ----
    def _note_epoch(self, epoch: int) -> None:
        if epoch > self.max_epoch:
            self.max_epoch = epoch

    def _coverage(self, epoch: int) -> int:
        """Highest lane seq proven replica-complete: the writer's acked
        watermark while the lane is live, the seal once it is fenced."""
        cov = self._lane_ack.get(epoch, 0)
        if epoch in self._sealed:
            cov = max(cov, self._sealed[epoch])
        return max(cov, self._floors.get(epoch, 0))

    def _lanes_known(self) -> set:
        return (set(self._floors) | set(self._lane_ack) | set(self._sealed)
                | set(self._lane_seq))

    # ---- feed persistence ----
    def _feed_path(self) -> Optional[Path]:
        return None if self.root is None else self.root / "feed.log"

    def _base_path(self) -> Optional[Path]:
        return None if self.root is None else self.root / "feed.base"

    def _load_base(self) -> None:
        """Load the truncation checkpoint (per-lane floor/ack/seal maps
        + per-key accounting) if one exists.  Everything at or below a
        lane's floor is certified applied; ``feed.log`` replay then
        layers the surviving tail on top."""
        path = self._base_path()
        if path is None or not path.exists():
            return
        buf = path.read_bytes()
        if not buf.startswith(_BASE_MAGIC):
            return  # older or torn checkpoint: rebuild from the log
        try:
            off = len(_BASE_MAGIC)
            floors, off = wire.unpack_lanes(buf, off)
            acks, off = wire.unpack_lanes(buf, off)
            seals, off = wire.unpack_lanes(buf, off)
            (n,) = struct.unpack_from("<I", buf, off)
            off += 4
            sizes = []
            for _ in range(n):
                key, off = wire.unpack_key(buf, off)
                raw, enc = struct.unpack_from("<QQ", buf, off)
                off += 16
                sizes.append((key, raw, enc))
            (n,) = struct.unpack_from("<I", buf, off)
            off += 4
            seqs = []
            for _ in range(n):
                key, off = wire.unpack_key(buf, off)
                (seq,) = struct.unpack_from("<Q", buf, off)
                off += 8
                seqs.append((key, seq))
        except (wire.WireError, struct.error, IndexError, UnicodeDecodeError):
            return  # torn checkpoint: fall back to whatever the log holds
        self._floors = floors
        self._lane_ack = acks
        self._sealed = seals
        for e, s in floors.items():
            self._note_epoch(e)
            self._lane_seq[e] = max(self._lane_seq.get(e, 0), s)
            self.last_seq = max(self.last_seq, make_vseq(e, s))
        for e in list(acks) + list(seals):
            self._note_epoch(e)
        for key, raw, enc in sizes:
            self.store.key_sizes[key] = (raw, enc)
        for key, seq in seqs:
            self._key_seq[key] = seq
            self.last_seq = max(self.last_seq, seq)
            e, s = split_vseq(seq)
            self._note_epoch(e)
            self._lane_seq[e] = max(self._lane_seq.get(e, 0), s)

    def _save_base_locked(self) -> None:
        """Checkpoint the current accounting under the current floors.
        Lane maps and keys are emitted in sorted order so the file bytes
        are a pure function of the state (the byte-identity property
        extends to the checkpoint).  Written tmp-then-rename, and always
        BEFORE the log rewrite, so a crash between the two only leaves
        stale log records the boot path drops by floor."""
        path = self._base_path()
        if path is None:
            return
        # a seal supersedes any ack water below it, so persist the ack
        # map normalized against the seals — a cell that missed some
        # piggybacked acks while dead still checkpoints the same bytes
        # as one that saw them all
        acks = dict(self._lane_ack)
        for e, seal in self._sealed.items():
            acks[e] = max(acks.get(e, 0), seal)
        out = [_BASE_MAGIC, wire.pack_lanes(self._floors),
               wire.pack_lanes(acks),
               wire.pack_lanes(self._sealed),
               struct.pack("<I", len(self.store.key_sizes))]
        for key in sorted(self.store.key_sizes,
                          key=lambda k: (k.tsid, k.sid, k.pid, k.did)):
            raw, enc = self.store.key_sizes[key]
            out.append(wire.pack_key(key) + struct.pack("<QQ", raw, enc))
        out.append(struct.pack("<I", len(self._key_seq)))
        for key in sorted(self._key_seq,
                          key=lambda k: (k.tsid, k.sid, k.pid, k.did)):
            out.append(wire.pack_key(key)
                       + struct.pack("<Q", self._key_seq[key]))
        tmp = path.with_suffix(".base.tmp")
        tmp.write_bytes(b"".join(out))
        os.replace(tmp, path)

    def _load_feed(self) -> None:
        """Boot: rebuild ``last_seq``, the applied-vseq set, the per-key
        vseq watermarks, the lane maps, and the store's size accounting
        from ``feed.base`` + ``feed.log``.  The chunk/extent files
        already hold the data (the store's file backend persists), so
        records are NOT re-applied — only the bookkeeping is replayed.

        The feed append in ``apply`` is not atomic and cells are killed
        with SIGKILL, so a torn last record is an expected crash
        artifact: any record that fails to decode is treated as the torn
        tail — the log is truncated back to the last whole record and
        catch-up refetches whatever the lost suffix held."""
        self._load_base()
        path = self._feed_path()
        if path is None or not path.exists():
            return
        data = path.read_bytes()
        off = 0
        good = 0  # byte offset of the last cleanly decoded record's end
        while off < len(data):
            try:
                rec, off = wire.FeedRecord.unpack(data, off)
            except (wire.WireError, struct.error, IndexError,
                    UnicodeDecodeError):
                with open(path, "r+b") as f:  # torn tail: drop it
                    f.truncate(good)
                break
            good = off
            e, s = split_vseq(rec.seq)
            if s <= self._floors.get(e, 0):
                # checkpoint written but crash hit before the log
                # rewrite: the record is already certified by the floor
                continue
            self._feed.append(rec)
            self._applied.add(rec.seq)
            self.last_seq = max(self.last_seq, rec.seq)
            self._note_epoch(e)
            self._lane_seq[e] = max(self._lane_seq.get(e, 0), s)
            if rec.seq > self._key_seq.get(rec.key, 0):
                self._key_seq[rec.key] = rec.seq
                if rec.op == wire.OP_PUT:
                    self.store.key_sizes[rec.key] = (rec.raw_bytes,
                                                     len(rec.blob))
                else:
                    self.store.key_sizes.pop(rec.key, None)

    def _owns(self, key) -> bool:
        return self.node_id in replica_nodes(key.tsid, key.sid,
                                             self.n_cells, self.r)

    # ---- write path ----
    def fence_check(self, vseq: int, writer_id: Optional[str] = None) -> None:
        """The wire-write gate: reject a write into a sealed lane above
        its seal point with the typed ``LeaseFenced`` (writes at or
        below the seal are duplicates or gap fills — ``apply`` dedupes
        them as always).  An accepted non-legacy write refreshes (or,
        for a cell that missed the acquire, installs) its lane's lease:
        the write IS the heartbeat.  Internal applies — catch-up, boot
        replay, reconciliation anti-entropy — never call this."""
        e, s = split_vseq(vseq)
        if e == 0:
            return  # legacy unleased lane: single-writer compatibility
        with self._flock:
            seal = self._sealed.get(e)
            if seal is not None and s > seal:
                self.fenced_writes += 1
                raise wire.LeaseFenced(
                    f"lane {e} sealed at {seal}, write seq {s} refused "
                    f"(stale writer: re-acquire a fresh epoch)")
            self._note_epoch(e)
            lease = self.leases.get(e)
            deadline = time.monotonic() + self.lease_ttl
            if lease is None:
                self.leases[e] = [writer_id, deadline]
            else:
                if lease[0] is None and writer_id is not None:
                    lease[0] = writer_id
                lease[1] = deadline

    def apply(self, rec: wire.FeedRecord) -> Tuple[bool, bool]:
        """Apply one feed record (a wire PUT/DELETE, a catch-up replay,
        or a client gap redelivery); returns ``(applied, existed)``.
        Duplicates — client retries after a lost ack, catch-up
        overlapping a live write — are detected against the applied-vseq
        set plus the per-lane truncation floor (both survive restarts
        via ``feed.base``/``feed.log``) and acked without touching the
        store, so a record can never double-append to the chunk files.
        A record at or below the key's newest applied write (an
        interior-gap repair arriving late, or a feed replay of a record
        whose effect arrived via full-state transfer) is stamped into
        the feed — the vseq is no longer a gap, and peers replicating
        this feed dedupe it the same way — but the store mutation is
        skipped so the key never regresses or double-applies.  A record
        landing above its lane's seal (an acked write that outlived
        every live replica, arriving via catch-up) merges the seal
        upward — internal applies bypass the fence by design."""
        # crash point for the service fault suite: REPRO_FAULTPOINTS=
        # "cell.apply=N:kill" SIGKILLs this cell on its Nth applied
        # record — mid write storm, before the mutation lands
        faultpoints.fire("cell.apply")
        e, s = split_vseq(rec.seq)
        with self._flock:
            if s <= self._floors.get(e, 0) or rec.seq in self._applied:
                return False, False
            if rec.seq > self._key_seq.get(rec.key, 0):
                self._key_seq[rec.key] = rec.seq
                if rec.op == wire.OP_PUT:
                    self.store.put_encoded(rec.key, rec.blob, rec.raw_bytes)
                    existed = True
                else:
                    existed = self.store.delete(rec.key)
            else:
                existed = False  # superseded: recorded, not applied
            self._feed.append(rec)
            self._applied.add(rec.seq)
            self.last_seq = max(self.last_seq, rec.seq)
            self._note_epoch(e)
            self._lane_seq[e] = max(self._lane_seq.get(e, 0), s)
            if e in self._sealed and s > self._sealed[e]:
                self._sealed[e] = s  # merge the fence up, never down
            path = self._feed_path()
            if path is not None:
                with open(path, "ab") as f:
                    f.write(rec.pack())
            return True, existed

    def feed_since(self, floors) -> List[wire.FeedRecord]:
        """Records above the *caller's* per-lane floors (a lane absent
        from the map means "send everything you have in it").  An int
        is accepted as a combined-vseq floor (single-lane callers)."""
        if isinstance(floors, int):
            with self._flock:
                return [r for r in self._feed if r.seq > floors]
        with self._flock:
            out = []
            for r in self._feed:
                e, s = split_vseq(r.seq)
                if s > floors.get(e, 0):
                    out.append(r)
            return out

    def feed_bytes(self) -> int:
        path = self._feed_path()
        if path is not None and path.exists():
            return path.stat().st_size
        with self._flock:
            return sum(49 + len(r.key.did) + len(r.blob) for r in self._feed)

    # ---- per-lane ack coverage / feed truncation ----
    def note_ack(self, water: int) -> None:
        """Record a writer-piggybacked ack watermark (every cell has
        applied everything it owns in the writer's lane at or below
        ``water``) and truncate the feed if enough backlog has fallen
        below coverage."""
        e, s = split_vseq(water)
        with self._flock:
            if s > self._lane_ack.get(e, 0):
                self._lane_ack[e] = s
                self._note_epoch(e)
            self._maybe_truncate_locked(force=False)

    def truncate_feed(self, force: bool = True) -> bool:
        with self._flock:
            return self._maybe_truncate_locked(force=force)

    def _maybe_truncate_locked(self, force: bool) -> bool:
        floors = dict(self._floors)
        below = 0
        for r in self._feed:
            e, s = split_vseq(r.seq)
            cov = self._coverage(e)
            if s <= cov:
                below += 1
                if cov > floors.get(e, 0):
                    floors[e] = cov
        if floors == self._floors:
            return False
        if below < (1 if force else self.feed_keep):
            return False
        self._floors = floors
        keep = []
        for r in self._feed:
            e, s = split_vseq(r.seq)
            if s > floors.get(e, 0):
                keep.append(r)
        keep.sort(key=lambda r: r.seq)  # rewrite in vseq order: the
        # surviving log bytes are a pure function of the record set
        self._save_base_locked()  # checkpoint BEFORE the log shrinks
        path = self._feed_path()
        if path is not None:
            tmp = path.with_suffix(".log.tmp")
            with open(tmp, "wb") as f:
                for r in keep:
                    f.write(r.pack())
            os.replace(tmp, path)
        self._feed = keep
        kept = {r.seq for r in keep}
        self._applied = {s for s in self._applied if s in kept}
        self.truncations += 1
        return True

    # ---- writer leases ----
    def lease_op(self, op: int, epoch: int, writer_id: str,
                 final_seq: int = 0) -> Tuple[bool, int]:
        """ACQUIRE / RENEW / RELEASE one writer lease; returns
        ``(granted, max_epoch)`` — the deny reply carries the highest
        epoch this cell has seen so a losing writer can propose past
        it.  Grants are monotonic: an epoch is granted only if it
        exceeds every epoch seen (or re-grants the same writer's own
        lease — acquire and renew are idempotent)."""
        now = time.monotonic()
        with self._flock:
            if op == wire.LEASE_ACQUIRE:
                lease = self.leases.get(epoch)
                if epoch in self._sealed:
                    granted = False
                elif lease is not None and lease[0] in (None, writer_id):
                    lease[0] = writer_id
                    lease[1] = now + self.lease_ttl
                    granted = True
                elif epoch > self.max_epoch and lease is None:
                    self.leases[epoch] = [writer_id, now + self.lease_ttl]
                    granted = True
                else:
                    granted = False
                if granted:
                    self._note_epoch(epoch)
                    self.lease_grants += 1
            elif op == wire.LEASE_RENEW:
                # install-if-missing: a restarted cell lost its lease
                # table, but the renewing writer IS the lane's holder
                # (an impostor would be fenced by the seal, and a lane
                # can have at most one living writer by acquisition) —
                # refusing here would spuriously degrade a healthy
                # writer once a quorum of cells has restarted
                lease = self.leases.get(epoch)
                granted = (epoch not in self._sealed
                           and (lease is None
                                or lease[0] in (None, writer_id)))
                if granted:
                    self.leases[epoch] = [writer_id, now + self.lease_ttl]
                    self._note_epoch(epoch)
            elif op == wire.LEASE_RELEASE:
                # clean writer exit: fence the lane at its final seq so
                # the sweeper needn't wait out the TTL.  The writer has
                # drained its redelivery queues (quiesce/close), so the
                # lane is replica-complete up to final_seq everywhere.
                seal = max(final_seq, self._lane_seq.get(epoch, 0),
                           self._sealed.get(epoch, 0))
                self._sealed[epoch] = seal
                self.leases.pop(epoch, None)
                self._note_epoch(epoch)
                self.reconciles += 1
                self._save_base_locked()
                self._maybe_truncate_locked(force=False)
                granted = True
            else:
                raise AssertionError(f"unknown lease op {op}")
            return granted, self.max_epoch

    def learn_peers(self, peers: List[Tuple[str, int]]) -> None:
        """Adopt cluster topology from a LEASE/RECONCILE frame — the
        addresses lease-expiry reconciliation anti-entropies from."""
        mine = (self.host, self.port)
        with self._flock:
            for p in peers:
                if tuple(p) != mine and tuple(p) not in self.known_peers:
                    self.known_peers.append(tuple(p))

    # ---- orphan-seq reconciliation ----
    def sweep_leases(self) -> int:
        """Detect expired writer leases and reconcile their lanes.
        Returns the number of lanes sealed this pass."""
        now = time.monotonic()
        with self._flock:
            expired = [e for e, (wid, deadline) in self.leases.items()
                       if deadline < now and e not in self._sealed]
        sealed = 0
        for e in expired:
            # crash point: REPRO_FAULTPOINTS="cell.lease_expire=1:kill"
            # SIGKILLs the sweeping cell between detection and repair
            faultpoints.fire("cell.lease_expire")
            if self.reconcile_lane(e):
                sealed += 1
        return sealed

    def reconcile_lane(self, epoch: int, timeout: float = 5.0) -> bool:
        """Coordinate orphan-seq reconciliation for one dead lane:
        query every peer's lane high-water mark, anti-entropy this
        cell's own gaps, seal the lane at the max replica-acked record,
        and broadcast the seal.  Refuses (returns False) unless EVERY
        other cell answers and none still sees a live lease — sealing
        implies "replica-complete up to the seal", which is only
        provable with the whole cluster reachable; a later sweep (or a
        restarted cell's catch-up) retries."""
        with self._flock:
            peers = list(self.known_peers)
            if epoch in self._sealed:
                return True
        if len({p for p in peers}) < self.n_cells - 1:
            return False
        marks = [self._lane_seq.get(epoch, 0)]
        for host, port in peers:
            try:
                with self._peer_socket(host, port, timeout) as s:
                    wire.send_frame(
                        s, wire.MSG_RECONCILE, 0,
                        struct.pack("<BQ", wire.RECONCILE_QUERY, epoch))
                    reply = wire.recv_frame(s)
                if reply.msg_type != wire.MSG_OK:
                    return False
                lane_seq, seal, has_seal, live = struct.unpack_from(
                    "<QQBB", reply.body, 0)
                if live:
                    return False  # the writer still renews somewhere
                marks.append(lane_seq)
                if has_seal:
                    marks.append(seal)
            except (OSError, wire.WireError, struct.error):
                return False
        seal = max(marks)
        # anti-entropy own gaps below the seal before fencing the lane
        self.catch_up(peers, timeout=timeout)
        # phase 1 (prepare): every peer fills its own gaps while every
        # feed is still intact.  Sealing truncates, and each cell's feed
        # only covers the placements it replicates — peers must pull
        # from EACH OTHER before anyone drops feed records, or the seal
        # would certify records a replica never received.
        prep = (struct.pack("<BQ", wire.RECONCILE_PREPARE, epoch)
                + wire.pack_peers([(self.host, self.port)] + peers))
        for host, port in peers:
            try:
                with self._peer_socket(host, port, timeout) as s:
                    wire.send_frame(s, wire.MSG_RECONCILE, 0, prep)
                    reply = wire.recv_frame(s)
                if reply.msg_type != wire.MSG_OK:
                    return False
                (lane_seq,) = struct.unpack_from("<Q", reply.body, 0)
                seal = max(seal, lane_seq)
            except (OSError, wire.WireError, struct.error):
                return False  # completeness unproven: retry next sweep
        # phase 2 (seal): fence + truncate, locally then broadcast —
        # every peer is now complete, so truncation cannot orphan them
        self.apply_seal(epoch, seal)
        body = (struct.pack("<BQQ", wire.RECONCILE_SEAL, epoch, seal)
                + wire.pack_peers([(self.host, self.port)] + peers))
        for host, port in peers:
            try:
                with self._peer_socket(host, port, timeout) as s:
                    wire.send_frame(s, wire.MSG_RECONCILE, 0, body)
                    wire.recv_frame(s)
            except (OSError, wire.WireError):
                continue  # peer repairs at restart catch-up / next sweep
        return True

    def apply_seal(self, epoch: int, seal: int) -> int:
        """Fence one lane at ``seal`` (merged up by any local record
        above it), drop its lease, persist, and let truncation resume —
        the ack-coverage advance that un-strands a dead writer's floor.
        Returns the effective seal."""
        # crash point: REPRO_FAULTPOINTS="cell.reconcile=1:kill" SIGKILLs
        # the cell mid-reconciliation — after anti-entropy, before the
        # seal persists; a restart (or the next sweep) converges
        faultpoints.fire("cell.reconcile")
        with self._flock:
            eff = max(seal, self._sealed.get(epoch, 0),
                      self._lane_seq.get(epoch, 0))
            self._sealed[epoch] = eff
            self.leases.pop(epoch, None)
            self._note_epoch(epoch)
            self.reconciles += 1
            self._save_base_locked()
            self._maybe_truncate_locked(force=False)
            return eff

    def _sweep_loop(self) -> None:
        while not self._stop.wait(self.lease_ttl / 2):
            try:
                self.sweep_leases()
            except Exception:  # noqa: BLE001 — sweeping must not kill serving
                continue

    # ---- background maintenance ----
    def maintain(self) -> bool:
        """Kick a background vacuum of the store's chunk files (reclaim
        tombstoned/superseded records).  Returns whether a new pass was
        started (False: one is already running).  The cell never refuses
        traffic during the pass — ``DeltaStore.vacuum`` holds the store
        lock per chunk and readers retry across rewrites."""
        with self._maint_lock:
            if self._maint_thread is not None and self._maint_thread.is_alive():
                return False
            t = threading.Thread(target=self._maint_pass,
                                 name=f"cell{self.node_id}-maint",
                                 daemon=True)
            self._maint_thread = t
            t.start()
            return True

    def _maint_pass(self) -> None:
        try:
            self.last_vacuum = self.store.vacuum()
        except Exception:  # noqa: BLE001 — maintenance must not kill serving
            self.last_vacuum = None

    # ---- replica catch-up ----
    def _peer_socket(self, host: str, port: int,
                     timeout: float) -> socket.socket:
        """Dial a peer cell with the HELLO (+ optional auth) handshake —
        cell-to-cell traffic speaks the same protocol as clients."""
        s = socket.create_connection((host, port), timeout=timeout)
        s.settimeout(timeout)
        try:
            wire.send_frame(s, wire.MSG_HELLO, 0)
            reply = wire.recv_frame(s)
            if reply.msg_type == wire.MSG_AUTH:
                if self.auth_key is None:
                    raise wire.AuthFailed(
                        f"peer {host}:{port} requires auth but this cell "
                        f"has no key")
                mac = hmac.new(self.auth_key, reply.body,
                               hashlib.sha256).digest()
                wire.send_frame(s, wire.MSG_AUTH, 0, mac)
                reply = wire.recv_frame(s)
            if reply.msg_type == wire.MSG_ERR:
                code, msg = wire.unpack_err(reply.body)
                if code == wire.ERR_AUTH_FAILED:
                    raise wire.AuthFailed(msg)
                raise wire.RemoteError(code, msg)
            if reply.msg_type != wire.MSG_HELLO:
                raise wire.FrameError(
                    f"expected HELLO reply, got type {reply.msg_type}")
        except BaseException:
            s.close()
            raise
        return s

    def _pull_feed(self, host: str, port: int, floors: Dict[int, int],
                   timeout: float,
                   ) -> Tuple[Dict[int, int], Dict[int, int],
                              List[wire.FeedRecord]]:
        with self._peer_socket(host, port, timeout) as s:
            wire.send_frame(s, wire.MSG_FEED_SINCE, 0,
                            wire.pack_lanes(floors))
            reply = wire.recv_frame(s)
        if reply.msg_type != wire.MSG_OK:
            raise wire.RemoteError(*wire.unpack_err(reply.body))
        peer_floors, off = wire.unpack_lanes(reply.body, 0)
        peer_seals, off = wire.unpack_lanes(reply.body, off)
        return peer_floors, peer_seals, wire.unpack_records(reply.body, off)

    def _is_fresh(self) -> bool:
        return (not self._feed and not self._applied and not self._floors
                and self.last_seq == 0 and not self.store.key_sizes)

    def _bootstrap_state(self, peers: List[Tuple[str, int]],
                         timeout: float) -> int:
        """Full-state transfer for a fresh (wiped) cell facing peers
        whose feeds are truncated: for every placement this cell owns,
        copy a live replica's chunk + extent file bytes verbatim and
        install its per-key accounting, then adopt the highest peer
        floors seen.  Returns the number of placements installed.  Chunk
        files never shrink at truncation (only the feed does), so any
        replica's copy is complete regardless of its floors — and since
        they are pure functions of the record set, the copied bytes are
        exactly what replaying the full history would have produced."""
        if self.store.backend != "file":
            raise FeedTruncated(
                "fresh mem-backed cell cannot bootstrap past a truncated "
                "peer feed: full-state transfer needs the file backend")
        pulled: set = set()
        floors: Dict[int, int] = {}
        seals: Dict[int, int] = {}
        installed = 0
        for host, port in peers:
            try:
                with self._peer_socket(host, port, timeout) as s:
                    wire.send_frame(s, wire.MSG_PLACEMENTS, 0)
                    reply = wire.recv_frame(s)
                    if reply.msg_type != wire.MSG_OK:
                        continue
                    placements = [
                        p for p in wire.unpack_placements(reply.body)
                        if p not in pulled
                        and self.node_id in replica_nodes(p[0], p[1],
                                                          self.n_cells,
                                                          self.r)]
                    for tsid, sid in placements:
                        wire.send_frame(s, wire.MSG_STATE_PULL, 0,
                                        struct.pack("<qq", tsid, sid))
                        reply = wire.recv_frame(s)
                        if reply.msg_type != wire.MSG_OK:
                            continue
                        state = wire.PlacementState.unpack(reply.body)
                        self._install_state((tsid, sid), state)
                        pulled.add((tsid, sid))
                        for e, f in state.floors.items():
                            floors[e] = max(floors.get(e, 0), f)
                        for e, f in state.seals.items():
                            seals[e] = max(seals.get(e, 0), f)
                        installed += 1
            except (OSError, wire.WireError, struct.error):
                continue
        with self._flock:
            if installed:
                for e, f in floors.items():
                    self._floors[e] = max(self._floors.get(e, 0), f)
                    self._lane_ack[e] = max(self._lane_ack.get(e, 0), f)
                    self._lane_seq[e] = max(self._lane_seq.get(e, 0), f)
                    self._note_epoch(e)
                    self.last_seq = max(self.last_seq, make_vseq(e, f))
                for e, f in seals.items():
                    self._sealed[e] = max(self._sealed.get(e, 0), f)
                    self._note_epoch(e)
                self.last_seq = max([self.last_seq]
                                    + list(self._key_seq.values()))
                self._save_base_locked()
        return installed

    def _install_state(self, placement: Tuple[int, int],
                       state: wire.PlacementState) -> None:
        cpath = self.store._chunk_path(0, placement)
        epath = self.store._extent_path(0, placement)
        cpath.parent.mkdir(parents=True, exist_ok=True)
        cpath.write_bytes(state.chunk)
        if state.ext:
            epath.write_bytes(state.ext)
        self.store.drop_chunk_caches(0, placement)
        for key, raw, enc in state.sizes:
            self.store.key_sizes[key] = (raw, enc)
        for key, seq in state.key_seqs:
            if seq > self._key_seq.get(key, 0):
                self._key_seq[key] = seq

    def catch_up(self, peers: List[Tuple[str, int]],
                 timeout: float = 5.0) -> int:
        """Converge with the cluster after a restart: pull every peer's
        feed above this cell's own per-lane truncation floors, keep the
        records whose key's replica chain includes this cell and whose
        vseq is not already certified applied, and apply them in vseq
        order.  Merges peer lane *seals* (a lane fenced while this cell
        was down stays fenced here), then returns the number of records
        applied (feed stamps included).

        Fetching from the floors rather than from ``last_seq`` is what
        repairs *interior* gaps — a PUT this cell missed while live
        (transient timeout) below a vseq it did accept would be
        invisible to a tail-only pull and would otherwise serve silently
        stale reads forever; the applied set makes the refetch cheap to
        dedupe and impossible to double-apply.  The floors are a safe
        lower bound because they only advance past records every replica
        acked (or a full-cluster reconciliation sealed).  A peer whose
        own floors are above ours can no longer serve the records in
        between as feed entries — for a disk-surviving cell that is fine
        (the ack invariant says we already hold everything we own down
        there); a *fresh* cell instead bootstraps by full-state transfer
        first.  Unreachable peers are skipped — with r-way replication
        any single live peer of a key suffices."""
        with self._flock:
            own_floors = dict(self._floors)
        fetched: Dict[int, wire.FeedRecord] = {}
        peer_floor_max: Dict[int, int] = {}
        peer_seals: Dict[int, int] = {}
        reachable: List[Tuple[str, int]] = []
        for host, port in peers:
            try:
                pf, ps, recs = self._pull_feed(host, port, own_floors,
                                               timeout)
            except (OSError, wire.WireError, struct.error):
                continue
            reachable.append((host, port))
            for e, f in pf.items():
                peer_floor_max[e] = max(peer_floor_max.get(e, 0), f)
            for e, f in ps.items():
                peer_seals[e] = max(peer_seals.get(e, 0), f)
            for rec in recs:
                e, s = split_vseq(rec.seq)
                if (s > own_floors.get(e, 0)
                        and rec.seq not in self._applied
                        and self._owns(rec.key)):
                    fetched.setdefault(rec.seq, rec)
        above = any(f > own_floors.get(e, 0)
                    for e, f in peer_floor_max.items())
        if above and self._is_fresh():
            self._bootstrap_state(reachable, timeout)
        n = 0
        for seq in sorted(fetched):
            applied, _ = self.apply(fetched[seq])
            n += applied
        # merge peer seals only AFTER the gap records above are applied:
        # a seal raises this lane's truncation coverage, and a concurrent
        # piggybacked ack must not advance the floor past records still
        # sitting in `fetched` (the floor certifies them applied)
        with self._flock:
            for e, f in peer_seals.items():
                if f > self._sealed.get(e, -1):
                    self._sealed[e] = max(f, self._lane_seq.get(e, 0))
                self.leases.pop(e, None)
                self._note_epoch(e)
        return n

    # ---- server ----
    def start(self, peers: Optional[List[Tuple[str, int]]] = None) -> int:
        """Catch up from ``peers`` (if any), bind, and serve in
        background threads.  Returns the bound port.  A second catch-up
        pass runs after bind so records that landed on peers while this
        cell was binding are not missed."""
        if peers:
            self.catch_up(peers)
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.workers,
                thread_name_prefix=f"cell{self.node_id}-worker")
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind((self.host, self.port))
        self._lsock.listen(64)
        self.port = self._lsock.getsockname()[1]
        for target, name in ((self._accept_loop, "accept"),
                             (self._sweep_loop, "sweep")):
            t = threading.Thread(target=target,
                                 name=f"cell{self.node_id}-{name}",
                                 daemon=True)
            t.start()
            self._threads.append(t)
        if peers:
            self.learn_peers(peers)
            self.catch_up(peers)
        return self.port

    def stop(self) -> None:
        self._stop.set()
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
        for c in list(self._conns):
            try:
                c.close()
            except OSError:
                pass
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._lsock.accept()
            except OSError:
                return  # listen socket closed by stop()
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._conns.add(conn)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _hello_body(self) -> bytes:
        return struct.pack("<BQ", self.node_id, self.last_seq)

    def _serve_conn(self, conn: socket.socket) -> None:
        """Per-connection read loop.  Cheap liveness traffic (HELLO,
        PING) is answered inline so it can never queue behind a slow
        request; everything else is dispatched to the worker pool under
        the per-connection in-flight cap.  Replies are written under
        ``send_lock`` in completion order — out-of-order by design, the
        client demuxes by ``req_id``.

        With ``auth_key`` set, the connection starts *unauthenticated*:
        HELLO is answered with an ``MSG_AUTH`` nonce challenge, the
        client's ``MSG_AUTH`` HMAC response is verified with a
        constant-time compare, and every other frame before success is
        refused with the typed ``AUTH_FAILED`` and a hangup."""
        send_lock = threading.Lock()
        slots = threading.BoundedSemaphore(self.inflight_cap)
        reader = wire.FrameReader(conn)  # pipelined requests batch per recv
        authed = self.auth_key is None
        nonce: Optional[bytes] = None
        try:
            while not self._stop.is_set():
                try:
                    frame = reader.next_frame()
                except (wire.ConnectionClosed, OSError):
                    return  # peer hung up, or stop() closed us mid-read
                except wire.WireError:
                    return  # garbage on the stream: drop the connection
                if frame.version != wire.PROTO_VERSION:
                    # answer under OUR version so the peer's codec can
                    # still read the rejection, then hang up
                    with send_lock:
                        wire.send_frame(
                            conn, wire.MSG_ERR, frame.req_id,
                            wire.pack_err(
                                wire.ERR_VERSION,
                                f"cell speaks v{wire.PROTO_VERSION}, "
                                f"client sent v{frame.version}"))
                    return
                if not authed:
                    try:
                        if frame.msg_type == wire.MSG_HELLO:
                            nonce = os.urandom(wire.AUTH_NONCE_LEN)
                            with send_lock:
                                wire.send_frame(conn, wire.MSG_AUTH,
                                                frame.req_id, nonce)
                            continue
                        if frame.msg_type == wire.MSG_AUTH and nonce:
                            mac = hmac.new(self.auth_key, nonce,
                                           hashlib.sha256).digest()
                            if hmac.compare_digest(mac, frame.body):
                                authed = True
                                nonce = None
                                with send_lock:
                                    wire.send_frame(conn, wire.MSG_HELLO,
                                                    frame.req_id,
                                                    self._hello_body())
                                continue
                        with send_lock:
                            wire.send_frame(
                                conn, wire.MSG_ERR, frame.req_id,
                                wire.pack_err(wire.ERR_AUTH_FAILED,
                                              "auth required: bad or "
                                              "missing HMAC response"))
                    except OSError:
                        pass
                    if not authed:
                        return  # failed handshake: hang up
                    continue
                if frame.msg_type in (wire.MSG_HELLO, wire.MSG_PING):
                    if frame.msg_type == wire.MSG_PING and len(frame.body) >= 8:
                        (water,) = struct.unpack_from("<Q", frame.body, 0)
                        self.note_ack(water)
                    reply = (wire.MSG_HELLO if frame.msg_type == wire.MSG_HELLO
                             else wire.MSG_OK)
                    try:
                        with send_lock:
                            wire.send_frame(conn, reply, frame.req_id,
                                            self._hello_body())
                    except OSError:
                        return
                    continue
                slots.acquire()  # in-flight cap: blocks the READ loop only
                if self._pool is None:  # direct use without start(): inline
                    self._run_request(conn, send_lock, slots, frame)
                else:
                    self._pool.submit(self._run_request, conn, send_lock,
                                      slots, frame)
        finally:
            self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _run_request(self, conn: socket.socket, send_lock: threading.Lock,
                     slots: threading.BoundedSemaphore,
                     frame: wire.Frame) -> None:
        try:
            try:
                if frame.msg_type == wire.MSG_MULTIGET:
                    self._stream_multiget(conn, send_lock, frame)
                    return
                mtype, body = self._handle(frame.msg_type, frame.body)
            except KeyMissing as e:
                mtype, body = wire.MSG_ERR, wire.pack_err(
                    wire.ERR_KEY_MISSING, str(e.args[0]))
            except FeedTruncated as e:
                mtype, body = wire.MSG_ERR, wire.pack_err(
                    wire.ERR_FEED_TRUNCATED, str(e))
            except wire.LeaseFenced as e:
                mtype, body = wire.MSG_ERR, wire.pack_err(
                    wire.ERR_LEASE_FENCED, str(e))
            except (wire.WireError, struct.error, IndexError,
                    UnicodeDecodeError, AssertionError) as e:
                mtype, body = wire.MSG_ERR, wire.pack_err(
                    wire.ERR_BAD_REQUEST, f"{type(e).__name__}: {e}")
            except Exception as e:  # noqa: BLE001 — relay, don't die
                mtype, body = wire.MSG_ERR, wire.pack_err(
                    wire.ERR_INTERNAL, f"{type(e).__name__}: {e}")
            try:
                with send_lock:
                    wire.send_frame(conn, mtype, frame.req_id, body)
            except OSError:
                pass
        finally:
            slots.release()

    def _stream_multiget(self, conn: socket.socket,
                         send_lock: threading.Lock,
                         frame: wire.Frame) -> None:
        """MULTIGET reply stream: one CHUNK frame per found key as it is
        read (the client can decode and pool-fill immediately), END with
        the found count as the terminal frame, ERR as the terminal frame
        on a hard miss.  All frames carry the request's req_id, so the
        stream interleaves freely with other in-flight replies."""
        try:
            body = frame.body
            (n,) = struct.unpack_from("<I", body, 0)
            off = 4
            keys = []
            for _ in range(n):
                k, off = wire.unpack_key(body, off)
                keys.append(k)
            fields, off = wire.unpack_fields(body, off)
            (missing_ok,) = struct.unpack_from("<B", body, off)
        except (wire.WireError, struct.error, IndexError,
                UnicodeDecodeError) as e:
            try:
                with send_lock:
                    wire.send_frame(conn, wire.MSG_ERR, frame.req_id,
                                    wire.pack_err(wire.ERR_BAD_REQUEST,
                                                  f"{type(e).__name__}: {e}"))
            except OSError:
                pass
            return
        # CHUNK frames coalesce into one sendall per ~64 KiB — identical
        # frames on the wire, a fraction of the syscalls (and on a busy
        # box, of the scheduler switches).  A terminal ERR/END appends
        # after any buffered chunks so per-request frame order holds.
        found = 0
        pend = bytearray()
        try:
            for k in keys:
                try:
                    blob = self.store.get_encoded(k, fields)
                except KeyMissing as e:
                    if missing_ok:
                        continue
                    pend += wire.encode_frame(
                        wire.MSG_ERR, frame.req_id,
                        wire.pack_err(wire.ERR_KEY_MISSING, str(e.args[0])))
                    with send_lock:
                        conn.sendall(pend)
                    return
                except Exception as e:  # noqa: BLE001 — relay, don't die
                    pend += wire.encode_frame(
                        wire.MSG_ERR, frame.req_id,
                        wire.pack_err(wire.ERR_INTERNAL,
                                      f"{type(e).__name__}: {e}"))
                    with send_lock:
                        conn.sendall(pend)
                    return
                found += 1
                pend += wire.encode_frame(
                    wire.MSG_CHUNK, frame.req_id,
                    wire.pack_key(k) + wire.pack_blob(blob))
                if len(pend) >= (1 << 16):
                    with send_lock:
                        conn.sendall(pend)
                    pend = bytearray()
            pend += wire.encode_frame(wire.MSG_END, frame.req_id,
                                      struct.pack("<I", found))
            with send_lock:
                conn.sendall(pend)
        except OSError:
            pass

    def _feed_status_locked(self) -> Dict:
        lanes = {}
        for e in sorted(self._lanes_known()):
            lanes[str(e)] = {
                "seq": self._lane_seq.get(e, 0),
                "ack": self._lane_ack.get(e, 0),
                "floor": self._floors.get(e, 0),
                "seal": self._sealed.get(e),
                "lease": (e in self.leases
                          and self.leases[e][1] > time.monotonic()),
            }
        known = self._lanes_known()
        return {
            "len": len(self._feed),
            "floor": max((make_vseq(e, f)
                          for e, f in self._floors.items()), default=0),
            "ack_water": max((make_vseq(e, self._coverage(e))
                              for e in known), default=0),
            "truncations": self.truncations,
            "lanes": lanes,
            "max_epoch": self.max_epoch,
            "fenced_writes": self.fenced_writes,
            "reconciles": self.reconciles,
        }

    def _handle(self, msg_type: int, body: bytes) -> Tuple[int, bytes]:
        if msg_type in (wire.MSG_HELLO, wire.MSG_PING):
            # normally answered inline by the read loop; kept here for
            # direct (non-socket) callers
            if msg_type == wire.MSG_PING and len(body) >= 8:
                (water,) = struct.unpack_from("<Q", body, 0)
                self.note_ack(water)
            reply = wire.MSG_HELLO if msg_type == wire.MSG_HELLO else wire.MSG_OK
            return reply, self._hello_body()
        if msg_type == wire.MSG_GET:
            key, off = wire.unpack_key(body, 0)
            fields, _ = wire.unpack_fields(body, off)
            return wire.MSG_OK, self.store.get_encoded(key, fields)
        if msg_type == wire.MSG_PUT:
            key, off = wire.unpack_key(body, 0)
            seq, raw = struct.unpack_from("<QQ", body, off)
            blob, off = wire.unpack_blob(body, off + 16)
            self.fence_check(seq)  # LeaseFenced before anything lands
            applied, _ = self.apply(
                wire.FeedRecord(seq, wire.OP_PUT, key, raw, blob))
            if off + 8 <= len(body):  # trailing ack watermark
                (water,) = struct.unpack_from("<Q", body, off)
                self.note_ack(water)
            return wire.MSG_OK, struct.pack("<BQ", applied, self.last_seq)
        if msg_type == wire.MSG_DELETE:
            key, off = wire.unpack_key(body, 0)
            (seq,) = struct.unpack_from("<Q", body, off)
            self.fence_check(seq)
            _, existed = self.apply(
                wire.FeedRecord(seq, wire.OP_DELETE, key, 0, b""))
            if off + 16 <= len(body):  # trailing ack watermark
                (water,) = struct.unpack_from("<Q", body, off + 8)
                self.note_ack(water)
            return wire.MSG_OK, struct.pack("<BQ", existed, self.last_seq)
        if msg_type == wire.MSG_FEED_SINCE:
            floors, _ = wire.unpack_lanes(body, 0)
            with self._flock:
                head = (wire.pack_lanes(self._floors)
                        + wire.pack_lanes(self._sealed))
            return wire.MSG_OK, (head
                                 + wire.pack_records(self.feed_since(floors)))
        if msg_type == wire.MSG_LEASE:
            (op,) = struct.unpack_from("<B", body, 0)
            (epoch,) = struct.unpack_from("<Q", body, 1)
            writer_id, off = wire.unpack_str(body, 9)
            final_seq = 0
            if op == wire.LEASE_RELEASE and off + 8 <= len(body):
                (final_seq,) = struct.unpack_from("<Q", body, off)
                off += 8
            if off < len(body):  # trailing peer list: learn the topology
                peers, _ = wire.unpack_peers(body, off)
                self.learn_peers(peers)
            granted, max_epoch = self.lease_op(op, epoch, writer_id,
                                               final_seq)
            return wire.MSG_OK, struct.pack("<BQ", granted, max_epoch)
        if msg_type == wire.MSG_RECONCILE:
            (op,) = struct.unpack_from("<B", body, 0)
            if op == wire.RECONCILE_QUERY:
                (epoch,) = struct.unpack_from("<Q", body, 1)
                with self._flock:
                    lane_seq = self._lane_seq.get(epoch, 0)
                    seal = self._sealed.get(epoch)
                    lease = self.leases.get(epoch)
                    live = (lease is not None
                            and lease[1] > time.monotonic())
                return wire.MSG_OK, struct.pack(
                    "<QQBB", lane_seq, seal or 0, seal is not None, live)
            if op == wire.RECONCILE_PREPARE:
                (epoch,) = struct.unpack_from("<Q", body, 1)
                peers: List[Tuple[str, int]] = []
                if len(body) > 9:
                    peers, _ = wire.unpack_peers(body, 9)
                    self.learn_peers(peers)
                mine = (self.host, self.port)
                others = [tuple(p) for p in peers if tuple(p) != mine]
                if others:  # fill own gaps while feeds are intact
                    self.catch_up(others)
                with self._flock:
                    return wire.MSG_OK, struct.pack(
                        "<Q", self._lane_seq.get(epoch, 0))
            if op == wire.RECONCILE_SEAL:
                epoch, seal = struct.unpack_from("<QQ", body, 1)
                peers: List[Tuple[str, int]] = []
                if len(body) > 17:
                    peers, _ = wire.unpack_peers(body, 17)
                    self.learn_peers(peers)
                mine = (self.host, self.port)
                others = [tuple(p) for p in peers if tuple(p) != mine]
                if others:  # anti-entropy own gaps before fencing
                    self.catch_up(others)
                eff = self.apply_seal(epoch, seal)
                return wire.MSG_OK, struct.pack("<Q", eff)
            raise AssertionError(f"unknown reconcile op {op}")
        if msg_type == wire.MSG_STATUS:
            s = self.store.stats
            with self._flock:
                feed = self._feed_status_locked()
                lease_view = {
                    str(e): {"writer": wid,
                             "remaining": round(dl - time.monotonic(), 3)}
                    for e, (wid, dl) in self.leases.items()}
            status = {
                "node": self.node_id, "last_seq": self.last_seq,
                "n_keys": len(self.store.key_sizes),
                "live_bytes": self.store.live_bytes(),
                "backend": self.store.backend,
                "feed_len": feed["len"],
                "feed": dict(feed, bytes=self.feed_bytes()),
                "leases": lease_view,
                "max_epoch": self.max_epoch,
                "stats": {"reads": s.reads, "writes": s.writes,
                          "bytes_read": s.bytes_read,
                          "bytes_written": s.bytes_written,
                          "bytes_io": s.bytes_io},
                "maint": {
                    "running": (self._maint_thread is not None
                                and self._maint_thread.is_alive()),
                    "last_vacuum": self.last_vacuum,
                },
            }
            return wire.MSG_OK, json.dumps(status).encode()
        if msg_type == wire.MSG_KEYS:
            tsid, sid = struct.unpack_from("<qq", body, 0)
            keys = self.store.keys_for_placement(tsid, sid)
            return wire.MSG_OK, (struct.pack("<I", len(keys))
                                 + b"".join(wire.pack_key(k) for k in keys))
        if msg_type == wire.MSG_MAINT:
            # empty body: legacy "kick a vacuum".  Otherwise a flags
            # byte: bit0 vacuum (fire-and-forget, background thread),
            # bit1 truncate the feed NOW if coverage allows, bit2 run a
            # SYNCHRONOUS canonical vacuum (chunk records reordered by
            # key — the multi-writer byte-identity anchor); bits 1-2 are
            # synchronous so benches/tests reach a deterministic final
            # disk state before comparing files
            flags = wire.MAINT_VACUUM
            if len(body) >= 1:
                (flags,) = struct.unpack_from("<B", body, 0)
            started = False
            if flags & wire.MAINT_VACUUM and not flags & wire.MAINT_CANON:
                started = self.maintain()
            if flags & wire.MAINT_TRUNCATE:
                self.truncate_feed(force=True)
            if flags & wire.MAINT_CANON:
                self.last_vacuum = self.store.vacuum(canonical=True)
                started = True
            return wire.MSG_OK, struct.pack("<B", started)
        if msg_type == wire.MSG_PLACEMENTS:
            placements = sorted({(k.tsid, k.sid)
                                 for k in self.store.key_sizes})
            return wire.MSG_OK, wire.pack_placements(placements)
        if msg_type == wire.MSG_STATE_PULL:
            if self.store.backend != "file":
                raise FeedTruncated(
                    "mem-backed cell cannot serve full-state transfer")
            tsid, sid = struct.unpack_from("<qq", body, 0)
            placement = (tsid, sid)
            with self._flock:
                cpath = self.store._chunk_path(0, placement)
                epath = self.store._extent_path(0, placement)
                chunk = cpath.read_bytes() if cpath.exists() else b""
                ext = epath.read_bytes() if epath.exists() else b""
                sizes = [(k, rw, en)
                         for k, (rw, en) in self.store.key_sizes.items()
                         if (k.tsid, k.sid) == placement]
                key_seqs = [(k, s) for k, s in self._key_seq.items()
                            if (k.tsid, k.sid) == placement]
                state = wire.PlacementState(dict(self._floors),
                                            dict(self._sealed), chunk, ext,
                                            sizes, key_seqs)
            return wire.MSG_OK, state.pack()
        raise AssertionError(f"unknown message type {msg_type}")


def _parse_peers(spec: str) -> List[Tuple[str, int]]:
    out = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        host, port = part.rsplit(":", 1)
        out.append((host, int(port)))
    return out


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Run one temporal-graph storage cell.")
    ap.add_argument("--node-id", type=int, required=True)
    ap.add_argument("--n-cells", type=int, required=True)
    ap.add_argument("--replication", type=int, default=1)
    ap.add_argument("--backend", default="file", choices=("mem", "file"))
    ap.add_argument("--root", default=None,
                    help="cell data dir (chunk/extent files + feed.log)")
    ap.add_argument("--fmt", default=None, help="block format (TGI2 default)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port (0 = ephemeral, printed on READY)")
    ap.add_argument("--peers", default="",
                    help="comma-separated host:port peers for boot catch-up")
    ap.add_argument("--workers", type=int, default=4,
                    help="request worker pool size (read loops stay free)")
    ap.add_argument("--inflight-cap", type=int, default=32,
                    help="max queued+running requests per connection")
    ap.add_argument("--feed-keep", type=int, default=256,
                    help="min fully-acked backlog before feed truncation")
    ap.add_argument("--lease-ttl", type=float, default=2.0,
                    help="writer-lease TTL seconds (sweeper reconciles "
                         "expired lanes)")
    ap.add_argument("--auth-key", default=None,
                    help="shared secret: require the HELLO HMAC handshake")
    args = ap.parse_args(argv)
    cell = StorageCell(node_id=args.node_id, n_cells=args.n_cells,
                       r=args.replication, backend=args.backend,
                       root=args.root, fmt=args.fmt, host=args.host,
                       port=args.port, workers=args.workers,
                       inflight_cap=args.inflight_cap,
                       feed_keep=args.feed_keep, lease_ttl=args.lease_ttl,
                       auth_key=args.auth_key)
    port = cell.start(peers=_parse_peers(args.peers))
    print(f"CELL READY node={cell.node_id} port={port}", flush=True)
    stop = threading.Event()
    signal.signal(signal.SIGTERM, lambda *_: stop.set())
    try:
        stop.wait()
    except KeyboardInterrupt:
        pass
    cell.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
