"""StorageCell: one storage node served over the wire protocol.

A cell owns one node's chunk/extent files through a private
single-node ``DeltaStore`` (m=1, r=1, no decoded-block pool — decoding
is the *client's* job; the cell ships encoded columns verbatim via
``get_encoded``/``assemble_block``, so a projected GET costs the cell
only the projected columns' file bytes).

Writes are change-feed records: the client stamps every ``put``/
``delete`` with a globally monotonic ``seq`` and fans it out to the
key's replica cells.  Each cell appends applied records to an
append-only ``feed.log`` (and an in-memory tail) — the cell's write
history in arrival order.  Because the client serializes writes (one
fan-out at a time), arrival order IS seq order, which makes a cell's
chunk/extent/feed files a pure function of its record set: a
killed-and-restarted cell that replays the records it missed via
``feed_since`` from its peers, in seq order, converges to
byte-identical files.  Duplicate deliveries (client retries, catch-up
racing a live write) are dropped by seq: every applied seq — including
those replayed from ``feed.log`` at boot — lives in an applied-seq
set, so catch-up can refetch the peer feed and repair interior gaps
(a transiently missed PUT below ``last_seq``), not just the tail.
A per-key max-seq guard keeps an out-of-order repair from regressing a
key past a newer applied write: the late record is stamped into the
feed (it is no longer a gap) but the store mutation is skipped.

**Feed compaction (replica-ack watermark).**  The feed no longer grows
without bound: the writer client piggybacks an *ack watermark* on
PUT/DELETE/PING bodies — the highest seq it can prove every cell has
applied (min over nodes of observed ``last_seq``, clamped below any
queued redelivery).  Once at least ``feed_keep`` in-memory records sit
at or below the watermark (or a forced MAINT pass asks), the cell
checkpoints: it writes ``feed.base`` (floor + per-key size/seq
accounting, sorted for byte determinism), rewrites ``feed.log`` with
only the records above the floor, and drops the truncated seqs from
the applied set — ``seq <= feed_floor`` itself now certifies
"applied".  The base is written *before* the log is rewritten, so a
crash between the two leaves stale records the boot path skips by
floor.  Catch-up stays correct: the floor only advances past records
every replica acked, so a disk-surviving restart already holds
everything at or below any peer's floor that it owns.  A *fresh* cell
(wiped disk) facing a truncated peer bootstraps by full-state transfer
— ``MSG_PLACEMENTS`` + ``MSG_STATE_PULL`` copy a live replica's chunk
and extent files verbatim (they are pure functions of the record set,
preserving byte-identical convergence) plus the per-key accounting,
then a normal feed pull stamps the records above the floor.  A fresh
*mem* cell cannot be rebuilt this way and fails with the typed
``FeedTruncated``.

**Pipelined serving.**  The per-connection read loop no longer
executes requests inline: frames are dispatched to a small cell-wide
worker pool (``workers``) under a per-connection in-flight cap
(``inflight_cap``, enforced by semaphore — a flooding client blocks in
its own read loop, which is TCP backpressure, not memory growth), and
replies are written under a per-connection send lock in completion
order — the ``req_id`` is the demux key, not arrival order.  HELLO and
PING are answered *inline on the read loop*, so a slow GET can never
head-of-line-block a health probe even with every worker busy.
MULTIGET replies stream one ``MSG_CHUNK`` frame per found key followed
by ``MSG_END``, so the client decodes early keys while the cell is
still reading later ones.

Run one cell per process via ``python -m repro.service.cell`` (prints
``CELL READY node=<i> port=<p>`` for the cluster harness) or
in-process via ``LocalCluster(mode="thread")``.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import socket
import struct
import sys
import threading
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.core import faultpoints
from repro.service import wire
from repro.storage.kvstore import (DeltaStore, KeyMissing, replica_nodes)

class FeedTruncated(wire.WireError):
    """Needed feed history predates a peer's truncation floor and no
    full-state transfer can cover it (mem backend, or no file-backed
    replica reachable)."""


class StorageCell:
    def __init__(self, node_id: int, n_cells: int, r: int,
                 backend: str = "file", root: Optional[str] = None,
                 fmt: Optional[str] = None, host: str = "127.0.0.1",
                 port: int = 0, workers: int = 4, inflight_cap: int = 32,
                 feed_keep: int = 256):
        assert backend in ("mem", "file")
        self.node_id = node_id
        self.n_cells = n_cells
        self.r = r
        self.host = host
        self.port = port  # 0 -> ephemeral; real port known after start()
        self.workers = max(1, workers)
        self.inflight_cap = max(1, inflight_cap)
        self.feed_keep = max(1, feed_keep)
        self.root = Path(root) if root is not None else None
        if backend == "file":
            assert root is not None
            self.root.mkdir(parents=True, exist_ok=True)
        self.store = DeltaStore(m=1, r=1, backend=backend, root=root,
                                fmt=fmt, pool_bytes=0, seek=True)
        # change feed: in-memory tail above the truncation floor plus an
        # append-only feed.log (file backend).  _flock serializes
        # apply+append so the log can never disagree with the store.
        self._feed: List[wire.FeedRecord] = []
        self._flock = threading.Lock()
        # every seq this cell has applied ABOVE the floor (rebuilt from
        # feed.log at boot) — together with ``seq <= feed_floor`` this is
        # the dedupe that lets catch-up refetch the peer feed and repair
        # interior gaps without double-applying anything
        self._applied: set = set()
        # per-key max applied seq: an out-of-order gap repair must never
        # regress a key past a newer write already applied
        self._key_seq: Dict[Tuple, int] = {}
        self.last_seq = 0
        # replica-ack watermark state
        self.feed_floor = 0   # highest truncated seq (0: nothing truncated)
        self.ack_water = 0    # highest client-proven cluster-wide ack seen
        self.truncations = 0  # completed feed truncation passes
        self._load_feed()
        self._lsock: Optional[socket.socket] = None
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._conns: set = set()
        self._pool: Optional[ThreadPoolExecutor] = None
        # background store maintenance (chunk vacuum): one pass at a
        # time, triggered by MSG_MAINT; the cell keeps serving while it
        # runs (vacuum holds the store lock per chunk only)
        self._maint_lock = threading.Lock()
        self._maint_thread: Optional[threading.Thread] = None
        self.last_vacuum: Optional[Dict] = None

    # ---- feed persistence ----
    def _feed_path(self) -> Optional[Path]:
        return None if self.root is None else self.root / "feed.log"

    def _base_path(self) -> Optional[Path]:
        return None if self.root is None else self.root / "feed.base"

    def _load_base(self) -> None:
        """Load the truncation checkpoint (floor + per-key accounting)
        if one exists.  Everything at or below the floor is certified
        applied; ``feed.log`` replay then layers the surviving tail on
        top."""
        path = self._base_path()
        if path is None or not path.exists():
            return
        buf = path.read_bytes()
        try:
            (floor,) = struct.unpack_from("<Q", buf, 0)
            (n,) = struct.unpack_from("<I", buf, 8)
            off = 12
            sizes = []
            for _ in range(n):
                key, off = wire.unpack_key(buf, off)
                raw, enc = struct.unpack_from("<QQ", buf, off)
                off += 16
                sizes.append((key, raw, enc))
            (n,) = struct.unpack_from("<I", buf, off)
            off += 4
            seqs = []
            for _ in range(n):
                key, off = wire.unpack_key(buf, off)
                (seq,) = struct.unpack_from("<Q", buf, off)
                off += 8
                seqs.append((key, seq))
        except (wire.WireError, struct.error, IndexError, UnicodeDecodeError):
            return  # torn checkpoint: fall back to whatever the log holds
        self.feed_floor = floor
        self.ack_water = max(self.ack_water, floor)
        self.last_seq = max(self.last_seq, floor)
        for key, raw, enc in sizes:
            self.store.key_sizes[key] = (raw, enc)
        for key, seq in seqs:
            self._key_seq[key] = seq
            self.last_seq = max(self.last_seq, seq)

    def _save_base_locked(self) -> None:
        """Checkpoint the current accounting under the current floor.
        Keys are emitted in sorted order so the file bytes are a pure
        function of the state (the byte-identity property extends to the
        checkpoint).  Written tmp-then-rename, and always BEFORE the log
        rewrite, so a crash between the two only leaves stale log
        records the boot path drops by floor."""
        path = self._base_path()
        if path is None:
            return
        out = [struct.pack("<QI", self.feed_floor, len(self.store.key_sizes))]
        for key in sorted(self.store.key_sizes,
                          key=lambda k: (k.tsid, k.sid, k.pid, k.did)):
            raw, enc = self.store.key_sizes[key]
            out.append(wire.pack_key(key) + struct.pack("<QQ", raw, enc))
        out.append(struct.pack("<I", len(self._key_seq)))
        for key in sorted(self._key_seq,
                          key=lambda k: (k.tsid, k.sid, k.pid, k.did)):
            out.append(wire.pack_key(key)
                       + struct.pack("<Q", self._key_seq[key]))
        tmp = path.with_suffix(".base.tmp")
        tmp.write_bytes(b"".join(out))
        os.replace(tmp, path)

    def _load_feed(self) -> None:
        """Boot: rebuild ``last_seq``, the applied-seq set, the per-key
        seq watermarks, and the store's size accounting from
        ``feed.base`` + ``feed.log``.  The chunk/extent files already
        hold the data (the store's file backend persists), so records
        are NOT re-applied — only the bookkeeping is replayed.

        The feed append in ``apply`` is not atomic and cells are killed
        with SIGKILL, so a torn last record is an expected crash
        artifact: any record that fails to decode is treated as the torn
        tail — the log is truncated back to the last whole record and
        catch-up refetches whatever the lost suffix held."""
        self._load_base()
        path = self._feed_path()
        if path is None or not path.exists():
            return
        data = path.read_bytes()
        off = 0
        good = 0  # byte offset of the last cleanly decoded record's end
        while off < len(data):
            try:
                rec, off = wire.FeedRecord.unpack(data, off)
            except (wire.WireError, struct.error, IndexError,
                    UnicodeDecodeError):
                with open(path, "r+b") as f:  # torn tail: drop it
                    f.truncate(good)
                break
            good = off
            if rec.seq <= self.feed_floor:
                # checkpoint written but crash hit before the log
                # rewrite: the record is already certified by the floor
                continue
            self._feed.append(rec)
            self._applied.add(rec.seq)
            self.last_seq = max(self.last_seq, rec.seq)
            if rec.seq > self._key_seq.get(rec.key, 0):
                self._key_seq[rec.key] = rec.seq
                if rec.op == wire.OP_PUT:
                    self.store.key_sizes[rec.key] = (rec.raw_bytes,
                                                     len(rec.blob))
                else:
                    self.store.key_sizes.pop(rec.key, None)

    def _owns(self, key) -> bool:
        return self.node_id in replica_nodes(key.tsid, key.sid,
                                             self.n_cells, self.r)

    def apply(self, rec: wire.FeedRecord) -> Tuple[bool, bool]:
        """Apply one feed record (a wire PUT/DELETE, a catch-up replay,
        or a client gap redelivery); returns ``(applied, existed)``.
        Duplicates — client retries after a lost ack, catch-up
        overlapping a live write — are detected against the applied-seq
        set plus the truncation floor (both survive restarts via
        ``feed.base``/``feed.log``) and acked without touching the
        store, so a record can never double-append to the chunk files.
        A record at or below the key's newest applied write (an
        interior-gap repair arriving late, or a feed replay of a record
        whose effect arrived via full-state transfer) is stamped into
        the feed — the seq is no longer a gap, and peers replicating
        this feed dedupe it the same way — but the store mutation is
        skipped so the key never regresses or double-applies."""
        # crash point for the service fault suite: REPRO_FAULTPOINTS=
        # "cell.apply=N:kill" SIGKILLs this cell on its Nth applied
        # record — mid write storm, before the mutation lands
        faultpoints.fire("cell.apply")
        with self._flock:
            if rec.seq <= self.feed_floor or rec.seq in self._applied:
                return False, False
            if rec.seq > self._key_seq.get(rec.key, 0):
                self._key_seq[rec.key] = rec.seq
                if rec.op == wire.OP_PUT:
                    self.store.put_encoded(rec.key, rec.blob, rec.raw_bytes)
                    existed = True
                else:
                    existed = self.store.delete(rec.key)
            else:
                existed = False  # superseded: recorded, not applied
            self._feed.append(rec)
            self._applied.add(rec.seq)
            self.last_seq = max(self.last_seq, rec.seq)
            path = self._feed_path()
            if path is not None:
                with open(path, "ab") as f:
                    f.write(rec.pack())
            return True, existed

    def feed_since(self, seq: int) -> List[wire.FeedRecord]:
        with self._flock:
            return [r for r in self._feed if r.seq > seq]

    def feed_bytes(self) -> int:
        path = self._feed_path()
        if path is not None and path.exists():
            return path.stat().st_size
        with self._flock:
            return sum(49 + len(r.key.did) + len(r.blob) for r in self._feed)

    # ---- replica-ack watermark / feed truncation ----
    def note_ack(self, water: int) -> None:
        """Record a client-piggybacked ack watermark (every cell has
        applied everything it owns at or below ``water``) and truncate
        the feed if enough backlog has fallen below it."""
        with self._flock:
            if water > self.ack_water:
                self.ack_water = water
            self._maybe_truncate_locked(force=False)

    def truncate_feed(self, force: bool = True) -> bool:
        with self._flock:
            return self._maybe_truncate_locked(force=force)

    def _maybe_truncate_locked(self, force: bool) -> bool:
        floor = self.ack_water
        if floor <= self.feed_floor:
            return False
        below = sum(1 for r in self._feed if r.seq <= floor)
        if below < (1 if force else self.feed_keep):
            return False
        self.feed_floor = floor
        keep = [r for r in self._feed if r.seq > floor]
        self._save_base_locked()  # checkpoint BEFORE the log shrinks
        path = self._feed_path()
        if path is not None:
            tmp = path.with_suffix(".log.tmp")
            with open(tmp, "wb") as f:
                for r in keep:
                    f.write(r.pack())
            os.replace(tmp, path)
        self._feed = keep
        self._applied = {s for s in self._applied if s > floor}
        self.truncations += 1
        return True

    # ---- background maintenance ----
    def maintain(self) -> bool:
        """Kick a background vacuum of the store's chunk files (reclaim
        tombstoned/superseded records).  Returns whether a new pass was
        started (False: one is already running).  The cell never refuses
        traffic during the pass — ``DeltaStore.vacuum`` holds the store
        lock per chunk and readers retry across rewrites."""
        with self._maint_lock:
            if self._maint_thread is not None and self._maint_thread.is_alive():
                return False
            t = threading.Thread(target=self._maint_pass,
                                 name=f"cell{self.node_id}-maint",
                                 daemon=True)
            self._maint_thread = t
            t.start()
            return True

    def _maint_pass(self) -> None:
        try:
            self.last_vacuum = self.store.vacuum()
        except Exception:  # noqa: BLE001 — maintenance must not kill serving
            self.last_vacuum = None

    # ---- replica catch-up ----
    def _pull_feed(self, host: str, port: int, since: int,
                   timeout: float) -> Tuple[int, List[wire.FeedRecord]]:
        with socket.create_connection((host, port), timeout=timeout) as s:
            s.settimeout(timeout)
            wire.send_frame(s, wire.MSG_FEED_SINCE, 0,
                            struct.pack("<Q", since))
            reply = wire.recv_frame(s)
        if reply.msg_type != wire.MSG_OK:
            raise wire.RemoteError(*wire.unpack_err(reply.body))
        (floor,) = struct.unpack_from("<Q", reply.body, 0)
        return floor, wire.unpack_records(reply.body, 8)

    def _is_fresh(self) -> bool:
        return (not self._feed and not self._applied and self.feed_floor == 0
                and self.last_seq == 0 and not self.store.key_sizes)

    def _bootstrap_state(self, peers: List[Tuple[str, int]],
                         timeout: float) -> int:
        """Full-state transfer for a fresh (wiped) cell facing peers
        whose feeds are truncated: for every placement this cell owns,
        copy a live replica's chunk + extent file bytes verbatim and
        install its per-key accounting, then adopt the highest peer
        floor seen.  Returns the number of placements installed.  Chunk
        files never shrink at truncation (only the feed does), so any
        replica's copy is complete regardless of its floor — and since
        they are pure functions of the record set, the copied bytes are
        exactly what replaying the full history would have produced."""
        if self.store.backend != "file":
            raise FeedTruncated(
                "fresh mem-backed cell cannot bootstrap past a truncated "
                "peer feed: full-state transfer needs the file backend")
        pulled: set = set()
        floors: List[int] = []
        installed = 0
        for host, port in peers:
            try:
                with socket.create_connection((host, port),
                                              timeout=timeout) as s:
                    s.settimeout(timeout)
                    wire.send_frame(s, wire.MSG_PLACEMENTS, 0)
                    reply = wire.recv_frame(s)
                    if reply.msg_type != wire.MSG_OK:
                        continue
                    placements = [
                        p for p in wire.unpack_placements(reply.body)
                        if p not in pulled
                        and self.node_id in replica_nodes(p[0], p[1],
                                                          self.n_cells,
                                                          self.r)]
                    for tsid, sid in placements:
                        wire.send_frame(s, wire.MSG_STATE_PULL, 0,
                                        struct.pack("<qq", tsid, sid))
                        reply = wire.recv_frame(s)
                        if reply.msg_type != wire.MSG_OK:
                            continue
                        state = wire.PlacementState.unpack(reply.body)
                        self._install_state((tsid, sid), state)
                        pulled.add((tsid, sid))
                        floors.append(state.floor)
                        installed += 1
            except (OSError, wire.WireError, struct.error):
                continue
        with self._flock:
            if floors:
                self.feed_floor = max(self.feed_floor, max(floors))
                self.ack_water = max(self.ack_water, self.feed_floor)
                self.last_seq = max([self.last_seq, self.feed_floor]
                                    + list(self._key_seq.values()))
                self._save_base_locked()
        return installed

    def _install_state(self, placement: Tuple[int, int],
                       state: wire.PlacementState) -> None:
        cpath = self.store._chunk_path(0, placement)
        epath = self.store._extent_path(0, placement)
        cpath.parent.mkdir(parents=True, exist_ok=True)
        cpath.write_bytes(state.chunk)
        if state.ext:
            epath.write_bytes(state.ext)
        self.store.drop_chunk_caches(0, placement)
        for key, raw, enc in state.sizes:
            self.store.key_sizes[key] = (raw, enc)
        for key, seq in state.key_seqs:
            if seq > self._key_seq.get(key, 0):
                self._key_seq[key] = seq

    def catch_up(self, peers: List[Tuple[str, int]],
                 timeout: float = 5.0) -> int:
        """Converge with the cluster after a restart: pull every peer's
        feed above this cell's own truncation floor, keep the records
        whose key's replica chain includes this cell and whose seq is
        not already certified applied, and apply them in seq order.
        Returns the number of records applied (feed stamps included).

        Fetching from the floor rather than from ``last_seq`` is what
        repairs *interior* gaps — a PUT this cell missed while live
        (transient timeout) below a seq it did accept would be invisible
        to a tail-only pull and would otherwise serve silently stale
        reads forever; the applied-seq set makes the refetch cheap to
        dedupe and impossible to double-apply.  The floor is a safe
        lower bound because it only ever advances past records every
        replica (including this cell) durably acked.  A peer whose own
        floor is above ours can no longer serve the records in between
        as feed entries — for a disk-surviving cell that is fine (the
        ack invariant says we already hold everything we own down
        there); a *fresh* cell instead bootstraps by full-state
        transfer first.  Unreachable peers are skipped — with r-way
        replication any single live peer of a key suffices."""
        fetched: Dict[int, wire.FeedRecord] = {}
        max_peer_floor = 0
        reachable: List[Tuple[str, int]] = []
        for host, port in peers:
            try:
                floor, recs = self._pull_feed(host, port, self.feed_floor,
                                              timeout)
            except (OSError, wire.WireError, struct.error):
                continue
            reachable.append((host, port))
            max_peer_floor = max(max_peer_floor, floor)
            for rec in recs:
                if (rec.seq > self.feed_floor
                        and rec.seq not in self._applied
                        and self._owns(rec.key)):
                    fetched.setdefault(rec.seq, rec)
        if max_peer_floor > self.feed_floor and self._is_fresh():
            self._bootstrap_state(reachable, timeout)
        n = 0
        for seq in sorted(fetched):
            applied, _ = self.apply(fetched[seq])
            n += applied
        return n

    # ---- server ----
    def start(self, peers: Optional[List[Tuple[str, int]]] = None) -> int:
        """Catch up from ``peers`` (if any), bind, and serve in
        background threads.  Returns the bound port.  A second catch-up
        pass runs after bind so records that landed on peers while this
        cell was binding are not missed."""
        if peers:
            self.catch_up(peers)
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.workers,
                thread_name_prefix=f"cell{self.node_id}-worker")
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind((self.host, self.port))
        self._lsock.listen(64)
        self.port = self._lsock.getsockname()[1]
        t = threading.Thread(target=self._accept_loop,
                             name=f"cell{self.node_id}-accept", daemon=True)
        t.start()
        self._threads.append(t)
        if peers:
            self.catch_up(peers)
        return self.port

    def stop(self) -> None:
        self._stop.set()
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
        for c in list(self._conns):
            try:
                c.close()
            except OSError:
                pass
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._lsock.accept()
            except OSError:
                return  # listen socket closed by stop()
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._conns.add(conn)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _serve_conn(self, conn: socket.socket) -> None:
        """Per-connection read loop.  Cheap liveness traffic (HELLO,
        PING) is answered inline so it can never queue behind a slow
        request; everything else is dispatched to the worker pool under
        the per-connection in-flight cap.  Replies are written under
        ``send_lock`` in completion order — out-of-order by design, the
        client demuxes by ``req_id``."""
        send_lock = threading.Lock()
        slots = threading.BoundedSemaphore(self.inflight_cap)
        reader = wire.FrameReader(conn)  # pipelined requests batch per recv
        try:
            while not self._stop.is_set():
                try:
                    frame = reader.next_frame()
                except (wire.ConnectionClosed, OSError):
                    return  # peer hung up, or stop() closed us mid-read
                except wire.WireError:
                    return  # garbage on the stream: drop the connection
                if frame.version != wire.PROTO_VERSION:
                    # answer under OUR version so the peer's codec can
                    # still read the rejection, then hang up
                    with send_lock:
                        wire.send_frame(
                            conn, wire.MSG_ERR, frame.req_id,
                            wire.pack_err(
                                wire.ERR_VERSION,
                                f"cell speaks v{wire.PROTO_VERSION}, "
                                f"client sent v{frame.version}"))
                    return
                if frame.msg_type in (wire.MSG_HELLO, wire.MSG_PING):
                    if frame.msg_type == wire.MSG_PING and len(frame.body) >= 8:
                        (water,) = struct.unpack_from("<Q", frame.body, 0)
                        self.note_ack(water)
                    reply = (wire.MSG_HELLO if frame.msg_type == wire.MSG_HELLO
                             else wire.MSG_OK)
                    try:
                        with send_lock:
                            wire.send_frame(conn, reply, frame.req_id,
                                            struct.pack("<BQ", self.node_id,
                                                        self.last_seq))
                    except OSError:
                        return
                    continue
                slots.acquire()  # in-flight cap: blocks the READ loop only
                if self._pool is None:  # direct use without start(): inline
                    self._run_request(conn, send_lock, slots, frame)
                else:
                    self._pool.submit(self._run_request, conn, send_lock,
                                      slots, frame)
        finally:
            self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _run_request(self, conn: socket.socket, send_lock: threading.Lock,
                     slots: threading.BoundedSemaphore,
                     frame: wire.Frame) -> None:
        try:
            try:
                if frame.msg_type == wire.MSG_MULTIGET:
                    self._stream_multiget(conn, send_lock, frame)
                    return
                mtype, body = self._handle(frame.msg_type, frame.body)
            except KeyMissing as e:
                mtype, body = wire.MSG_ERR, wire.pack_err(
                    wire.ERR_KEY_MISSING, str(e.args[0]))
            except FeedTruncated as e:
                mtype, body = wire.MSG_ERR, wire.pack_err(
                    wire.ERR_FEED_TRUNCATED, str(e))
            except (wire.WireError, struct.error, IndexError,
                    UnicodeDecodeError, AssertionError) as e:
                mtype, body = wire.MSG_ERR, wire.pack_err(
                    wire.ERR_BAD_REQUEST, f"{type(e).__name__}: {e}")
            except Exception as e:  # noqa: BLE001 — relay, don't die
                mtype, body = wire.MSG_ERR, wire.pack_err(
                    wire.ERR_INTERNAL, f"{type(e).__name__}: {e}")
            try:
                with send_lock:
                    wire.send_frame(conn, mtype, frame.req_id, body)
            except OSError:
                pass
        finally:
            slots.release()

    def _stream_multiget(self, conn: socket.socket,
                         send_lock: threading.Lock,
                         frame: wire.Frame) -> None:
        """MULTIGET reply stream: one CHUNK frame per found key as it is
        read (the client can decode and pool-fill immediately), END with
        the found count as the terminal frame, ERR as the terminal frame
        on a hard miss.  All frames carry the request's req_id, so the
        stream interleaves freely with other in-flight replies."""
        try:
            body = frame.body
            (n,) = struct.unpack_from("<I", body, 0)
            off = 4
            keys = []
            for _ in range(n):
                k, off = wire.unpack_key(body, off)
                keys.append(k)
            fields, off = wire.unpack_fields(body, off)
            (missing_ok,) = struct.unpack_from("<B", body, off)
        except (wire.WireError, struct.error, IndexError,
                UnicodeDecodeError) as e:
            try:
                with send_lock:
                    wire.send_frame(conn, wire.MSG_ERR, frame.req_id,
                                    wire.pack_err(wire.ERR_BAD_REQUEST,
                                                  f"{type(e).__name__}: {e}"))
            except OSError:
                pass
            return
        # CHUNK frames coalesce into one sendall per ~64 KiB — identical
        # frames on the wire, a fraction of the syscalls (and on a busy
        # box, of the scheduler switches).  A terminal ERR/END appends
        # after any buffered chunks so per-request frame order holds.
        found = 0
        pend = bytearray()
        try:
            for k in keys:
                try:
                    blob = self.store.get_encoded(k, fields)
                except KeyMissing as e:
                    if missing_ok:
                        continue
                    pend += wire.encode_frame(
                        wire.MSG_ERR, frame.req_id,
                        wire.pack_err(wire.ERR_KEY_MISSING, str(e.args[0])))
                    with send_lock:
                        conn.sendall(pend)
                    return
                except Exception as e:  # noqa: BLE001 — relay, don't die
                    pend += wire.encode_frame(
                        wire.MSG_ERR, frame.req_id,
                        wire.pack_err(wire.ERR_INTERNAL,
                                      f"{type(e).__name__}: {e}"))
                    with send_lock:
                        conn.sendall(pend)
                    return
                found += 1
                pend += wire.encode_frame(
                    wire.MSG_CHUNK, frame.req_id,
                    wire.pack_key(k) + wire.pack_blob(blob))
                if len(pend) >= (1 << 16):
                    with send_lock:
                        conn.sendall(pend)
                    pend = bytearray()
            pend += wire.encode_frame(wire.MSG_END, frame.req_id,
                                      struct.pack("<I", found))
            with send_lock:
                conn.sendall(pend)
        except OSError:
            pass

    def _handle(self, msg_type: int, body: bytes) -> Tuple[int, bytes]:
        if msg_type in (wire.MSG_HELLO, wire.MSG_PING):
            # normally answered inline by the read loop; kept here for
            # direct (non-socket) callers
            if msg_type == wire.MSG_PING and len(body) >= 8:
                (water,) = struct.unpack_from("<Q", body, 0)
                self.note_ack(water)
            reply = wire.MSG_HELLO if msg_type == wire.MSG_HELLO else wire.MSG_OK
            return reply, struct.pack("<BQ", self.node_id, self.last_seq)
        if msg_type == wire.MSG_GET:
            key, off = wire.unpack_key(body, 0)
            fields, _ = wire.unpack_fields(body, off)
            return wire.MSG_OK, self.store.get_encoded(key, fields)
        if msg_type == wire.MSG_PUT:
            key, off = wire.unpack_key(body, 0)
            seq, raw = struct.unpack_from("<QQ", body, off)
            blob, off = wire.unpack_blob(body, off + 16)
            applied, _ = self.apply(
                wire.FeedRecord(seq, wire.OP_PUT, key, raw, blob))
            if off + 8 <= len(body):  # trailing ack watermark
                (water,) = struct.unpack_from("<Q", body, off)
                self.note_ack(water)
            return wire.MSG_OK, struct.pack("<BQ", applied, self.last_seq)
        if msg_type == wire.MSG_DELETE:
            key, off = wire.unpack_key(body, 0)
            (seq,) = struct.unpack_from("<Q", body, off)
            _, existed = self.apply(
                wire.FeedRecord(seq, wire.OP_DELETE, key, 0, b""))
            if off + 16 <= len(body):  # trailing ack watermark
                (water,) = struct.unpack_from("<Q", body, off + 8)
                self.note_ack(water)
            return wire.MSG_OK, struct.pack("<BQ", existed, self.last_seq)
        if msg_type == wire.MSG_FEED_SINCE:
            (since,) = struct.unpack_from("<Q", body, 0)
            return wire.MSG_OK, (struct.pack("<Q", self.feed_floor)
                                 + wire.pack_records(self.feed_since(since)))
        if msg_type == wire.MSG_STATUS:
            s = self.store.stats
            status = {
                "node": self.node_id, "last_seq": self.last_seq,
                "n_keys": len(self.store.key_sizes),
                "live_bytes": self.store.live_bytes(),
                "backend": self.store.backend,
                "feed_len": len(self._feed),
                "feed": {"len": len(self._feed), "floor": self.feed_floor,
                         "bytes": self.feed_bytes(),
                         "ack_water": self.ack_water,
                         "truncations": self.truncations},
                "stats": {"reads": s.reads, "writes": s.writes,
                          "bytes_read": s.bytes_read,
                          "bytes_written": s.bytes_written,
                          "bytes_io": s.bytes_io},
                "maint": {
                    "running": (self._maint_thread is not None
                                and self._maint_thread.is_alive()),
                    "last_vacuum": self.last_vacuum,
                },
            }
            return wire.MSG_OK, json.dumps(status).encode()
        if msg_type == wire.MSG_KEYS:
            tsid, sid = struct.unpack_from("<qq", body, 0)
            keys = self.store.keys_for_placement(tsid, sid)
            return wire.MSG_OK, (struct.pack("<I", len(keys))
                                 + b"".join(wire.pack_key(k) for k in keys))
        if msg_type == wire.MSG_MAINT:
            # empty body: legacy "kick a vacuum".  Otherwise a flags
            # byte: bit0 vacuum (fire-and-forget, background thread),
            # bit1 truncate the feed NOW if the watermark allows
            # (synchronous — used by benches/tests to reach a
            # deterministic final feed state before comparing files)
            flags = wire.MAINT_VACUUM
            if len(body) >= 1:
                (flags,) = struct.unpack_from("<B", body, 0)
            started = False
            if flags & wire.MAINT_VACUUM:
                started = self.maintain()
            if flags & wire.MAINT_TRUNCATE:
                self.truncate_feed(force=True)
            return wire.MSG_OK, struct.pack("<B", started)
        if msg_type == wire.MSG_PLACEMENTS:
            placements = sorted({(k.tsid, k.sid)
                                 for k in self.store.key_sizes})
            return wire.MSG_OK, wire.pack_placements(placements)
        if msg_type == wire.MSG_STATE_PULL:
            if self.store.backend != "file":
                raise FeedTruncated(
                    "mem-backed cell cannot serve full-state transfer")
            tsid, sid = struct.unpack_from("<qq", body, 0)
            placement = (tsid, sid)
            with self._flock:
                cpath = self.store._chunk_path(0, placement)
                epath = self.store._extent_path(0, placement)
                chunk = cpath.read_bytes() if cpath.exists() else b""
                ext = epath.read_bytes() if epath.exists() else b""
                sizes = [(k, rw, en)
                         for k, (rw, en) in self.store.key_sizes.items()
                         if (k.tsid, k.sid) == placement]
                key_seqs = [(k, s) for k, s in self._key_seq.items()
                            if (k.tsid, k.sid) == placement]
                state = wire.PlacementState(self.feed_floor, chunk, ext,
                                            sizes, key_seqs)
            return wire.MSG_OK, state.pack()
        raise AssertionError(f"unknown message type {msg_type}")


def _parse_peers(spec: str) -> List[Tuple[str, int]]:
    out = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        host, port = part.rsplit(":", 1)
        out.append((host, int(port)))
    return out


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Run one temporal-graph storage cell.")
    ap.add_argument("--node-id", type=int, required=True)
    ap.add_argument("--n-cells", type=int, required=True)
    ap.add_argument("--replication", type=int, default=1)
    ap.add_argument("--backend", default="file", choices=("mem", "file"))
    ap.add_argument("--root", default=None,
                    help="cell data dir (chunk/extent files + feed.log)")
    ap.add_argument("--fmt", default=None, help="block format (TGI2 default)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port (0 = ephemeral, printed on READY)")
    ap.add_argument("--peers", default="",
                    help="comma-separated host:port peers for boot catch-up")
    ap.add_argument("--workers", type=int, default=4,
                    help="request worker pool size (read loops stay free)")
    ap.add_argument("--inflight-cap", type=int, default=32,
                    help="max queued+running requests per connection")
    ap.add_argument("--feed-keep", type=int, default=256,
                    help="min fully-acked backlog before feed truncation")
    args = ap.parse_args(argv)
    cell = StorageCell(node_id=args.node_id, n_cells=args.n_cells,
                       r=args.replication, backend=args.backend,
                       root=args.root, fmt=args.fmt, host=args.host,
                       port=args.port, workers=args.workers,
                       inflight_cap=args.inflight_cap,
                       feed_keep=args.feed_keep)
    port = cell.start(peers=_parse_peers(args.peers))
    print(f"CELL READY node={cell.node_id} port={port}", flush=True)
    stop = threading.Event()
    signal.signal(signal.SIGTERM, lambda *_: stop.set())
    try:
        stop.wait()
    except KeyboardInterrupt:
        pass
    cell.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
