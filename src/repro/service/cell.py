"""StorageCell: one storage node served over the wire protocol.

A cell owns one node's chunk/extent files through a private
single-node ``DeltaStore`` (m=1, r=1, no decoded-block pool — decoding
is the *client's* job; the cell ships encoded columns verbatim via
``get_encoded``/``assemble_block``, so a projected GET costs the cell
only the projected columns' file bytes).

Writes are change-feed records: the client stamps every ``put``/
``delete`` with a globally monotonic ``seq`` and fans it out to the
key's replica cells.  Each cell appends applied records to an
append-only ``feed.log`` (and an in-memory tail) — the cell's entire
write history in arrival order.  Because the client serializes writes
(one fan-out at a time), arrival order IS seq order, which makes a
cell's chunk/extent/feed files a pure function of its record set: a
killed-and-restarted cell that replays the records it missed via
``feed_since`` from its peers, in seq order, converges to
byte-identical files.  Duplicate deliveries (client retries, catch-up
racing a live write) are dropped by seq: every applied seq — including
those replayed from ``feed.log`` at boot — lives in an applied-seq
set, so catch-up can refetch the *whole* peer feed and repair interior
gaps (a transiently missed PUT below ``last_seq``), not just the tail.
A per-key max-seq guard keeps an out-of-order repair from regressing a
key past a newer applied write: the late record is stamped into the
feed (it is no longer a gap) but the store mutation is skipped.

The server is a plain threaded accept loop — one thread per
connection, blocking frame reads, every reply framed under
``wire.PROTO_VERSION`` (a mismatched client gets ERR "VERSION" and the
connection closed).  Run one per process via ``python -m
repro.service.cell`` (prints ``CELL READY node=<i> port=<p>`` for the
cluster harness) or in-process via ``LocalCluster(mode="thread")``.
"""
from __future__ import annotations

import argparse
import json
import signal
import socket
import struct
import sys
import threading
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.core import faultpoints
from repro.service import wire
from repro.storage.kvstore import (DeltaStore, KeyMissing, replica_nodes)


class StorageCell:
    def __init__(self, node_id: int, n_cells: int, r: int,
                 backend: str = "file", root: Optional[str] = None,
                 fmt: Optional[str] = None, host: str = "127.0.0.1",
                 port: int = 0):
        assert backend in ("mem", "file")
        self.node_id = node_id
        self.n_cells = n_cells
        self.r = r
        self.host = host
        self.port = port  # 0 -> ephemeral; real port known after start()
        self.root = Path(root) if root is not None else None
        if backend == "file":
            assert root is not None
            self.root.mkdir(parents=True, exist_ok=True)
        self.store = DeltaStore(m=1, r=1, backend=backend, root=root,
                                fmt=fmt, pool_bytes=0, seek=True)
        # change feed: full in-memory tail + append-only feed.log (file
        # backend).  _flock serializes apply+append so the log can never
        # disagree with the store.
        self._feed: List[wire.FeedRecord] = []
        self._flock = threading.Lock()
        # every seq this cell has ever applied (rebuilt from feed.log at
        # boot) — the dedupe that lets catch-up refetch from seq 0 and
        # repair interior gaps without double-applying anything
        self._applied: set = set()
        # per-key max applied seq: an out-of-order gap repair must never
        # regress a key past a newer write already applied
        self._key_seq: Dict[Tuple, int] = {}
        self.last_seq = 0
        self._load_feed()
        self._lsock: Optional[socket.socket] = None
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._conns: set = set()
        # background store maintenance (chunk vacuum): one pass at a
        # time, triggered by MSG_MAINT; the cell keeps serving while it
        # runs (vacuum holds the store lock per chunk only)
        self._maint_lock = threading.Lock()
        self._maint_thread: Optional[threading.Thread] = None
        self.last_vacuum: Optional[Dict] = None

    # ---- feed persistence ----
    def _feed_path(self) -> Optional[Path]:
        return None if self.root is None else self.root / "feed.log"

    def _load_feed(self) -> None:
        """Boot: rebuild ``last_seq``, the applied-seq set, the per-key
        seq watermarks, and the store's size accounting from
        ``feed.log``.  The chunk/extent files already hold the data (the
        store's file backend persists), so records are NOT re-applied —
        only the bookkeeping is replayed.

        The feed append in ``apply`` is not atomic and cells are killed
        with SIGKILL, so a torn last record is an expected crash
        artifact: any record that fails to decode is treated as the torn
        tail — the log is truncated back to the last whole record and
        catch-up refetches whatever the lost suffix held."""
        path = self._feed_path()
        if path is None or not path.exists():
            return
        data = path.read_bytes()
        off = 0
        good = 0  # byte offset of the last cleanly decoded record's end
        while off < len(data):
            try:
                rec, off = wire.FeedRecord.unpack(data, off)
            except (wire.WireError, struct.error, IndexError,
                    UnicodeDecodeError):
                with open(path, "r+b") as f:  # torn tail: drop it
                    f.truncate(good)
                break
            good = off
            self._feed.append(rec)
            self._applied.add(rec.seq)
            self.last_seq = max(self.last_seq, rec.seq)
            if rec.seq >= self._key_seq.get(rec.key, 0):
                self._key_seq[rec.key] = rec.seq
                if rec.op == wire.OP_PUT:
                    self.store.key_sizes[rec.key] = (rec.raw_bytes,
                                                     len(rec.blob))
                else:
                    self.store.key_sizes.pop(rec.key, None)

    def _owns(self, key) -> bool:
        return self.node_id in replica_nodes(key.tsid, key.sid,
                                             self.n_cells, self.r)

    def apply(self, rec: wire.FeedRecord) -> Tuple[bool, bool]:
        """Apply one feed record (a wire PUT/DELETE, a catch-up replay,
        or a client gap redelivery); returns ``(applied, existed)``.
        Duplicates — client retries after a lost ack, catch-up
        overlapping a live write — are detected against the full
        applied-seq set (which survives restarts via ``feed.log``) and
        acked without touching the store, so a record can never
        double-append to the chunk files.  A record older than the key's
        newest applied write (an interior-gap repair arriving after the
        writes that superseded it) is stamped into the feed — the seq is
        no longer a gap, and peers replicating this feed dedupe it the
        same way — but the store mutation is skipped so the key never
        regresses to a stale version."""
        # crash point for the service fault suite: REPRO_FAULTPOINTS=
        # "cell.apply=N:kill" SIGKILLs this cell on its Nth applied
        # record — mid write storm, before the mutation lands
        faultpoints.fire("cell.apply")
        with self._flock:
            if rec.seq in self._applied:
                return False, False
            if rec.seq >= self._key_seq.get(rec.key, 0):
                self._key_seq[rec.key] = rec.seq
                if rec.op == wire.OP_PUT:
                    self.store.put_encoded(rec.key, rec.blob, rec.raw_bytes)
                    existed = True
                else:
                    existed = self.store.delete(rec.key)
            else:
                existed = False  # superseded: recorded, not applied
            self._feed.append(rec)
            self._applied.add(rec.seq)
            self.last_seq = max(self.last_seq, rec.seq)
            path = self._feed_path()
            if path is not None:
                with open(path, "ab") as f:
                    f.write(rec.pack())
            return True, existed

    def feed_since(self, seq: int) -> List[wire.FeedRecord]:
        with self._flock:
            return [r for r in self._feed if r.seq > seq]

    # ---- background maintenance ----
    def maintain(self) -> bool:
        """Kick a background vacuum of the store's chunk files (reclaim
        tombstoned/superseded records).  Returns whether a new pass was
        started (False: one is already running).  The cell never refuses
        traffic during the pass — ``DeltaStore.vacuum`` holds the store
        lock per chunk and readers retry across rewrites."""
        with self._maint_lock:
            if self._maint_thread is not None and self._maint_thread.is_alive():
                return False
            t = threading.Thread(target=self._maint_pass,
                                 name=f"cell{self.node_id}-maint",
                                 daemon=True)
            self._maint_thread = t
            t.start()
            return True

    def _maint_pass(self) -> None:
        try:
            self.last_vacuum = self.store.vacuum()
        except Exception:  # noqa: BLE001 — maintenance must not kill serving
            self.last_vacuum = None

    # ---- replica catch-up ----
    def catch_up(self, peers: List[Tuple[str, int]],
                 timeout: float = 5.0) -> int:
        """Converge with the cluster after a restart: pull every peer's
        FULL feed (``feed_since(0)``), keep the records whose key's
        replica chain includes this cell and whose seq is not already in
        the applied set, and apply them in seq order.  Returns the
        number of records applied.  Fetching from 0 rather than from
        ``last_seq`` is what repairs *interior* gaps — a PUT this cell
        missed while live (transient timeout) below a seq it did accept
        would be invisible to a tail-only pull and would otherwise serve
        silently stale reads forever; the applied-seq set makes the full
        refetch cheap to dedupe and impossible to double-apply.
        Unreachable peers are skipped — with r-way replication any
        single live peer of a key suffices."""
        fetched: Dict[int, wire.FeedRecord] = {}
        for host, port in peers:
            try:
                with socket.create_connection((host, port),
                                              timeout=timeout) as s:
                    s.settimeout(timeout)
                    wire.send_frame(s, wire.MSG_FEED_SINCE, 0,
                                    struct.pack("<Q", 0))
                    reply = wire.recv_frame(s)
                if reply.msg_type != wire.MSG_OK:
                    continue
                for rec in wire.unpack_records(reply.body):
                    if rec.seq not in self._applied and self._owns(rec.key):
                        fetched.setdefault(rec.seq, rec)
            except (OSError, wire.WireError):
                continue
        n = 0
        for seq in sorted(fetched):
            applied, _ = self.apply(fetched[seq])
            n += applied
        return n

    # ---- server ----
    def start(self, peers: Optional[List[Tuple[str, int]]] = None) -> int:
        """Catch up from ``peers`` (if any), bind, and serve in
        background threads.  Returns the bound port.  A second catch-up
        pass runs after bind so records that landed on peers while this
        cell was binding are not missed."""
        if peers:
            self.catch_up(peers)
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind((self.host, self.port))
        self._lsock.listen(64)
        self.port = self._lsock.getsockname()[1]
        t = threading.Thread(target=self._accept_loop,
                             name=f"cell{self.node_id}-accept", daemon=True)
        t.start()
        self._threads.append(t)
        if peers:
            self.catch_up(peers)
        return self.port

    def stop(self) -> None:
        self._stop.set()
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
        for c in list(self._conns):
            try:
                c.close()
            except OSError:
                pass

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._lsock.accept()
            except OSError:
                return  # listen socket closed by stop()
            self._conns.add(conn)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                try:
                    frame = wire.recv_frame(conn)
                except wire.ConnectionClosed:
                    return
                except wire.WireError:
                    return  # garbage on the stream: drop the connection
                if frame.version != wire.PROTO_VERSION:
                    # answer under OUR version so the peer's codec can
                    # still read the rejection, then hang up
                    wire.send_frame(
                        conn, wire.MSG_ERR, frame.req_id,
                        wire.pack_err(wire.ERR_VERSION,
                                      f"cell speaks v{wire.PROTO_VERSION}, "
                                      f"client sent v{frame.version}"))
                    return
                try:
                    mtype, body = self._handle(frame.msg_type, frame.body)
                except KeyMissing as e:
                    mtype, body = wire.MSG_ERR, wire.pack_err(
                        wire.ERR_KEY_MISSING, str(e.args[0]))
                except (wire.WireError, struct.error, IndexError,
                        UnicodeDecodeError, AssertionError) as e:
                    mtype, body = wire.MSG_ERR, wire.pack_err(
                        wire.ERR_BAD_REQUEST, f"{type(e).__name__}: {e}")
                except Exception as e:  # noqa: BLE001 — relay, don't die
                    mtype, body = wire.MSG_ERR, wire.pack_err(
                        wire.ERR_INTERNAL, f"{type(e).__name__}: {e}")
                try:
                    wire.send_frame(conn, mtype, frame.req_id, body)
                except OSError:
                    return
        finally:
            self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _handle(self, msg_type: int, body: bytes) -> Tuple[int, bytes]:
        if msg_type in (wire.MSG_HELLO, wire.MSG_PING):
            reply = wire.MSG_HELLO if msg_type == wire.MSG_HELLO else wire.MSG_OK
            return reply, struct.pack("<BQ", self.node_id, self.last_seq)
        if msg_type == wire.MSG_GET:
            key, off = wire.unpack_key(body, 0)
            fields, _ = wire.unpack_fields(body, off)
            return wire.MSG_OK, self.store.get_encoded(key, fields)
        if msg_type == wire.MSG_MULTIGET:
            (n,) = struct.unpack_from("<I", body, 0)
            off = 4
            keys = []
            for _ in range(n):
                k, off = wire.unpack_key(body, off)
                keys.append(k)
            fields, off = wire.unpack_fields(body, off)
            (missing_ok,) = struct.unpack_from("<B", body, off)
            found = []
            for k in keys:
                try:
                    found.append((k, self.store.get_encoded(k, fields)))
                except KeyMissing:
                    if not missing_ok:
                        raise
            out = [struct.pack("<I", len(found))]
            for k, blob in found:
                out.append(wire.pack_key(k))
                out.append(wire.pack_blob(blob))
            return wire.MSG_OK, b"".join(out)
        if msg_type == wire.MSG_PUT:
            key, off = wire.unpack_key(body, 0)
            seq, raw = struct.unpack_from("<QQ", body, off)
            blob, _ = wire.unpack_blob(body, off + 16)
            applied, _ = self.apply(
                wire.FeedRecord(seq, wire.OP_PUT, key, raw, blob))
            return wire.MSG_OK, struct.pack("<B", applied)
        if msg_type == wire.MSG_DELETE:
            key, off = wire.unpack_key(body, 0)
            (seq,) = struct.unpack_from("<Q", body, off)
            _, existed = self.apply(
                wire.FeedRecord(seq, wire.OP_DELETE, key, 0, b""))
            return wire.MSG_OK, struct.pack("<B", existed)
        if msg_type == wire.MSG_FEED_SINCE:
            (since,) = struct.unpack_from("<Q", body, 0)
            return wire.MSG_OK, wire.pack_records(self.feed_since(since))
        if msg_type == wire.MSG_STATUS:
            s = self.store.stats
            status = {
                "node": self.node_id, "last_seq": self.last_seq,
                "n_keys": len(self.store.key_sizes),
                "live_bytes": self.store.live_bytes(),
                "backend": self.store.backend,
                "feed_len": len(self._feed),
                "stats": {"reads": s.reads, "writes": s.writes,
                          "bytes_read": s.bytes_read,
                          "bytes_written": s.bytes_written,
                          "bytes_io": s.bytes_io},
                "maint": {
                    "running": (self._maint_thread is not None
                                and self._maint_thread.is_alive()),
                    "last_vacuum": self.last_vacuum,
                },
            }
            return wire.MSG_OK, json.dumps(status).encode()
        if msg_type == wire.MSG_KEYS:
            tsid, sid = struct.unpack_from("<qq", body, 0)
            keys = self.store.keys_for_placement(tsid, sid)
            return wire.MSG_OK, (struct.pack("<I", len(keys))
                                 + b"".join(wire.pack_key(k) for k in keys))
        if msg_type == wire.MSG_MAINT:
            # fire-and-forget: the pass runs on a background thread so
            # the cell answers (and keeps serving) immediately
            started = self.maintain()
            return wire.MSG_OK, struct.pack("<B", started)
        raise AssertionError(f"unknown message type {msg_type}")


def _parse_peers(spec: str) -> List[Tuple[str, int]]:
    out = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        host, port = part.rsplit(":", 1)
        out.append((host, int(port)))
    return out


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Run one temporal-graph storage cell.")
    ap.add_argument("--node-id", type=int, required=True)
    ap.add_argument("--n-cells", type=int, required=True)
    ap.add_argument("--replication", type=int, default=1)
    ap.add_argument("--backend", default="file", choices=("mem", "file"))
    ap.add_argument("--root", default=None,
                    help="cell data dir (chunk/extent files + feed.log)")
    ap.add_argument("--fmt", default=None, help="block format (TGI2 default)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port (0 = ephemeral, printed on READY)")
    ap.add_argument("--peers", default="",
                    help="comma-separated host:port peers for boot catch-up")
    args = ap.parse_args(argv)
    cell = StorageCell(node_id=args.node_id, n_cells=args.n_cells,
                       r=args.replication, backend=args.backend,
                       root=args.root, fmt=args.fmt, host=args.host,
                       port=args.port)
    port = cell.start(peers=_parse_peers(args.peers))
    print(f"CELL READY node={cell.node_id} port={port}", flush=True)
    stop = threading.Event()
    signal.signal(signal.SIGTERM, lambda *_: stop.set())
    try:
        stop.wait()
    except KeyboardInterrupt:
        pass
    cell.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
