"""RemoteDeltaStore: the local ``DeltaStore`` surface over wire cells.

A drop-in store whose ``m`` nodes are ``StorageCell`` servers reached
over sockets — ``TGI``, the PlanExecutor fetch stage, and the
decoded-block pool run on top of it unchanged, because everything
above the physical-I/O layer is *inherited*: placement, replica
failover, the pool preamble, projection, and stats all come from
``DeltaStore``; this class only swaps dict/file reads for wire frames.

Read path: ``_read_columns`` issues one GET per key (fields pushed
through the wire, so the cell preads only the projected columns) and
decodes the TGI2 reply client-side — a reply that fails its per-column
crc32 raises ``BlockCorruption``, which the inherited ``get`` treats
as a dead replica and fails over, extending corrupt-replica failover
across the process boundary.  ``_group_fetch`` batches each multiget
group into one MULTIGET frame per replica tier; a group whose primary
cell is known-unavailable is hedged straight to the fallback replicas
(``StoreStats.hedged_reads``).  Requests carry a per-request timeout
and bounded-backoff retries; a cell that stays unreachable is marked
*suspect* for ``suspect_ttl`` seconds so subsequent reads skip it
without paying the timeout again, then re-probed.

Write path: every ``put``/``delete`` is stamped with a globally
monotonic ``seq`` and fanned out to the key's replica cells while the
writer lock is held — writes are serialized, so every cell receives
its records in seq order, which is what makes change-feed catch-up
(``StorageCell.catch_up``) converge to byte-identical files.  A write
(put OR delete) succeeds only when at least one replica cell accepted
it — otherwise it raises ``StorageNodeDown`` with the local accounting
untouched.  A replica that missed an acknowledged write (down,
suspect, or a transient failure) gets the record queued on a per-node
*redelivery queue*: the queue is drained, in seq order, before that
node serves any further read or receives any further write from this
client, so a cell with an interior feed gap this client created can
never serve it a stale version — and a restarting cell additionally
repairs gaps from any writer via the full-feed ``catch_up`` pull.

Attaching requires every cell to answer a PING: the write seq resumes
from the cluster-wide high-water mark, and a cell that is unreachable
at attach time could be the only holder of the newest seqs — stamping
over them would be silently dropped by the cells' dedupe.  Pass
``require_full_attach=False`` to accept that risk explicitly (e.g. a
read-only session against a degraded cluster).
"""
from __future__ import annotations

import socket
import struct
import threading
import time
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.service import wire
from repro.storage import serialize
from repro.storage.kvstore import (DEFAULT_POOL_BYTES, BlockCorruption,
                                   DeltaKey, DeltaStore, KeyMissing,
                                   NodeUnavailable, ReadSizes,
                                   StorageNodeDown, replica_nodes)


class RemoteDeltaStore(DeltaStore):
    def __init__(self, addrs: List[Tuple[str, int]], r: int = 1,
                 fmt: Optional[str] = None,
                 pool_bytes: int = DEFAULT_POOL_BYTES,
                 timeout: float = 5.0, retries: int = 2,
                 backoff: float = 0.05, suspect_ttl: float = 2.0,
                 require_full_attach: bool = True):
        super().__init__(m=len(addrs), r=r, backend="mem", fmt=fmt,
                         pool_bytes=pool_bytes)
        self.backend = "remote"
        self.addrs = list(addrs)
        self.timeout = timeout
        self.retries = retries
        self.backoff = backoff
        self.suspect_ttl = suspect_ttl
        self._suspects: Dict[int, float] = {}
        self._conns: List[List[socket.socket]] = [[] for _ in addrs]
        self._conn_lock = threading.Lock()
        self._req_id = 0
        self._wlock = threading.Lock()
        # per-node redelivery queues: (seq, msg_type, body) of replica
        # writes that node missed, drained in seq order before the node
        # serves any further read/write from this client (gap repair)
        self._pending: List[List[Tuple[int, int, bytes]]] = [[] for _ in addrs]
        # resume the global write sequence from the cluster's high-water
        # mark, so a fresh client attaching can never stamp a seq the
        # feeds have already seen (which dedupe would silently drop).
        # The mark is only trustworthy if EVERY cell answered — an
        # unreachable cell could be the sole holder of the newest seqs.
        self._seq = 0
        unreachable: List[int] = []
        for i in range(self.m):
            try:
                _, last_seq = struct.unpack(
                    "<BQ", self._request(i, wire.MSG_PING, b"", retries=0))
                self._seq = max(self._seq, last_seq)
            except NodeUnavailable:
                unreachable.append(i)
                self._mark_unavailable(i)
        if unreachable and require_full_attach:
            self.close()
            raise StorageNodeDown(
                f"cells {unreachable} unreachable at attach: the write-seq "
                f"high-water mark cannot be resumed safely (pass "
                f"require_full_attach=False for a degraded attach)")

    # ---- connection pool ----
    def _dial(self, node: int) -> socket.socket:
        sock = socket.create_connection(self.addrs[node],
                                        timeout=self.timeout)
        sock.settimeout(self.timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        wire.send_frame(sock, wire.MSG_HELLO, 0)
        reply = wire.recv_frame(sock)
        if reply.msg_type == wire.MSG_ERR:
            code, msg = wire.unpack_err(reply.body)
            sock.close()
            if code == wire.ERR_VERSION:
                raise wire.ProtocolMismatch(msg)
            raise wire.RemoteError(code, msg)
        if reply.msg_type != wire.MSG_HELLO:
            sock.close()
            raise wire.FrameError(
                f"expected HELLO reply, got type {reply.msg_type}")
        return sock

    def _checkout(self, node: int) -> socket.socket:
        with self._conn_lock:
            if self._conns[node]:
                return self._conns[node].pop()
        return self._dial(node)

    def _checkin(self, node: int, sock: socket.socket) -> None:
        with self._conn_lock:
            self._conns[node].append(sock)

    def close(self) -> None:
        with self._conn_lock:
            for stack in self._conns:
                while stack:
                    try:
                        stack.pop().close()
                    except OSError:
                        pass

    # ---- request/reply with timeout, retry, bounded backoff ----
    def _request(self, node: int, msg_type: int, body: bytes,
                 retries: Optional[int] = None) -> bytes:
        """One request to one cell.  Transport failures (connect/read
        timeout, reset, torn or corrupt frame) are retried with bounded
        exponential backoff, then surface as ``NodeUnavailable`` — the
        caller fails over.  Server-relayed errors (ERR frames) are not
        retried: the cell is alive, the request itself failed."""
        retries = self.retries if retries is None else retries
        delay = self.backoff
        last: Exception = NodeUnavailable(f"cell {node}")
        for _ in range(retries + 1):
            sock = None
            try:
                sock = self._checkout(node)
                with self._lock:
                    self._req_id += 1
                    req_id = self._req_id
                wire.send_frame(sock, msg_type, req_id, body)
                reply = wire.recv_frame(sock)
                if reply.req_id != req_id:
                    raise wire.FrameError("reply req_id mismatch")
                if reply.msg_type == wire.MSG_ERR:
                    code, msg = wire.unpack_err(reply.body)
                    self._checkin(node, sock)
                    if code == wire.ERR_VERSION:
                        raise wire.ProtocolMismatch(msg)
                    if code == wire.ERR_KEY_MISSING:
                        raise KeyMissing(msg)
                    raise wire.RemoteError(code, msg)
                self._checkin(node, sock)
                return reply.body
            except (wire.ProtocolMismatch, wire.RemoteError, KeyMissing):
                raise
            except (OSError, wire.WireError) as e:
                if sock is not None:
                    try:
                        sock.close()
                    except OSError:
                        pass
                last = e
                time.sleep(delay)
                delay = min(delay * 2, 1.0)
        raise NodeUnavailable(
            f"cell {node} @ {self.addrs[node]}: {last}") from last

    # ---- node health (suspect set with re-probe TTL) ----
    def _health_ok(self, i: int) -> bool:
        """Pure reachability check: not down, not a live suspect.  Safe
        to call while holding ``_wlock`` (no side effects beyond TTL
        expiry of the suspect mark)."""
        if i in self.down:
            return False
        t = self._suspects.get(i)
        if t is None:
            return True
        if time.monotonic() - t > self.suspect_ttl:
            self._suspects.pop(i, None)  # TTL over: re-probe the cell
            return True
        return False

    def _node_ok(self, i: int) -> bool:
        """The routing gate the (inherited) read paths consult.  On top
        of reachability, a node with queued redeliveries is *gap-known*:
        it missed acknowledged writes, so a read routed there could
        return a stale version with a valid crc — no failover would
        trigger.  Drain the queue first; if the node still can't take
        the backlog, treat it as unavailable and let the read fail over
        to a replica that has the writes."""
        if not self._health_ok(i):
            return False
        if self._pending[i]:
            with self._wlock:
                if self._pending[i] and not self._drain_pending(i):
                    return False
        return True

    def _mark_unavailable(self, i: int) -> None:
        self._suspects[i] = time.monotonic()

    def _drain_pending(self, node: int) -> bool:
        """Redeliver ``node``'s queued writes in seq order; True when
        the queue is empty.  Caller holds ``_wlock`` — the drain must
        serialize with live writes so the node keeps seeing seqs in
        order.  A failed redelivery re-marks the node suspect and keeps
        the rest of the queue (including on RemoteError: dropping a
        record would silently re-open the gap; restart catch-up remains
        the backstop for a persistently failing cell)."""
        q = self._pending[node]
        while q:
            _seq, mtype, body = q[0]
            try:
                self._request(node, mtype, body)
            except NodeUnavailable:
                self._mark_unavailable(node)
                return False
            except wire.RemoteError:
                return False
            q.pop(0)
            with self._lock:
                self.stats.redelivered += 1
        return True

    # ---- physical I/O overrides (everything above is inherited) ----
    def _read_columns(self, node: int, key: DeltaKey,
                      fields: Optional[Tuple[str, ...]],
                      ) -> Tuple[Dict[str, np.ndarray], int, int]:
        flist = None if fields is None else list(fields)
        body = wire.pack_key(key) + wire.pack_fields(flist)
        blob = self._request(node, wire.MSG_GET, body)
        # the reply IS a TGI2 block: per-column crc32 verified on decode
        # (BlockCorruption -> inherited get() fails over to next replica)
        arrays, enc_read, raw_read = serialize.loads_sized(blob, fields=flist)
        self._pool_dir_fill(key, blob)
        return arrays, enc_read, raw_read

    def _fan_out(self, key: DeltaKey, seq: int, msg_type: int,
                 body: bytes) -> List[bytes]:
        """Send one stamped record to every replica cell of ``key``
        (caller holds ``_wlock``).  A reachable node first drains its
        redelivery backlog so it keeps receiving seqs in order; a node
        that is suspect or fails gets the record queued for redelivery
        instead.  Returns the replies of the cells that acked — if NONE
        did, the write failed: nothing is queued (a record the caller
        saw fail must not materialize later) and ``StorageNodeDown`` is
        raised."""
        acked: List[bytes] = []
        missed: List[int] = []
        for node in self.replicas(key):
            if self._health_ok(node) and self._drain_pending(node):
                try:
                    acked.append(self._request(node, msg_type, body))
                    continue
                except NodeUnavailable:
                    self._mark_unavailable(node)
            missed.append(node)
        if not acked:
            raise StorageNodeDown(f"all replica cells down for {key}")
        for node in missed:
            self._pending[node].append((seq, msg_type, body))
        return acked

    def put_encoded(self, key: DeltaKey, blob: bytes, raw_bytes: int):
        with self._wlock:
            self._seq += 1
            body = (wire.pack_key(key)
                    + struct.pack("<QQ", self._seq, raw_bytes)
                    + wire.pack_blob(blob))
            self._fan_out(key, self._seq, wire.MSG_PUT, body)
        if self.pool is not None:
            self.pool.invalidate(key)
        with self._lock:
            self.stats.writes += 1
            self.stats.bytes_written += len(blob) * self.r
            self.stats.bytes_raw_written += raw_bytes * self.r
            self.key_sizes[key] = (raw_bytes, len(blob))

    def delete(self, key: DeltaKey) -> bool:
        """Like ``put_encoded``, a delete must be acked by at least one
        replica cell — otherwise no DELETE record exists in any feed
        (the seq would be a permanent gap and the key would stay live on
        the cluster), so it raises ``StorageNodeDown`` with the local
        accounting untouched instead of silently 'succeeding'."""
        with self._wlock:
            self._seq += 1
            body = wire.pack_key(key) + struct.pack("<Q", self._seq)
            replies = self._fan_out(key, self._seq, wire.MSG_DELETE, body)
            existed = any(bool(rep[0]) for rep in replies)
        if self.pool is not None:
            self.pool.invalidate(key)
        with self._lock:
            sizes = self.key_sizes.pop(key, None)
            if sizes is not None:
                self.stats.n_deletes += 1
                self.stats.bytes_deleted += sizes[1] * self.r
        return existed or sizes is not None

    def _group_fetch(self, primary: int, gkeys: List[DeltaKey],
                     fields: Optional[Iterable[str]], missing_ok: bool,
                     sizes: Optional[Dict[DeltaKey, ReadSizes]],
                     ) -> Dict[DeltaKey, Dict]:
        """One MULTIGET frame per replica tier for a whole primary-node
        group.  Keys with pooled state go through the inherited per-key
        ``get`` (it merges pool hits with a partial fetch); cold keys
        ride the batch.  An unavailable tier redirects the *remaining
        batch* to the next replica in one frame — the hedged path."""
        out: Dict[DeltaKey, Dict] = {}
        batch: List[DeltaKey] = []
        for k in gkeys:
            if self.pool is not None and self.pool.dir_get(k) is not None:
                try:
                    out[k] = self.get(k, fields=fields, sizes=sizes)
                except KeyMissing:
                    if not missing_ok:
                        raise
            else:
                batch.append(k)
        if not batch:
            return out
        if not self._node_ok(primary):
            with self._lock:
                self.stats.hedged_reads += len(batch)
        flist = None if fields is None else list(fields)
        pending = batch
        reachable = False
        for j, node in enumerate(self.replicas(batch[0])):
            if not pending:
                break
            if not self._node_ok(node):
                if j > 0 or self.r == 1:
                    with self._lock:
                        self.stats.failovers += len(pending)
                continue
            req = [struct.pack("<I", len(pending))]
            req += [wire.pack_key(k) for k in pending]
            req.append(wire.pack_fields(flist))
            req.append(struct.pack("<B", 1))  # found-subset reply; the
            # client decides missing vs try-next-replica
            try:
                reply = self._request(node, wire.MSG_MULTIGET, b"".join(req))
            except NodeUnavailable:
                self._mark_unavailable(node)
                with self._lock:
                    self.stats.failovers += len(pending)
                continue
            reachable = True
            (n,) = struct.unpack_from("<I", reply, 0)
            off = 4
            got: Dict[DeltaKey, bytes] = {}
            for _ in range(n):
                k, off = wire.unpack_key(reply, off)
                blob, off = wire.unpack_blob(reply, off)
                got[k] = blob
            still: List[DeltaKey] = []
            for k in pending:
                blob = got.get(k)
                if blob is None:
                    still.append(k)  # not on this tier: try the next
                    continue
                try:
                    arrays, enc_read, raw_read = serialize.loads_sized(
                        blob, fields=flist)
                except BlockCorruption:
                    with self._lock:
                        self.stats.failovers += 1
                    still.append(k)
                    continue
                self._pool_dir_fill(k, blob)
                with self._lock:
                    self.stats.reads += 1
                    self.stats.bytes_read += enc_read
                    self.stats.bytes_decompressed += raw_read
                    if self.pool is not None:
                        self.stats.pool_misses += len(arrays)
                    if j > 0:
                        self.stats.failovers += 1
                if self.pool is not None:
                    for name, a in arrays.items():
                        self.pool.put(k, name, a)
                if sizes is not None:
                    sizes[k] = ReadSizes(enc_read, raw_read, 0, 0)
                out[k] = arrays
            pending = still
        if pending:
            if not reachable:
                raise StorageNodeDown(
                    f"no live replica cell for {pending[0]}")
            if not missing_ok:
                raise KeyMissing(pending[0])
        return out

    def keys_for_placement(self, tsid: int, sid: int) -> List[DeltaKey]:
        body = struct.pack("<qq", tsid, sid)
        last: Exception = StorageNodeDown(
            f"no live replica cell for placement ({tsid}, {sid})")
        for node in replica_nodes(tsid, sid, self.m, self.r):
            if not self._node_ok(node):
                continue
            try:
                reply = self._request(node, wire.MSG_KEYS, body)
            except NodeUnavailable as e:
                self._mark_unavailable(node)
                last = e
                continue
            (n,) = struct.unpack_from("<I", reply, 0)
            off = 4
            out = []
            for _ in range(n):
                k, off = wire.unpack_key(reply, off)
                out.append(k)
            return out
        raise StorageNodeDown(str(last))

    def node_status(self) -> Dict:
        """The shared cluster-health shape, with liveness *probed*: each
        cell answers a PING (one attempt) so "up" reflects the cluster
        as it is now, not just the suspect cache."""
        for i in range(self.m):
            try:
                self._request(i, wire.MSG_PING, b"", retries=0)
                self._suspects.pop(i, None)
            except (NodeUnavailable, wire.WireError):
                self._mark_unavailable(i)
        return super().node_status()

    def cell_status(self, node: int) -> Dict:
        """Server-side view of one cell (its own stats/feed/last_seq) —
        the bench asserts server-measured ``bytes_io`` through this."""
        import json
        return json.loads(self._request(node, wire.MSG_STATUS, b""))

    def maintain(self, node: int) -> bool:
        """Ask one cell to run a background vacuum pass (MSG_MAINT).
        The cell acks immediately and keeps serving while the pass runs;
        returns whether a new pass was started (False: already running).
        Progress/results surface in ``cell_status(node)["maint"]``."""
        reply = self._request(node, wire.MSG_MAINT, b"")
        (started,) = struct.unpack_from("<B", reply, 0)
        return bool(started)

    def report_snapshot(self) -> Dict:
        """One-copy storage accounting (see the base class), with the
        node section swapped for the *probed* cluster health — remote
        liveness is a cell property, not derivable from the client's
        write-accounting mirror."""
        snap = super().report_snapshot()
        snap["node_status"] = self.node_status()
        return snap
