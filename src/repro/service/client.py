"""RemoteDeltaStore: the local ``DeltaStore`` surface over wire cells.

A drop-in store whose ``m`` nodes are ``StorageCell`` servers reached
over sockets — ``TGI``, the PlanExecutor fetch stage, and the
decoded-block pool run on top of it unchanged, because everything
above the physical-I/O layer is *inherited*: placement, replica
failover, the pool preamble, projection, and stats all come from
``DeltaStore``; this class only swaps dict/file reads for wire frames.

**Transport: a per-node connection multiplexer.**  Each node gets one
socket (dialed lazily, HELLO handshake once per connection) shared by
every concurrent request: a background reader thread demuxes reply
frames to waiting futures by ``req_id``, so replies complete out of
order and a slow GET never head-of-line-blocks a PING.  In-flight
requests per node are bounded by a window semaphore (backpressure: a
submitter blocks, within its deadline, until a slot frees).  Deadlines
are wall-clock from *enqueue* — queue wait, connect, send, and reply
all spend the same budget — and an expired request cancels its future
WITHOUT poisoning the connection: the late reply is drained and
dropped by the reader, the slot frees on that terminal frame, and
every other in-flight request proceeds untouched.  A dead connection
fails all its pending futures with ``NodeUnavailable``; the request
wrapper transparently re-dials and re-issues *idempotent* requests
only (GET/MULTIGET/PING/STATUS/KEYS/FEED_SINCE/...) with bounded
backoff — writes fail loudly after one attempt and rely on the
seq-dedup'd redelivery queue, never on silent transport replays.
Idle connections (mux and the serial fallback pool) are reaped after
``idle_ttl``.  Pass ``pipeline=False`` for the pre-multiplexer
behavior: one checked-out connection per request — kept as the bench
baseline and as a fallback.

Read path: ``_read_columns`` issues one GET per key (fields pushed
through the wire, so the cell preads only the projected columns) and
decodes the TGI2 reply client-side — a reply that fails its per-column
crc32 raises ``BlockCorruption``, which the inherited ``get`` treats
as a dead replica and fails over, extending corrupt-replica failover
across the process boundary.  ``multiget`` fans out every replica-tier
group *concurrently* across nodes on the muxes — hedged reads ride the
same futures — and consumes the streamed CHUNK replies as they arrive,
decoding and filling the BlockPool while the cells are still reading
later keys.  A cell that stays unreachable is marked *suspect* for
``suspect_ttl`` seconds so subsequent reads skip it without paying the
timeout again, then re-probed.

Write path: **lease-fenced multi-writer**.  Before its first write the
client acquires a time-bounded *writer lease* from a cell quorum
(``m//2 + 1`` grants): a monotonic **fencing epoch** that names this
writer incarnation's *lane*.  Every ``put``/``delete`` is stamped with
a *vseq* — ``(epoch, seq)`` packed into one u64 — and fanned out to
the key's replica cells while the writer lock is held; within a lane
seqs are monotone, so every cell receives this writer's records in
order, and across lanes the u64 vseq order is the cluster-wide total
order that makes N concurrent writers' feeds merge deterministically
(restart catch-up stays byte-identical).  Accepted writes double as
the lease heartbeat; a background thread renews explicitly every
``lease_ttl/3`` so an idle writer stays live.  A cell that has sealed
the lane (this writer was presumed dead and reconciled away) rejects
the write with the typed ``LeaseFenced`` — never silently applied —
and the client invalidates its lease and re-acquires a fresh epoch for
the next write.  When no quorum is reachable the client **degrades to
read-only**: writes raise the typed ``WriteUnavailable`` *immediately*
(no network attempt, no hang) while reads keep failing over, and the
renewal thread re-acquires automatically once a quorum returns.

A write (put OR delete) succeeds only when at least one replica cell
accepted it — otherwise it raises ``StorageNodeDown`` with the local
accounting untouched.  A replica that missed an acknowledged write
(down, suspect, or a transient failure) gets the record queued on a
per-node *redelivery queue*: the queue is drained, in vseq order,
before that node serves any further read or receives any further write
from this client, so a cell with an interior feed gap this client
created can never serve it a stale version — and a restarting cell
additionally repairs gaps from any writer via the feed ``catch_up``
pull.  A queued record whose lane got sealed in the meantime is
dropped at drain time (``fence_drops``): the reconciliation that
sealed the lane already anti-entropied the records that mattered.

Every write and ``quiesce`` piggybacks the client's *ack watermark* —
the highest own-lane vseq below which no redelivery is queued, i.e.
every cell provably holds everything it owns — which is what lets
cells truncate ``feed.log`` per lane (see ``StorageCell``).  A
hard-killed writer obviously stops acking; its lane's floor is
un-stranded by lease-expiry reconciliation instead.  ``close()``
releases the lease cleanly (sealing the lane at its final seq) when
every own-lane redelivery has drained, so well-behaved exits don't
wait out the TTL.

Attach is read-only and lazy: no probe, no seq resume — a fresh epoch
starts its lane at seq 0, so nothing this writer stamps can collide
with history.  Transport retries across the client (mux redial, serial
fallback, lease acquisition) share one jittered ``Backoff`` helper
with per-call deadline caps.

With ``auth_key`` set, every dialed connection answers the cell's
HELLO challenge with ``HMAC-SHA256(key, nonce)`` before any other
frame; a wrong or missing key surfaces as the typed ``AuthFailed``
(never retried, never wrapped into ``NodeUnavailable``).
"""
from __future__ import annotations

import hashlib
import hmac
import random
import socket
import struct
import threading
import time
import uuid
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.service import wire
from repro.storage import serialize
from repro.storage.kvstore import (DEFAULT_POOL_BYTES, BlockCorruption,
                                   DeltaKey, DeltaStore, KeyMissing,
                                   NodeUnavailable, ReadSizes,
                                   StorageNodeDown, WriteUnavailable,
                                   make_vseq, replica_nodes, split_vseq)

# message types the transport may re-issue transparently after a
# reconnect: read-only (or seq-dedup'd maintenance) requests.  PUT and
# DELETE are deliberately absent — a write gets ONE transport attempt
# and then fails loudly into the redelivery queue, so a retry can never
# materialize a write the caller saw fail.  LEASE and RECONCILE are
# idempotent by construction (grants/seals are keyed by epoch and
# monotone), so a replayed frame converges to the same state.
_IDEMPOTENT = frozenset({
    wire.MSG_HELLO, wire.MSG_PING, wire.MSG_GET, wire.MSG_MULTIGET,
    wire.MSG_STATUS, wire.MSG_KEYS, wire.MSG_FEED_SINCE, wire.MSG_MAINT,
    wire.MSG_PLACEMENTS, wire.MSG_STATE_PULL, wire.MSG_LEASE,
    wire.MSG_RECONCILE,
})


class Backoff:
    """One jittered exponential-backoff policy for every retry loop in
    the client (transport redial, serial fallback, lease acquisition).
    ``sleep`` blocks for the next delay — clipped to the remaining
    deadline budget — and returns False *without sleeping* once the
    budget is exhausted, so every loop is bounded by its caller's
    deadline, never by an iteration count alone.  Full jitter
    (0.5x–1.5x the nominal delay) decorrelates concurrent retriers —
    with N writers hammering a recovering cell, synchronized retry
    waves are exactly the failure mode this avoids."""

    __slots__ = ("delay", "cap", "deadline", "rng")

    def __init__(self, base: float, cap: float = 1.0,
                 deadline: Optional[float] = None,
                 rng: Optional[random.Random] = None):
        self.delay = max(1e-4, base)
        self.cap = cap
        self.deadline = deadline
        self.rng = rng if rng is not None else random.Random()

    def sleep(self, deadline: Optional[float] = None) -> bool:
        if deadline is None:
            deadline = self.deadline
        d = self.delay * (0.5 + self.rng.random())
        if deadline is not None:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return False
            d = min(d, remaining)
        time.sleep(d)
        self.delay = min(self.delay * 2, self.cap)
        return True


class _Deadline(Exception):
    """Internal: a per-request deadline expired (wall-clock from
    enqueue).  Converted to ``NodeUnavailable`` at the API boundary."""


class _MuxFuture:
    """Reply slot of one in-flight request: an ordered event queue the
    reader thread pushes into (``("chunk", body)`` per CHUNK frame, then
    exactly one terminal ``("end", msg_type, body)`` or ``("err",
    exc)``).  The waiter consumes with a deadline; ``cancelled`` makes
    the reader drop late frames instead of queuing them."""

    __slots__ = ("_q", "_cond", "cancelled")

    def __init__(self):
        self._q: deque = deque()
        self._cond = threading.Condition()
        self.cancelled = False

    def push(self, item) -> None:
        with self._cond:
            self._q.append(item)
            self._cond.notify()

    def push_many(self, items) -> None:
        """Batch push from the demux loop: one lock hold + one notify
        for a whole CHUNK train instead of a wakeup per frame."""
        with self._cond:
            self._q.extend(items)
            self._cond.notify()

    def next(self, deadline: float):
        with self._cond:
            while not self._q:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise _Deadline()
                self._cond.wait(remaining)
            return self._q.popleft()

    def next_batch(self, deadline: float) -> List:
        """Pop *everything* queued in one lock round (blocking like
        ``next`` while empty).  Consumers that can absorb a run of
        events amortise the handoff to one wakeup per CHUNK train."""
        with self._cond:
            while not self._q:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise _Deadline()
                self._cond.wait(remaining)
            evs = list(self._q)
            self._q.clear()
            return evs


class _NodeMux:
    """One multiplexed connection to one cell.  ``submit`` acquires a
    window slot (bounded in-flight, backpressure within the caller's
    deadline), registers a future under a fresh ``req_id``, and sends
    the frame; a background reader thread owns the receive side and
    demuxes every incoming frame to its future.  The window slot is
    released exactly when the request's terminal frame arrives (or the
    connection dies) — a cancelled future keeps its slot until the
    server's reply is drained, which is the price of not poisoning the
    stream, bounded by the window.  Connection death fails every
    pending future with ``NodeUnavailable``; re-dial is lazy on the
    next submit."""

    def __init__(self, store: "RemoteDeltaStore", node: int, window: int):
        self.store = store
        self.node = node
        self.window = threading.BoundedSemaphore(window)
        self.lock = threading.Lock()
        self.send_lock = threading.Lock()
        self.sock: Optional[socket.socket] = None
        self.gen = 0  # bumped per dial; stale reader threads self-expire
        self.waiters: Dict[int, _MuxFuture] = {}
        self.inflight_hwm = 0
        self.last_used = time.monotonic()
        self.closed = False

    def submit(self, msg_type: int, body: bytes,
               deadline: float) -> _MuxFuture:
        """Register + send one request; returns its future.  Raises
        ``_Deadline`` if the window or the dial exhausts the budget and
        ``NodeUnavailable`` if the node can't be dialed.  A send failure
        does NOT raise — it fails the connection, and the returned
        future already carries the error event."""
        remaining = deadline - time.monotonic()
        if remaining <= 0 or not self.window.acquire(timeout=remaining):
            raise _Deadline()
        fut = _MuxFuture()
        registered = False
        try:
            with self.lock:
                if self.closed:
                    raise NodeUnavailable(f"cell {self.node}: client closed")
                if self.sock is None:
                    if self.gen > 0:
                        with self.store._lock:
                            self.store.stats.rt_reconnects += 1
                    sock = self.store._dial(self.node)
                    sock.settimeout(None)  # deadlines live in the futures
                    self.sock = sock
                    self.gen += 1
                    t = threading.Thread(
                        target=self._read_loop, args=(sock, self.gen),
                        name=f"mux{self.node}-reader", daemon=True)
                    t.start()
                req_id = self.store._next_req_id()
                self.waiters[req_id] = fut
                registered = True
                depth = len(self.waiters)
                self.inflight_hwm = max(self.inflight_hwm, depth)
                self.last_used = time.monotonic()
                sock, gen = self.sock, self.gen
        except (wire.ProtocolMismatch, wire.AuthFailed):
            raise  # typed handshake failures: never masked as "down"
        except (OSError, wire.WireError) as e:
            raise NodeUnavailable(
                f"cell {self.node} @ {self.store.addrs[self.node]}: {e}"
            ) from e
        finally:
            if not registered:
                self.window.release()
        with self.store._lock:
            if depth > 1:
                self.store.stats.rt_pipelined += 1
            else:
                self.store.stats.rt_serial += 1
        try:
            with self.send_lock:
                wire.send_frame(sock, msg_type, req_id, body)
        except OSError as e:
            self._fail(gen, e)  # drains fut with the error event
        return fut

    def cancel(self, fut: _MuxFuture) -> None:
        """Deadline expiry: stop waiting without poisoning the stream.
        The future stays registered so the reader can drain (and drop)
        the late reply; its window slot frees on that terminal frame."""
        fut.cancelled = True
        with self.store._lock:
            self.store.stats.rt_deadline_cancels += 1

    def _read_loop(self, sock: socket.socket, gen: int) -> None:
        reader = wire.FrameReader(sock)
        while True:
            try:
                frames = reader.read_frames()
            except (OSError, wire.WireError) as e:
                self._fail(gen, e)
                return
            # resolve the whole batch under ONE lock hold, then deliver
            # with one wakeup per future — a 64-chunk train costs one
            # recv, one lock round, one notify
            resolved = []
            with self.lock:
                if gen != self.gen:
                    return  # superseded connection: stand down
                self.last_used = time.monotonic()
                for frame in frames:
                    terminal = frame.msg_type != wire.MSG_CHUNK
                    fut = self.waiters.get(frame.req_id)
                    if fut is not None and terminal:
                        del self.waiters[frame.req_id]
                    resolved.append((fut, terminal, frame))
            deliver: Dict[int, Tuple[_MuxFuture, list]] = {}
            for fut, terminal, frame in resolved:
                if fut is None:
                    continue  # stray frame (already-failed request): drop
                if terminal:
                    self.window.release()
                if fut.cancelled:
                    continue  # deadline passed: drain and drop
                slot = deliver.setdefault(id(fut), (fut, []))
                if terminal:
                    slot[1].append(("end", frame.msg_type, frame.body))
                else:
                    slot[1].append(("chunk", frame.body))
            for fut, items in deliver.values():
                fut.push_many(items)

    def _fail(self, gen: int, exc: Exception) -> None:
        """Connection death: close the socket and fail every pending
        future.  ``gen`` guards double-failure (send-side and read-side
        racing) and stale reader threads."""
        with self.lock:
            if gen != self.gen:
                return
            if self.sock is not None:
                try:
                    self.sock.close()
                except OSError:
                    pass
                self.sock = None
            pending = list(self.waiters.values())
            self.waiters.clear()
        err = NodeUnavailable(
            f"cell {self.node} @ {self.store.addrs[self.node]}: {exc}")
        for fut in pending:
            self.window.release()
            fut.push(("err", err))

    def reap_if_idle(self, cutoff: float) -> bool:
        with self.lock:
            if (self.sock is None or self.waiters
                    or self.last_used >= cutoff):
                return False
            try:
                self.sock.close()
            except OSError:
                pass
            self.sock = None
            self.gen += 1  # blocked reader fails with a stale gen: no drain
            return True

    def close(self) -> None:
        with self.lock:
            self.closed = True
            if self.sock is not None:
                try:
                    self.sock.close()
                except OSError:
                    pass
                self.sock = None
            self.gen += 1
            pending = list(self.waiters.values())
            self.waiters.clear()
        err = NodeUnavailable(f"cell {self.node}: client closed")
        for fut in pending:
            self.window.release()
            fut.push(("err", err))


class RemoteDeltaStore(DeltaStore):
    def __init__(self, addrs: List[Tuple[str, int]], r: int = 1,
                 fmt: Optional[str] = None,
                 pool_bytes: int = DEFAULT_POOL_BYTES,
                 timeout: float = 5.0, retries: int = 2,
                 backoff: float = 0.05, suspect_ttl: float = 2.0,
                 pipeline: bool = True, window: int = 32,
                 idle_ttl: float = 30.0, lease_ttl: float = 2.0,
                 auth_key: Optional[str] = None,
                 writer_id: Optional[str] = None):
        super().__init__(m=len(addrs), r=r, backend="mem", fmt=fmt,
                         pool_bytes=pool_bytes)
        self.backend = "remote"
        self.addrs = list(addrs)
        self.timeout = timeout
        self.retries = retries
        self.backoff = backoff
        self.suspect_ttl = suspect_ttl
        self.window = max(1, window)
        self.idle_ttl = idle_ttl
        self.lease_ttl = max(0.05, lease_ttl)
        self.auth_key = auth_key.encode() if auth_key else None
        self.writer_id = writer_id or uuid.uuid4().hex[:12]
        self._pipeline = pipeline
        self._suspects: Dict[int, float] = {}
        # serial fallback pool: (socket, last-checkin time) per node
        self._conns: List[List[Tuple[socket.socket, float]]] = [
            [] for _ in addrs]
        self._conn_lock = threading.Lock()
        self._muxes = [_NodeMux(self, j, self.window)
                       for j in range(len(addrs))]
        self._req_id = 0
        self._wlock = threading.Lock()
        # per-node redelivery queues: (vseq, msg_type, body) of replica
        # writes that node missed, drained in vseq order before the node
        # serves any further read/write from this client (gap repair)
        self._pending: List[List[Tuple[int, int, bytes]]] = [[] for _ in addrs]
        # writer-lease state, all guarded by _wlock: the lane this
        # writer stamps (epoch 0 = no lease yet), its lane-local seq,
        # the client-side lease validity horizon, and the degraded flag
        # (True: no lease AND no quorum — writes fail fast until the
        # renewal thread re-acquires)
        self._seq = 0
        self._epoch = 0
        self._lease_deadline = 0.0
        self._degraded = False
        self._max_epoch_seen = 0
        self._closed = threading.Event()
        self._reaper = threading.Thread(target=self._reap_loop,
                                        name="remote-store-reaper",
                                        daemon=True)
        self._reaper.start()
        self._lease_thread = threading.Thread(
            target=self._lease_loop, name="remote-store-lease", daemon=True)
        self._lease_thread.start()

    # ---- connection management ----
    def _dial(self, node: int) -> socket.socket:
        sock = socket.create_connection(self.addrs[node],
                                        timeout=self.timeout)
        sock.settimeout(self.timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        wire.send_frame(sock, wire.MSG_HELLO, 0)
        reply = wire.recv_frame(sock)
        if reply.msg_type == wire.MSG_AUTH:
            # HELLO challenge: prove the shared secret before anything
            # else is served.  No key configured -> typed AuthFailed
            # (retrying cannot help; never masked as NodeUnavailable).
            if self.auth_key is None:
                sock.close()
                raise wire.AuthFailed(
                    f"cell {node} requires auth (pass auth_key=...)")
            mac = hmac.new(self.auth_key, reply.body,
                           hashlib.sha256).digest()
            wire.send_frame(sock, wire.MSG_AUTH, 0, mac)
            reply = wire.recv_frame(sock)
        if reply.msg_type == wire.MSG_ERR:
            code, msg = wire.unpack_err(reply.body)
            sock.close()
            if code == wire.ERR_VERSION:
                raise wire.ProtocolMismatch(msg)
            if code == wire.ERR_AUTH_FAILED:
                raise wire.AuthFailed(msg)
            raise wire.RemoteError(code, msg)
        if reply.msg_type != wire.MSG_HELLO:
            sock.close()
            raise wire.FrameError(
                f"expected HELLO reply, got type {reply.msg_type}")
        return sock

    def _next_req_id(self) -> int:
        with self._lock:
            self._req_id = (self._req_id + 1) & 0xFFFFFFFF or 1
            return self._req_id

    def _checkout(self, node: int) -> socket.socket:
        cutoff = time.monotonic() - self.idle_ttl
        with self._conn_lock:
            while self._conns[node]:
                sock, ts = self._conns[node].pop()
                if ts >= cutoff:
                    return sock
                try:  # sat idle past the TTL: the cell may have dropped
                    sock.close()  # it; don't hand a dead socket out
                except OSError:
                    pass
        return self._dial(node)

    def _checkin(self, node: int, sock: socket.socket) -> None:
        with self._conn_lock:
            self._conns[node].append((sock, time.monotonic()))

    def _reap_loop(self) -> None:
        interval = max(0.05, min(self.idle_ttl, 5.0) / 2)
        while not self._closed.wait(interval):
            cutoff = time.monotonic() - self.idle_ttl
            for mux in self._muxes:
                mux.reap_if_idle(cutoff)
            with self._conn_lock:
                for node, stack in enumerate(self._conns):
                    live = [(s, ts) for s, ts in stack if ts >= cutoff]
                    for s, ts in stack:
                        if ts < cutoff:
                            try:
                                s.close()
                            except OSError:
                                pass
                    self._conns[node] = live

    def close(self) -> None:
        self._release_lease()
        self._closed.set()
        for mux in self._muxes:
            mux.close()
        with self._conn_lock:
            for stack in self._conns:
                while stack:
                    try:
                        stack.pop()[0].close()
                    except OSError:
                        pass

    # ---- request/reply: deadline from enqueue, idempotent-only retry ----
    def _map_reply(self, msg_type: int, body: bytes) -> bytes:
        if msg_type != wire.MSG_ERR:
            return body
        code, msg = wire.unpack_err(body)
        if code == wire.ERR_VERSION:
            raise wire.ProtocolMismatch(msg)
        if code == wire.ERR_KEY_MISSING:
            raise KeyMissing(msg)
        if code == wire.ERR_LEASE_FENCED:
            raise wire.LeaseFenced(msg)
        if code == wire.ERR_AUTH_FAILED:
            raise wire.AuthFailed(msg)
        raise wire.RemoteError(code, msg)

    def _request(self, node: int, msg_type: int, body: bytes,
                 retries: Optional[int] = None,
                 deadline: Optional[float] = None) -> bytes:
        """One request to one cell.  The deadline is wall-clock from
        THIS call (enqueue): window wait, dial, send, queueing on the
        server, and the reply all draw down the same ``timeout`` budget,
        so a request stuck behind a full window can't silently exceed
        the caller's patience.  Transport failures (dead connection,
        torn or corrupt frame) are retried with bounded backoff for
        idempotent message types only, then surface as
        ``NodeUnavailable`` — the caller fails over.  Server-relayed
        errors (ERR frames) are never retried: the cell is alive, the
        request itself failed."""
        if deadline is None:
            deadline = time.monotonic() + self.timeout
        if not self._pipeline:
            return self._request_serial(node, msg_type, body, retries,
                                        deadline)
        retries = self.retries if retries is None else retries
        attempts = (retries + 1) if msg_type in _IDEMPOTENT else 1
        bo = Backoff(self.backoff, deadline=deadline)
        mux = self._muxes[node]
        last: Exception = NodeUnavailable(f"cell {node}")
        for _ in range(attempts):
            try:
                fut = mux.submit(msg_type, body, deadline)
            except _Deadline:
                break
            except NodeUnavailable as e:
                last = e
                if not bo.sleep():
                    break
                continue
            try:
                ev = fut.next(deadline)
            except _Deadline:
                mux.cancel(fut)
                raise NodeUnavailable(
                    f"cell {node} @ {self.addrs[node]}: deadline "
                    f"({self.timeout}s from enqueue) expired") from None
            if ev[0] == "err":
                last = ev[1]
                if not bo.sleep():
                    break
                continue
            assert ev[0] == "end", f"unexpected stream event {ev[0]}"
            return self._map_reply(ev[1], ev[2])
        raise NodeUnavailable(
            f"cell {node} @ {self.addrs[node]}: {last}") from last

    def _request_serial(self, node: int, msg_type: int, body: bytes,
                        retries: Optional[int], deadline: float) -> bytes:
        """The pre-multiplexer transport: one checked-out connection per
        request, blocking reply read.  Kept as the ``pipeline=False``
        baseline; per-attempt socket timeouts are clipped to the
        remaining enqueue budget."""
        retries = self.retries if retries is None else retries
        bo = Backoff(self.backoff, deadline=deadline)
        last: Exception = NodeUnavailable(f"cell {node}")
        for _ in range(retries + 1):
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            sock = None
            try:
                sock = self._checkout(node)
                sock.settimeout(max(0.05, remaining))
                req_id = self._next_req_id()
                wire.send_frame(sock, msg_type, req_id, body)
                reply = wire.recv_frame(sock)
                if reply.req_id != req_id:
                    raise wire.FrameError("reply req_id mismatch")
                with self._lock:
                    self.stats.rt_serial += 1
                self._checkin(node, sock)
                return self._map_reply(reply.msg_type, reply.body)
            except (wire.ProtocolMismatch, wire.AuthFailed, wire.LeaseFenced,
                    wire.RemoteError, KeyMissing):
                raise  # the cell answered: retrying cannot change it
            except (OSError, wire.WireError) as e:
                if sock is not None:
                    try:
                        sock.close()
                    except OSError:
                        pass
                last = e
                if not bo.sleep():
                    break
        raise NodeUnavailable(
            f"cell {node} @ {self.addrs[node]}: {last}") from last

    # ---- node health (suspect set with re-probe TTL) ----
    def _health_ok(self, i: int) -> bool:
        """Pure reachability check: not down, not a live suspect.  Safe
        to call while holding ``_wlock`` (no side effects beyond TTL
        expiry of the suspect mark)."""
        if i in self.down:
            return False
        t = self._suspects.get(i)
        if t is None:
            return True
        if time.monotonic() - t > self.suspect_ttl:
            self._suspects.pop(i, None)  # TTL over: re-probe the cell
            return True
        return False

    def _node_ok(self, i: int) -> bool:
        """The routing gate the (inherited) read paths consult.  On top
        of reachability, a node with queued redeliveries is *gap-known*:
        it missed acknowledged writes, so a read routed there could
        return a stale version with a valid crc — no failover would
        trigger.  Drain the queue first; if the node still can't take
        the backlog, treat it as unavailable and let the read fail over
        to a replica that has the writes."""
        if not self._health_ok(i):
            return False
        if self._pending[i]:
            with self._wlock:
                if self._pending[i] and not self._drain_pending(i):
                    return False
        return True

    def _mark_unavailable(self, i: int) -> None:
        self._suspects[i] = time.monotonic()

    def _drain_pending(self, node: int) -> bool:
        """Redeliver ``node``'s queued writes in seq order; True when
        the queue is empty.  Caller holds ``_wlock`` — the drain must
        serialize with live writes so the node keeps seeing seqs in
        order.  A failed redelivery re-marks the node suspect and keeps
        the rest of the queue (including on RemoteError: dropping a
        record would silently re-open the gap; restart catch-up remains
        the backstop for a persistently failing cell)."""
        q = self._pending[node]
        while q:
            _seq, mtype, body = q[0]
            try:
                self._request(node, mtype, body)
            except wire.LeaseFenced:
                # the record's lane was sealed while it sat queued: the
                # reconciliation that sealed it already anti-entropied
                # every record that mattered, so this copy is moot —
                # drop it, or the node stays gap-known forever
                q.pop(0)
                with self._lock:
                    self.stats.fence_drops += 1
                continue
            except NodeUnavailable:
                self._mark_unavailable(node)
                return False
            except wire.RemoteError:
                return False
            q.pop(0)
            with self._lock:
                self.stats.redelivered += 1
        return True

    # ---- writer lease lifecycle ----
    def _lease_body(self, op: int, epoch: int,
                    final_seq: Optional[int] = None,
                    peers: bool = False) -> bytes:
        body = (struct.pack("<BQ", op, epoch)
                + wire.pack_str(self.writer_id))
        if final_seq is not None:
            body += struct.pack("<Q", final_seq)
        if peers:
            body += wire.pack_peers(self.addrs)
        return body

    def _lease_quorum(self) -> int:
        return self.m // 2 + 1

    def _acquire_lease_locked(self, deadline: float) -> None:
        """Acquire a fresh fencing epoch from a cell quorum (caller
        holds ``_wlock``).  Proposes past the highest epoch seen and,
        on a denied round, past the highest epoch the denials revealed
        — two racing writers converge in one extra round.  The ACQUIRE
        body carries the full address list so every cell learns the
        topology reconciliation will later anti-entropy across.  Raises
        ``WriteUnavailable`` once the deadline budget is exhausted
        without a quorum."""
        quorum = self._lease_quorum()
        bo = Backoff(self.backoff, deadline=deadline)
        propose = max(self._max_epoch_seen, self._epoch) + 1
        while True:
            grants = 0
            body = self._lease_body(wire.LEASE_ACQUIRE, propose, peers=True)
            for j in range(self.m):
                try:
                    rep = self._request(j, wire.MSG_LEASE, body, retries=0,
                                        deadline=deadline)
                except (NodeUnavailable, wire.RemoteError):
                    self._mark_unavailable(j)
                    continue
                granted, max_epoch = struct.unpack_from("<BQ", rep, 0)
                self._max_epoch_seen = max(self._max_epoch_seen, max_epoch)
                grants += granted
            if grants >= quorum:
                self._epoch = propose
                self._max_epoch_seen = max(self._max_epoch_seen, propose)
                self._seq = 0  # a fresh lane starts empty: no seq resume
                self._degraded = False
                self._lease_deadline = time.monotonic() + self.lease_ttl
                with self._lock:
                    self.stats.lease_acquires += 1
                return
            propose = max(self._max_epoch_seen, propose) + 1
            if not bo.sleep():
                self._degraded = True
                raise WriteUnavailable(
                    f"writer lease: no quorum ({grants}/{quorum} grants, "
                    f"m={self.m}) — write plane degraded to read-only; "
                    f"re-acquiring in the background")

    def _ensure_lease_locked(self) -> None:
        """Write-path gate (caller holds ``_wlock``): a live lease
        passes immediately; a degraded writer fails FAST with the typed
        ``WriteUnavailable`` (no network — the renewal thread owns
        re-acquisition); anything else (first write, lapsed or fenced
        lease) acquires synchronously within one timeout budget."""
        if self._epoch and not self._degraded \
                and time.monotonic() < self._lease_deadline:
            return
        if self._degraded:
            raise WriteUnavailable(
                "write plane degraded: no writer-lease quorum (reads keep "
                "serving; writes resume once a quorum returns)")
        self._acquire_lease_locked(time.monotonic() + self.timeout)

    def _renew_locked(self, deadline: float) -> bool:
        quorum = self._lease_quorum()
        grants = 0
        body = self._lease_body(wire.LEASE_RENEW, self._epoch)
        for j in range(self.m):
            try:
                rep = self._request(j, wire.MSG_LEASE, body, retries=0,
                                    deadline=deadline)
            except (NodeUnavailable, wire.RemoteError):
                continue
            granted, max_epoch = struct.unpack_from("<BQ", rep, 0)
            self._max_epoch_seen = max(self._max_epoch_seen, max_epoch)
            grants += granted
        if grants >= quorum:
            self._lease_deadline = time.monotonic() + self.lease_ttl
            with self._lock:
                self.stats.lease_renewals += 1
            return True
        return False

    def _invalidate_lease_locked(self) -> None:
        """A cell fenced our epoch: the lane was sealed (this writer was
        presumed dead).  Drop the lease WITHOUT degrading — the next
        write re-acquires a fresh epoch synchronously."""
        self._lease_deadline = 0.0
        self._degraded = False
        with self._lock:
            self.stats.lease_fenced += 1

    def _lease_loop(self) -> None:
        """Background renewal: every ``lease_ttl/3`` renew a held lease
        (writes also extend it, so this mostly matters when idle),
        degrade to read-only when the lease expires without a quorum,
        and — while degraded — keep trying to re-acquire so writes
        resume automatically when the quorum returns."""
        interval = self.lease_ttl / 3
        while not self._closed.wait(interval):
            if not self._wlock.acquire(timeout=interval):
                continue  # a write holds the lock — it IS the heartbeat
            try:
                budget = time.monotonic() + min(self.timeout,
                                                self.lease_ttl)
                if self._degraded:
                    try:
                        self._acquire_lease_locked(budget)
                    except WriteUnavailable:
                        pass
                    continue
                if not self._epoch:
                    continue  # never written: nothing to maintain
                if not self._renew_locked(budget) \
                        and time.monotonic() >= self._lease_deadline:
                    self._degraded = True
            finally:
                self._wlock.release()

    def _release_lease(self) -> None:
        """Best-effort clean exit: seal our lane at its final seq so the
        cells needn't wait out the TTL.  Only safe — and only attempted
        — when every own-lane redelivery has drained (a RELEASE seal
        asserts the lane is replica-complete up to ``final_seq``); a
        writer exiting with queued records leaves the TTL + orphan-seq
        reconciliation to seal the lane instead."""
        try:
            with self._wlock:
                if not self._epoch or self._degraded:
                    return
                for q in self._pending:
                    for vseq, _, _ in q:
                        if split_vseq(vseq)[0] == self._epoch:
                            return
                body = self._lease_body(wire.LEASE_RELEASE, self._epoch,
                                        final_seq=self._seq)
                for j in range(self.m):
                    try:
                        self._request(j, wire.MSG_LEASE, body, retries=0)
                    except (NodeUnavailable, wire.WireError):
                        continue
                self._epoch = 0
                self._lease_deadline = 0.0
        except Exception:  # noqa: BLE001 — close() must never fail on this
            pass

    def lease_status(self) -> Dict:
        """This writer's lane as the client sees it: epoch, lane seq,
        degraded flag, and how much lease validity remains."""
        with self._wlock:
            return {"writer_id": self.writer_id, "epoch": self._epoch,
                    "seq": self._seq, "degraded": self._degraded,
                    "remaining": max(0.0, self._lease_deadline
                                     - time.monotonic())}

    def reconcile_lane(self, epoch: int, force: bool = False) -> int:
        """Operator-driven orphan-seq reconciliation for one lane:
        query every cell's lane high-water mark, have every cell
        anti-entropy its gaps from the peer list (prepare: while every
        feed is still intact), then seal the lane at the max and
        broadcast.  Requires every cell reachable — sealing asserts
        replica-completeness, which a partial view cannot prove — and,
        unless ``force``, refuses while any cell still sees a live
        lease.  ``force`` fences a *live* writer deliberately (the
        stale-writer drill: its next write gets ``LeaseFenced``).
        Returns the seal point."""
        marks: List[int] = []
        for j in range(self.m):
            rep = self._request(
                j, wire.MSG_RECONCILE,
                struct.pack("<BQ", wire.RECONCILE_QUERY, epoch))
            lane_seq, seal, has_seal, live = struct.unpack_from(
                "<QQBB", rep, 0)
            if live and not force:
                raise StorageNodeDown(
                    f"lane {epoch} still holds a live lease on cell {j}; "
                    f"pass force=True to fence it anyway")
            marks.append(lane_seq)
            if has_seal:
                marks.append(seal)
        prep = (struct.pack("<BQ", wire.RECONCILE_PREPARE, epoch)
                + wire.pack_peers(self.addrs))
        for j in range(self.m):
            rep = self._request(j, wire.MSG_RECONCILE, prep)
            marks.append(struct.unpack_from("<Q", rep, 0)[0])
        seal = max(marks)
        body = (struct.pack("<BQQ", wire.RECONCILE_SEAL, epoch, seal)
                + wire.pack_peers(self.addrs))
        for j in range(self.m):
            self._request(j, wire.MSG_RECONCILE, body)
        return seal

    # ---- replica-ack watermark (feed truncation) ----
    def _ack_watermark_locked(self, exclude_current: bool = False) -> int:
        """Highest OWN-LANE seq S such that every record this client
        stamped with lane seq <= S was accepted by EVERY replica cell it
        belongs to: every fan-out either acked on all replicas or queued
        the misses, so S is ``_seq`` clamped below the oldest own-lane
        queued redelivery.  Returned as a vseq — cells split it and
        advance only this lane's ack coverage, so one writer's watermark
        can never certify (or strand) another writer's lane.  Queued
        records from a *previous* epoch of this client are ignored: the
        watermark asserts nothing about sealed lanes.  Caller holds
        ``_wlock``.  ``exclude_current`` backs off by one for the write
        being fanned out right now (its own acks are not in yet)."""
        base = self._seq - (1 if exclude_current else 0)
        for q in self._pending:
            for vseq, _, _ in q:
                e, s = split_vseq(vseq)
                if e == self._epoch:
                    base = min(base, s - 1)
                    break  # queues are vseq-ordered: first hit is min
        return make_vseq(self._epoch, max(0, base))

    def ack_watermark(self) -> int:
        with self._wlock:
            return self._ack_watermark_locked()

    def quiesce(self, truncate: bool = False) -> int:
        """Drain every redelivery queue (best effort), then push the ack
        watermark to every cell with a PING; with ``truncate`` also ask
        each cell to truncate its feed up to the watermark NOW (forced
        MAINT) — benches/tests use this to reach a deterministic feed
        state before comparing files.  Returns the watermark."""
        with self._wlock:
            for j in range(self.m):
                if self._pending[j]:
                    self._drain_pending(j)
            water = self._ack_watermark_locked()
        body = struct.pack("<Q", water)
        for j in range(self.m):
            try:
                self._request(j, wire.MSG_PING, body, retries=0)
            except (NodeUnavailable, wire.WireError):
                continue
            if truncate:
                try:
                    self._request(j, wire.MSG_MAINT,
                                  struct.pack("<B", wire.MAINT_TRUNCATE))
                except (NodeUnavailable, wire.RemoteError):
                    pass
        return water

    # ---- physical I/O overrides (everything above is inherited) ----
    def _read_columns(self, node: int, key: DeltaKey,
                      fields: Optional[Tuple[str, ...]],
                      ) -> Tuple[Dict[str, np.ndarray], int, int]:
        flist = None if fields is None else list(fields)
        body = wire.pack_key(key) + wire.pack_fields(flist)
        blob = self._request(node, wire.MSG_GET, body)
        # the reply IS a TGI2 block: per-column crc32 verified on decode
        # (BlockCorruption -> inherited get() fails over to next replica)
        arrays, enc_read, raw_read = serialize.loads_sized(blob, fields=flist)
        self._pool_dir_fill(key, blob)
        return arrays, enc_read, raw_read

    def _fan_out(self, key: DeltaKey, seq: int, msg_type: int,
                 body: bytes) -> List[bytes]:
        """Send one stamped record to every replica cell of ``key``
        (caller holds ``_wlock``).  A reachable node first drains its
        redelivery backlog so it keeps receiving seqs in order; a node
        that is suspect or fails gets the record queued for redelivery
        instead.  Returns the replies of the cells that acked — if NONE
        did, the write failed: nothing is queued (a record the caller
        saw fail must not materialize later) and ``StorageNodeDown`` is
        raised."""
        acked: List[bytes] = []
        missed: List[int] = []
        fenced: Optional[wire.LeaseFenced] = None
        for node in self.replicas(key):
            if self._health_ok(node) and self._drain_pending(node):
                try:
                    acked.append(self._request(node, msg_type, body))
                    continue
                except wire.LeaseFenced as e:
                    fenced = e  # lane sealed there: do NOT queue a copy
                    continue
                except NodeUnavailable:
                    self._mark_unavailable(node)
            missed.append(node)
        if fenced is not None:
            # our epoch was reconciled away (this writer was presumed
            # dead).  Invalidate the lease so the next write re-acquires
            # a fresh epoch.  With zero acks the write plainly failed —
            # surface the typed fence.  With partial acks the record IS
            # durable (the accepting cell's copy rides the seal upward
            # when reconciliation reaches it), so the write stands.
            self._invalidate_lease_locked()
            if not acked:
                raise fenced
        if not acked:
            raise StorageNodeDown(f"all replica cells down for {key}")
        for node in missed:
            self._pending[node].append((seq, msg_type, body))
        return acked

    def put_encoded(self, key: DeltaKey, blob: bytes, raw_bytes: int):
        with self._wlock:
            self._ensure_lease_locked()
            self._seq += 1
            vseq = make_vseq(self._epoch, self._seq)
            body = (wire.pack_key(key)
                    + struct.pack("<QQ", vseq, raw_bytes)
                    + wire.pack_blob(blob)
                    + struct.pack("<Q",
                                  self._ack_watermark_locked(True)))
            acked = self._fan_out(key, vseq, wire.MSG_PUT, body)
            if len(acked) >= self._lease_quorum():
                # a quorum saw the write: it doubles as the heartbeat
                self._lease_deadline = time.monotonic() + self.lease_ttl
        if self.pool is not None:
            self.pool.invalidate(key)
        with self._lock:
            self.stats.writes += 1
            self.stats.bytes_written += len(blob) * self.r
            self.stats.bytes_raw_written += raw_bytes * self.r
            self.key_sizes[key] = (raw_bytes, len(blob))

    def delete(self, key: DeltaKey) -> bool:
        """Like ``put_encoded``, a delete must be acked by at least one
        replica cell — otherwise no DELETE record exists in any feed
        (the seq would be a permanent gap and the key would stay live on
        the cluster), so it raises ``StorageNodeDown`` with the local
        accounting untouched instead of silently 'succeeding'."""
        with self._wlock:
            self._ensure_lease_locked()
            self._seq += 1
            vseq = make_vseq(self._epoch, self._seq)
            body = (wire.pack_key(key) + struct.pack("<Q", vseq)
                    + struct.pack("<Q",
                                  self._ack_watermark_locked(True)))
            replies = self._fan_out(key, vseq, wire.MSG_DELETE, body)
            existed = any(bool(rep[0]) for rep in replies)
            if len(replies) >= self._lease_quorum():
                self._lease_deadline = time.monotonic() + self.lease_ttl
        if self.pool is not None:
            self.pool.invalidate(key)
        with self._lock:
            sizes = self.key_sizes.pop(key, None)
            if sizes is not None:
                self.stats.n_deletes += 1
                self.stats.bytes_deleted += sizes[1] * self.r
        return existed or sizes is not None

    # ---- multiget: replica-parallel fan-out over streamed chunks ----
    def _mg_body(self, keys: List[DeltaKey],
                 flist: Optional[List[str]]) -> bytes:
        req = [struct.pack("<I", len(keys))]
        req += [wire.pack_key(k) for k in keys]
        req.append(wire.pack_fields(flist))
        req.append(struct.pack("<B", 1))  # found-subset reply; the
        # client decides missing vs try-next-replica
        return b"".join(req)

    def _absorb_hit(self, k: DeltaKey, blob: bytes,
                    flist: Optional[List[str]],
                    sizes: Optional[Dict[DeltaKey, ReadSizes]],
                    tier: int) -> Optional[Dict]:
        """Decode one multiget hit and run the full read-side
        bookkeeping (pool fill, stats, sizes); None on a corrupt blob
        (counted as a failover — the key retries on the next tier)."""
        try:
            arrays, enc_read, raw_read = serialize.loads_sized(
                blob, fields=flist)
        except BlockCorruption:
            with self._lock:
                self.stats.failovers += 1
            return None
        self._pool_dir_fill(k, blob)
        with self._lock:
            self.stats.reads += 1
            self.stats.bytes_read += enc_read
            self.stats.bytes_decompressed += raw_read
            if self.pool is not None:
                self.stats.pool_misses += len(arrays)
            if tier > 0:
                self.stats.failovers += 1
        if self.pool is not None:
            for name, a in arrays.items():
                self.pool.put(k, name, a)
        if sizes is not None:
            sizes[k] = ReadSizes(enc_read, raw_read, 0, 0)
        return arrays

    def _mg_drain(self, node: int, fut: _MuxFuture, deadline: float,
                  on_blob: Callable[[DeltaKey, bytes], None]) -> int:
        """Consume one MULTIGET reply stream from a mux future, invoking
        ``on_blob`` per CHUNK as it arrives (decode overlaps the
        server's reads of later keys).  Returns the server's found
        count; transport failure or deadline -> ``NodeUnavailable``."""
        mux = self._muxes[node]
        while True:
            try:
                evs = fut.next_batch(deadline)
            except _Deadline:
                mux.cancel(fut)
                raise NodeUnavailable(
                    f"cell {node}: multiget deadline expired") from None
            for ev in evs:
                if ev[0] == "chunk":
                    k, off = wire.unpack_key(ev[1], 0)
                    blob, _ = wire.unpack_blob(ev[1], off)
                    on_blob(k, blob)
                    continue
                if ev[0] == "err":
                    raise NodeUnavailable(
                        f"cell {node}: {ev[1]}") from ev[1]
                mtype, body = ev[1], ev[2]
                if mtype == wire.MSG_END:
                    (found,) = struct.unpack_from("<I", body, 0)
                    return found
                self._map_reply(mtype, body)  # raises on ERR
                raise wire.FrameError(
                    f"unexpected terminal frame {mtype}")

    def multiget(self, keys: Iterable[DeltaKey], c: int = 1,
                 fields: Optional[Iterable[str]] = None,
                 missing_ok: bool = False,
                 sizes: Optional[Dict[DeltaKey, ReadSizes]] = None,
                 ) -> Dict[DeltaKey, Dict]:
        """Replica-parallel pipelined multiget: every primary-node group
        is submitted to its node's mux *concurrently* (one streamed
        MULTIGET each — ``c`` is moot, parallelism is free on the
        muxes), then the streams are drained with decode/pool-fill per
        arriving chunk.  Keys a tier leaves unserved advance together to
        the next replica tier — hedged groups (primary known-dead) ride
        the same mechanism starting at tier 0.  With ``pipeline=False``
        falls back to the serial per-group path."""
        if not self._pipeline:
            return super().multiget(keys, c=c, fields=fields,
                                    missing_ok=missing_ok, sizes=sizes)
        keys = list(keys)
        flist = None if fields is None else list(fields)
        out: Dict[DeltaKey, Dict] = {}
        groups: Dict[int, List[DeltaKey]] = {}
        for k in keys:
            if self.pool is not None and self.pool.dir_get(k) is not None:
                try:
                    out[k] = self.get(k, fields=fields, sizes=sizes)
                except KeyMissing:
                    if not missing_ok:
                        raise
            else:
                groups.setdefault(self.replicas(k)[0], []).append(k)
        states = []
        for primary, batch in groups.items():
            if not self._node_ok(primary):
                with self._lock:
                    self.stats.hedged_reads += len(batch)
            states.append({"chain": self.replicas(batch[0]),
                           "pending": batch, "reachable": False})
        for tier in range(self.r):
            live = []
            for st in states:
                pending = st["pending"]
                if not pending:
                    continue
                node = st["chain"][tier]
                if not self._node_ok(node):
                    if tier > 0 or self.r == 1:
                        with self._lock:
                            self.stats.failovers += len(pending)
                    continue
                deadline = time.monotonic() + self.timeout
                try:
                    fut = self._muxes[node].submit(
                        wire.MSG_MULTIGET, self._mg_body(pending, flist),
                        deadline)
                except (_Deadline, NodeUnavailable):
                    self._mark_unavailable(node)
                    with self._lock:
                        self.stats.failovers += len(pending)
                    continue
                live.append((st, node, fut, deadline))
            for st, node, fut, deadline in live:
                pending = st["pending"]
                done: Dict[DeltaKey, Dict] = {}

                def absorb(k, blob, done=done, tier=tier):
                    if k in done:
                        return
                    arrays = self._absorb_hit(k, blob, flist, sizes, tier)
                    if arrays is not None:
                        done[k] = arrays

                ok = False
                for attempt in range(self.retries + 1):
                    try:
                        self._mg_drain(node, fut, deadline, absorb)
                        ok = True
                        break
                    except NodeUnavailable:
                        # transport blip mid-stream: re-issue the
                        # remaining keys on the same tier within the
                        # original enqueue deadline (MULTIGET is
                        # idempotent; already-absorbed keys are skipped)
                        if (attempt == self.retries
                                or time.monotonic() >= deadline):
                            break
                        rest = [k for k in pending if k not in done]
                        if not rest:
                            ok = True
                            break
                        try:
                            fut = self._muxes[node].submit(
                                wire.MSG_MULTIGET,
                                self._mg_body(rest, flist), deadline)
                        except (_Deadline, NodeUnavailable):
                            break
                    except (KeyMissing, wire.RemoteError,
                            wire.WireError):
                        break  # cell alive, batch refused: next tier
                if not ok:
                    self._mark_unavailable(node)
                    with self._lock:
                        self.stats.failovers += len(pending) - len(done)
                else:
                    st["reachable"] = True
                out.update(done)
                st["pending"] = [k for k in pending if k not in done]
            if all(not st["pending"] for st in states):
                break
        for st in states:
            if st["pending"]:
                if not st["reachable"]:
                    raise StorageNodeDown(
                        f"no live replica cell for {st['pending'][0]}")
                if not missing_ok:
                    raise KeyMissing(st["pending"][0])
        return out

    def _mg_round_serial(self, node: int, pending: List[DeltaKey],
                         flist: Optional[List[str]],
                         ) -> Dict[DeltaKey, bytes]:
        """Serial-mode MULTIGET: one checked-out connection, blocking
        CHUNK/END stream read.  Returns key -> blob for the found
        subset; transport failure -> ``NodeUnavailable``."""
        deadline = time.monotonic() + self.timeout
        body = self._mg_body(pending, flist)
        bo = Backoff(self.backoff, deadline=deadline)
        last: Exception = NodeUnavailable(f"cell {node}")
        for _ in range(self.retries + 1):
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            sock = None
            try:
                sock = self._checkout(node)
                sock.settimeout(max(0.05, remaining))
                req_id = self._next_req_id()
                wire.send_frame(sock, wire.MSG_MULTIGET, req_id, body)
                got: Dict[DeltaKey, bytes] = {}
                while True:
                    reply = wire.recv_frame(sock)
                    if reply.req_id != req_id:
                        raise wire.FrameError("reply req_id mismatch")
                    if reply.msg_type == wire.MSG_CHUNK:
                        k, off = wire.unpack_key(reply.body, 0)
                        blob, _ = wire.unpack_blob(reply.body, off)
                        got[k] = blob
                        continue
                    with self._lock:
                        self.stats.rt_serial += 1
                    self._checkin(node, sock)
                    if reply.msg_type == wire.MSG_END:
                        return got
                    self._map_reply(reply.msg_type, reply.body)
                    raise wire.FrameError(
                        f"unexpected terminal frame {reply.msg_type}")
            except (wire.ProtocolMismatch, wire.AuthFailed,
                    wire.RemoteError, KeyMissing):
                raise
            except (OSError, wire.WireError) as e:
                if sock is not None:
                    try:
                        sock.close()
                    except OSError:
                        pass
                last = e
                if not bo.sleep():
                    break
        raise NodeUnavailable(
            f"cell {node} @ {self.addrs[node]}: {last}") from last

    def _group_fetch(self, primary: int, gkeys: List[DeltaKey],
                     fields: Optional[Iterable[str]], missing_ok: bool,
                     sizes: Optional[Dict[DeltaKey, ReadSizes]],
                     ) -> Dict[DeltaKey, Dict]:
        """Serial-mode group fetch (``pipeline=False``, reached via the
        inherited ``multiget``): one MULTIGET frame per replica tier for
        a whole primary-node group.  Keys with pooled state go through
        the inherited per-key ``get`` (it merges pool hits with a
        partial fetch); cold keys ride the batch.  An unavailable tier
        redirects the *remaining batch* to the next replica in one
        frame — the hedged path."""
        out: Dict[DeltaKey, Dict] = {}
        batch: List[DeltaKey] = []
        for k in gkeys:
            if self.pool is not None and self.pool.dir_get(k) is not None:
                try:
                    out[k] = self.get(k, fields=fields, sizes=sizes)
                except KeyMissing:
                    if not missing_ok:
                        raise
            else:
                batch.append(k)
        if not batch:
            return out
        if not self._node_ok(primary):
            with self._lock:
                self.stats.hedged_reads += len(batch)
        flist = None if fields is None else list(fields)
        pending = batch
        reachable = False
        for j, node in enumerate(self.replicas(batch[0])):
            if not pending:
                break
            if not self._node_ok(node):
                if j > 0 or self.r == 1:
                    with self._lock:
                        self.stats.failovers += len(pending)
                continue
            try:
                got = self._mg_round_serial(node, pending, flist)
            except NodeUnavailable:
                self._mark_unavailable(node)
                with self._lock:
                    self.stats.failovers += len(pending)
                continue
            reachable = True
            still: List[DeltaKey] = []
            for k in pending:
                blob = got.get(k)
                if blob is None:
                    still.append(k)  # not on this tier: try the next
                    continue
                arrays = self._absorb_hit(k, blob, flist, sizes, j)
                if arrays is None:
                    still.append(k)
                    continue
                out[k] = arrays
            pending = still
        if pending:
            if not reachable:
                raise StorageNodeDown(
                    f"no live replica cell for {pending[0]}")
            if not missing_ok:
                raise KeyMissing(pending[0])
        return out

    def keys_for_placement(self, tsid: int, sid: int) -> List[DeltaKey]:
        body = struct.pack("<qq", tsid, sid)
        last: Exception = StorageNodeDown(
            f"no live replica cell for placement ({tsid}, {sid})")
        for node in replica_nodes(tsid, sid, self.m, self.r):
            if not self._node_ok(node):
                continue
            try:
                reply = self._request(node, wire.MSG_KEYS, body)
            except NodeUnavailable as e:
                self._mark_unavailable(node)
                last = e
                continue
            (n,) = struct.unpack_from("<I", reply, 0)
            off = 4
            out = []
            for _ in range(n):
                k, off = wire.unpack_key(reply, off)
                out.append(k)
            return out
        raise StorageNodeDown(str(last))

    def node_status(self) -> Dict:
        """The shared cluster-health shape, with liveness *probed*: each
        cell answers a PING (one attempt) so "up" reflects the cluster
        as it is now, not just the suspect cache."""
        for i in range(self.m):
            try:
                self._request(i, wire.MSG_PING, b"", retries=0)
                self._suspects.pop(i, None)
            except (NodeUnavailable, wire.WireError):
                self._mark_unavailable(i)
        return super().node_status()

    def feed_status(self) -> List[Optional[Dict]]:
        """Per-cell feed state (length/floor/bytes/ack_water/
        truncations), ``None`` for unreachable cells — how benches and
        ``storage_report`` observe ack-watermark feed truncation."""
        out: List[Optional[Dict]] = []
        for i in range(self.m):
            try:
                out.append(self.cell_status(i).get("feed"))
            except (NodeUnavailable, wire.WireError, ValueError):
                out.append(None)
        return out

    def transport_stats(self) -> Dict:
        """Live mux state + transport counters: per-node in-flight
        depth (and its high-water mark), connectedness, and the
        pipelined/serial/cancel/reconnect round-trip counters."""
        nodes = []
        for j, mux in enumerate(self._muxes):
            with mux.lock:
                nodes.append({"node": j,
                              "connected": mux.sock is not None,
                              "in_flight": len(mux.waiters),
                              "inflight_hwm": mux.inflight_hwm})
        with self._lock:
            s = self.stats
            counters = {"rt_pipelined": s.rt_pipelined,
                        "rt_serial": s.rt_serial,
                        "rt_deadline_cancels": s.rt_deadline_cancels,
                        "rt_reconnects": s.rt_reconnects,
                        "hedged_reads": s.hedged_reads,
                        "failovers": s.failovers}
        return {"pipeline": self._pipeline, "window": self.window,
                "in_flight": sum(n["in_flight"] for n in nodes),
                "inflight_hwm": max((n["inflight_hwm"] for n in nodes),
                                    default=0),
                **counters, "nodes": nodes}

    def cell_status(self, node: int) -> Dict:
        """Server-side view of one cell (its own stats/feed/last_seq) —
        the bench asserts server-measured ``bytes_io`` through this."""
        import json
        return json.loads(self._request(node, wire.MSG_STATUS, b""))

    def maintain(self, node: int, canonical: bool = False) -> bool:
        """Ask one cell to run a vacuum pass (MSG_MAINT).  The default
        background pass acks immediately and keeps serving while it
        runs; ``canonical=True`` instead runs a SYNCHRONOUS canonical
        vacuum — chunk records reordered by key, the pass that makes
        replica files byte-identical under multi-writer interleaving.
        Returns whether a pass ran/started (False: one already
        running).  Results surface in ``cell_status(node)["maint"]``."""
        body = (struct.pack("<B", wire.MAINT_CANON) if canonical else b"")
        reply = self._request(node, wire.MSG_MAINT, body)
        (started,) = struct.unpack_from("<B", reply, 0)
        return bool(started)

    def report_snapshot(self) -> Dict:
        """One-copy storage accounting (see the base class), with the
        node section swapped for the *probed* cluster health — remote
        liveness is a cell property, not derivable from the client's
        write-accounting mirror."""
        snap = super().report_snapshot()
        snap["node_status"] = self.node_status()
        snap["transport"] = self.transport_stats()
        snap["feeds"] = self.feed_status()
        return snap
