"""Standalone multi-writer stress client: one SIGKILL-able writer
process for the ``multiwriter`` chaos bench and stress suite.

Connects a ``RemoteDeltaStore`` to a running cluster, acquires its own
writer lease, and hammers a shared keyspace with seeded-deterministic
PUTs (and occasional DELETEs).  Every *acked* operation is appended to
``--out`` and flushed BEFORE the next one starts, so when the harness
SIGKILLs this process mid-storm the log is exactly the set of writes
the cluster acknowledged — the "zero acked writes lost" oracle.  Lines:

    PUT <tsid> <sid> <pid> <did> <epoch> <seq> <token>
    DEL <tsid> <sid> <pid> <did> <epoch> <seq> -

``token`` seeds the payload (``payload_arrays(token)``), so a verifier
can reconstruct the winning value per key (max ``(epoch, seq)`` across
every writer's log) and compare it byte-for-byte against what the
cluster serves.  Payloads are pure functions of the token — no clocks,
no process state — so the oracle is reproducible across runs.

Exit code 0 after ``--n-writes`` acked operations; 3 if the write
plane degraded (``WriteUnavailable``) past the retry budget.  A torn
last line (SIGKILL between write and flush) is the reader's problem —
``read_acked_log`` drops it.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.service import wire  # noqa: F401  (re-exported for callers)
from repro.service.client import RemoteDeltaStore
from repro.storage.kvstore import (DeltaKey, DeltaStore, StorageNodeDown,
                                   WriteUnavailable, make_vseq)

_DELETE_EVERY = 10  # every 10th op (per writer stream) is a delete


def key_for(slot: int) -> DeltaKey:
    """The shared keyspace: slot -> key, spread over two placements so
    every cell in a small cluster owns traffic."""
    return DeltaKey(tsid=7, sid=slot % 2, did=f"E:{slot}", pid=slot)


def payload_arrays(token: int) -> Dict[str, np.ndarray]:
    """Deterministic payload for one token: seeded arrays, so the blob
    a verifier re-encodes for token T is byte-identical to what the
    writer sent."""
    rng = np.random.default_rng(token)
    n = 16 + token % 17
    return {"src": rng.integers(0, 1 << 20, size=n).astype(np.int64),
            "dst": rng.integers(0, 1 << 20, size=n).astype(np.int64),
            "t": np.arange(token, token + n, dtype=np.int64)}


def encode_token(key: DeltaKey, token: int,
                 fmt: Optional[str] = None) -> Tuple[bytes, int]:
    """(blob, raw_bytes) for one token — the exact bytes a writer fans
    out, reusable by the oracle."""
    enc = DeltaStore(m=1, r=1, backend="mem", fmt=fmt, pool_bytes=0)
    return enc.encode_payload(key, payload_arrays(token))


def read_acked_log(path: Path) -> List[Tuple[str, DeltaKey, int, int]]:
    """Parse one writer's acked log into ``(op, key, vseq, token)``
    rows, dropping a torn (SIGKILLed mid-write) last line."""
    rows: List[Tuple[str, DeltaKey, int, int]] = []
    if not path.exists():
        return rows
    for line in path.read_text().splitlines():
        parts = line.split()
        if len(parts) != 8 or parts[0] not in ("PUT", "DEL"):
            continue  # torn tail or noise: not an acked write
        try:
            op = parts[0]
            key = DeltaKey(int(parts[1]), int(parts[2]), parts[4],
                           int(parts[3]))
            epoch, seq = int(parts[5]), int(parts[6])
            token = 0 if parts[7] == "-" else int(parts[7])
        except ValueError:
            continue
        rows.append((op, key, make_vseq(epoch, seq), token))
    return rows


def run_writer(addrs: List[Tuple[str, int]], r: int, n_writes: int,
               keyspace: int, seed: int, out: Path,
               lease_ttl: float = 1.0, timeout: float = 5.0,
               auth_key: Optional[str] = None,
               fmt: Optional[str] = None) -> int:
    rng = np.random.default_rng(seed)
    store = RemoteDeltaStore(addrs, r=r, fmt=fmt, pool_bytes=0,
                             timeout=timeout, lease_ttl=lease_ttl,
                             auth_key=auth_key,
                             writer_id=f"stress-{seed}")
    degraded_budget = 50
    done = 0
    with open(out, "a") as log:
        while done < n_writes:
            slot = int(rng.integers(0, keyspace))
            token = seed * 1_000_003 + done  # unique per (writer, op)
            key = key_for(slot)
            delete = done % _DELETE_EVERY == (_DELETE_EVERY - 1)
            try:
                if delete:
                    store.delete(key)
                else:
                    blob, raw = encode_token(key, token, fmt)
                    store.put_encoded(key, blob, raw)
            except (WriteUnavailable, StorageNodeDown):
                degraded_budget -= 1
                if degraded_budget <= 0:
                    store.close()
                    return 3
                time.sleep(lease_ttl / 4)
                continue
            st = store.lease_status()
            log.write(f"{'DEL' if delete else 'PUT'} {key.tsid} {key.sid} "
                      f"{key.pid} {key.did} {st['epoch']} {st['seq']} "
                      f"{'-' if delete else token}\n")
            log.flush()  # acked -> durable in the oracle BEFORE next op
            done += 1
    store.quiesce()
    store.close()
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="One lease-fenced stress writer (SIGKILL-able).")
    ap.add_argument("--addrs", required=True,
                    help="comma-separated host:port cells")
    ap.add_argument("--r", type=int, default=2)
    ap.add_argument("--n-writes", type=int, default=200)
    ap.add_argument("--keyspace", type=int, default=32)
    ap.add_argument("--seed", type=int, required=True)
    ap.add_argument("--out", required=True, help="acked-ops log path")
    ap.add_argument("--lease-ttl", type=float, default=1.0)
    ap.add_argument("--timeout", type=float, default=5.0)
    ap.add_argument("--auth-key", default=None)
    ap.add_argument("--fmt", default=None)
    args = ap.parse_args(argv)
    addrs = []
    for part in args.addrs.split(","):
        host, port = part.strip().rsplit(":", 1)
        addrs.append((host, int(port)))
    print(f"WRITER READY seed={args.seed}", flush=True)
    return run_writer(addrs, args.r, args.n_writes, args.keyspace,
                      args.seed, Path(args.out), lease_ttl=args.lease_ttl,
                      timeout=args.timeout, auth_key=args.auth_key,
                      fmt=args.fmt)


if __name__ == "__main__":
    sys.exit(main())
