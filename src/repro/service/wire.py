"""Wire protocol for the temporal graph service plane.

Length-prefixed binary framing over a byte stream (TCP).  Every message
is one frame:

    header (16 bytes, little-endian):
        magic     2s   b"TW"
        version   u8   PROTO_VERSION — checked on BOTH ends; a server
                       answers a mismatched frame with ERR code
                       "VERSION" (framed under ITS version) so old
                       clients fail with ProtocolMismatch, not garbage
        type      u8   message type (MSG_*)
        req_id    u32  request correlation id, echoed in the reply
        body_len  u32  payload byte count (<= MAX_FRAME)
        body_crc  u32  crc32 of the payload
    body (body_len bytes)

Bodies are hand-rolled ``struct`` packing — no msgpack, no pickle.
Block payloads are NOT re-encoded for the wire: a GET reply body *is* a
TGI2 block (``serialize.assemble_block`` of the projected columns), so
per-column crc32s ride end to end and a corrupt reply surfaces as
``BlockCorruption`` on decode, which the client treats as a replica
failure (failover), exactly like a corrupt local disk read.

Decoding is total: truncated, oversized, corrupt, or garbage frames
raise *typed* errors (``FrameError`` / ``FrameTooLarge`` /
``FrameCorrupt`` / ``ProtocolMismatch``) — never a hang, never a
silent mis-parse.  ``decode_frame`` is a pure bytes->Frame function so
the codec is fuzzable without sockets.

Protocol v2 (pipelining + feed compaction):

* **Streaming replies.** A MULTIGET no longer answers with one giant
  OK frame: the server sends one ``MSG_CHUNK`` frame per found key
  (body: ``pack_key + pack_blob``) followed by one ``MSG_END`` frame
  (body: ``<I found_count>``), all under the request's ``req_id``.
  The client starts decoding (and filling its BlockPool) from the
  first CHUNK while the server is still reading later keys, and a
  multiplexed connection can interleave CHUNK streams of concurrent
  requests — the demux key is ``req_id``, not arrival order.
* **Ack piggyback.** The writer client appends a trailing ``<Q
  ack_watermark>`` to PUT / DELETE / PING bodies: the highest seq S
  such that, as far as this client can prove, EVERY cell has applied
  every record it owns with seq <= S (min over nodes of observed
  ``last_seq``, clamped below any queued redelivery).  Cells use the
  watermark to truncate ``feed.log`` (see ``cell.py``); the field is
  optional — an empty PING body or a v1-shaped write body means "no
  ack claim".
* **Feed floor + full-state transfer.** FEED_SINCE replies are
  prefixed with the cell's per-lane floor map (the highest truncated
  seq per writer lane; records at or below their lane's floor are no
  longer in the feed).  A peer that needs records below a floor
  bootstraps via ``MSG_PLACEMENTS`` (list the cell's chunk placements)
  + ``MSG_STATE_PULL`` (verbatim chunk + extent file bytes for one
  placement, plus per-key accounting) — chunk files are pure functions
  of the record set, so copying them preserves the
  byte-identical-convergence property.

Protocol v3 (lease-fenced multi-writer):

* **Versioned seqs.** Every write is stamped with a ``vseq`` — the
  writer's fencing ``epoch`` and its lane-local ``seq`` packed into
  one u64 (``kvstore.make_vseq``; numeric order == lexicographic
  ``(epoch, seq)`` order).  N concurrent writers each own one epoch
  lane; cells merge the lanes deterministically because every per-key
  conflict resolves to the max vseq, whatever the arrival order.
* **Writer leases.** ``MSG_LEASE`` carries acquire / renew / release
  for a time-bounded writer lease: an epoch is granted iff it exceeds
  every epoch the cell has seen (monotonic fencing), a write in lane
  ``e`` refreshes lane ``e``'s lease (heartbeat piggybacked on
  writes), and a write into a *sealed* lane above its seal point is
  rejected with the typed ``ERR_LEASE_FENCED`` — never silently
  applied.
* **Orphan-seq reconciliation.** ``MSG_RECONCILE`` queries a lane's
  replica high-water marks and broadcasts the agreed *seal*: cells
  anti-entropy the dead lane from their peers up to the max
  replica-acked record, fence the lane at that point, and advance the
  lane's ack coverage so feed truncation resumes instead of stranding
  the floor behind a hard-killed writer forever.
* **Shared-secret auth (opt-in).** A cell configured with an auth key
  answers HELLO with ``MSG_AUTH`` carrying a random nonce; the client
  must reply ``MSG_AUTH`` with ``HMAC-SHA256(key, nonce)`` before any
  other frame is served.  A wrong or missing response gets the typed
  ``ERR_AUTH_FAILED`` and a closed connection.
"""
from __future__ import annotations

import socket
import struct
import zlib
from typing import Dict, List, NamedTuple, Optional, Tuple

from repro.storage.kvstore import (DeltaKey,  # noqa: F401 — re-exported
                                   make_vseq, split_vseq)

PROTO_VERSION = 3
FRAME_MAGIC = b"TW"
HEADER = struct.Struct("<2sBBIII")  # magic, version, type, req_id, len, crc
MAX_FRAME = 1 << 28  # 256 MiB: far above any block, far below a bomb

(MSG_HELLO, MSG_OK, MSG_ERR, MSG_PING, MSG_GET, MSG_MULTIGET, MSG_PUT,
 MSG_DELETE, MSG_FEED_SINCE, MSG_STATUS, MSG_KEYS,
 MSG_MAINT, MSG_CHUNK, MSG_END, MSG_PLACEMENTS,
 MSG_STATE_PULL, MSG_LEASE, MSG_RECONCILE, MSG_AUTH) = range(1, 20)

# ERR body codes (pack_str'd): the client maps these back to the local
# store's exception types so failure semantics match the local backend
ERR_KEY_MISSING = "KEY_MISSING"
ERR_BAD_REQUEST = "BAD_REQUEST"
ERR_INTERNAL = "INTERNAL"
ERR_VERSION = "VERSION"
# requested feed history predates the truncation floor and the cell
# cannot serve a full-state transfer (mem backend): caller must either
# bootstrap from a file-backed replica or accept the typed failure
ERR_FEED_TRUNCATED = "FEED_TRUNCATED"
# write stamped into a sealed (fenced) lane above its seal point: the
# writer's lease expired and a reconciliation pass closed the lane, or
# a newer writer fenced it — the write must NOT be applied anywhere
ERR_LEASE_FENCED = "LEASE_FENCED"
# HELLO auth handshake failed: wrong or missing shared-secret HMAC
ERR_AUTH_FAILED = "AUTH_FAILED"

# change-feed record ops
OP_PUT = 0
OP_DELETE = 1

# MAINT body flags (an empty MAINT body means "vacuum only" — the v1
# shape).  TRUNCATE forces a synchronous feed truncation up to the
# cell's ack coverage regardless of backlog size, so benches/tests can
# reach a deterministic final feed state before comparing files.
# CANON runs a *synchronous* canonical vacuum (chunk records reordered
# by record key — the byte-identity anchor under multi-writer
# interleave; see ``DeltaStore.vacuum(canonical=True)``).
MAINT_VACUUM = 1
MAINT_TRUNCATE = 2
MAINT_CANON = 4

# MSG_LEASE ops
LEASE_ACQUIRE = 1
LEASE_RENEW = 2
LEASE_RELEASE = 3

# MSG_RECONCILE ops.  PREPARE runs between QUERY and SEAL: every cell
# anti-entropies its lane gaps from the peer list while every feed is
# still intact — sealing truncates, so nobody may seal until the whole
# cluster holds what it owns.
RECONCILE_QUERY = 1
RECONCILE_SEAL = 2
RECONCILE_PREPARE = 3

# auth handshake sizes: the server's random challenge and the client's
# HMAC-SHA256 response
AUTH_NONCE_LEN = 16
AUTH_MAC_LEN = 32


class WireError(RuntimeError):
    """Base of every wire-protocol error."""


class FrameError(WireError):
    """Malformed frame: bad magic, truncated header/body, or trailing
    garbage where a frame boundary should be."""


class FrameTooLarge(WireError):
    """Declared body length exceeds MAX_FRAME — rejected before any
    body byte is read, so a hostile length can't balloon memory."""


class FrameCorrupt(WireError):
    """Body bytes fail the header's crc32."""


class ProtocolMismatch(WireError):
    """Peer speaks a different PROTO_VERSION."""


class ConnectionClosed(WireError):
    """Clean EOF between frames (peer went away)."""


class LeaseFenced(WireError):
    """A write carried an epoch whose lane is sealed at or below the
    write's seq: the writer's lease expired (or a newer writer fenced
    it) and reconciliation closed the lane.  The write was NOT applied;
    the writer must degrade and re-acquire a fresh epoch."""


class AuthFailed(WireError):
    """The HELLO auth handshake failed: the cell requires a shared
    secret this client lacks, the HMAC response was wrong, or the cell
    refused an unauthenticated request."""


class RemoteError(WireError):
    """Server-side failure relayed through an ERR frame."""

    def __init__(self, code: str, message: str):
        super().__init__(f"{code}: {message}")
        self.code = code
        self.message = message


class Frame(NamedTuple):
    version: int
    msg_type: int
    req_id: int
    body: bytes


# ---------------------------------------------------------------------------
# frame codec (pure bytes <-> Frame; the socket layer wraps these)
# ---------------------------------------------------------------------------


def encode_frame(msg_type: int, req_id: int, body: bytes = b"",
                 version: int = PROTO_VERSION) -> bytes:
    if len(body) > MAX_FRAME:
        raise FrameTooLarge(f"body of {len(body)} bytes exceeds MAX_FRAME")
    return HEADER.pack(FRAME_MAGIC, version, msg_type, req_id, len(body),
                       zlib.crc32(body) & 0xFFFFFFFF) + body


def decode_frame(data: bytes) -> Tuple[Frame, int]:
    """Decode one complete frame from the head of ``data``; returns
    ``(frame, bytes_consumed)``.  Raises typed errors on anything that
    is not a well-formed frame — a decoder that can't throw can only
    hang or mis-parse."""
    if len(data) < HEADER.size:
        raise FrameError(f"truncated header: {len(data)} < {HEADER.size} bytes")
    magic, version, msg_type, req_id, body_len, body_crc = HEADER.unpack_from(data)
    if magic != FRAME_MAGIC:
        raise FrameError(f"bad frame magic {magic!r}")
    if body_len > MAX_FRAME:
        raise FrameTooLarge(f"declared body of {body_len} bytes exceeds MAX_FRAME")
    end = HEADER.size + body_len
    if len(data) < end:
        raise FrameError(f"truncated body: have {len(data) - HEADER.size} "
                         f"of {body_len} bytes")
    body = bytes(data[HEADER.size:end])
    if zlib.crc32(body) & 0xFFFFFFFF != body_crc:
        raise FrameCorrupt("frame body crc32 mismatch")
    return Frame(version, msg_type, req_id, body), end


def _recv_exact(sock: socket.socket, n: int, mid_frame: bool) -> bytes:
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(n - got)
        if not chunk:
            if got == 0 and not mid_frame:
                raise ConnectionClosed("peer closed the connection")
            raise FrameError(f"connection closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_frame(sock: socket.socket, msg_type: int, req_id: int,
               body: bytes = b"", version: int = PROTO_VERSION) -> None:
    sock.sendall(encode_frame(msg_type, req_id, body, version))


def recv_frame(sock: socket.socket) -> Frame:
    """Read one frame off a socket.  The header is validated before the
    body is read, so an oversized length raises without allocating."""
    head = _recv_exact(sock, HEADER.size, mid_frame=False)
    magic, version, msg_type, req_id, body_len, body_crc = HEADER.unpack(head)
    if magic != FRAME_MAGIC:
        raise FrameError(f"bad frame magic {magic!r}")
    if body_len > MAX_FRAME:
        raise FrameTooLarge(f"declared body of {body_len} bytes exceeds MAX_FRAME")
    body = _recv_exact(sock, body_len, mid_frame=True) if body_len else b""
    if zlib.crc32(body) & 0xFFFFFFFF != body_crc:
        raise FrameCorrupt("frame body crc32 mismatch")
    return Frame(version, msg_type, req_id, body)


class FrameReader:
    """Buffered frame reader for pipelined streams: one ``recv`` syscall
    can carry many frames (a multiget's CHUNK train, a burst of small
    requests), so the per-frame syscall pair of ``recv_frame`` collapses
    to ~one per buffer fill.  Same validation, same typed errors, same
    frames — only the socket read granularity changes.  Not for sharing
    between threads (buffered bytes belong to one reader)."""

    __slots__ = ("sock", "bufsize", "_buf")

    def __init__(self, sock: socket.socket, bufsize: int = 1 << 18):
        self.sock = sock
        self.bufsize = bufsize
        self._buf = bytearray()

    def _parse_one(self) -> Optional[Frame]:
        buf = self._buf
        if len(buf) < HEADER.size:
            return None
        magic, version, msg_type, req_id, body_len, body_crc = \
            HEADER.unpack_from(buf)
        if magic != FRAME_MAGIC:
            raise FrameError(f"bad frame magic {magic!r}")
        if body_len > MAX_FRAME:
            raise FrameTooLarge(
                f"declared body of {body_len} bytes exceeds MAX_FRAME")
        end = HEADER.size + body_len
        if len(buf) < end:
            return None
        body = bytes(buf[HEADER.size:end])
        if zlib.crc32(body) & 0xFFFFFFFF != body_crc:
            raise FrameCorrupt("frame body crc32 mismatch")
        del buf[:end]
        return Frame(version, msg_type, req_id, body)

    def _fill(self) -> None:
        chunk = self.sock.recv(self.bufsize)
        if not chunk:
            if self._buf:
                raise FrameError(
                    f"connection closed mid-frame ({len(self._buf)} "
                    f"buffered bytes)")
            raise ConnectionClosed("peer closed the connection")
        self._buf += chunk

    def next_frame(self) -> Frame:
        """Blocking read of the next frame (drop-in for ``recv_frame``)."""
        while True:
            frame = self._parse_one()
            if frame is not None:
                return frame
            self._fill()

    def read_frames(self) -> List[Frame]:
        """Block until at least one frame is available, then return every
        complete frame currently buffered — the demux loop's batch unit."""
        out: List[Frame] = []
        while True:
            frame = self._parse_one()
            if frame is None:
                if out:
                    return out
                self._fill()
            else:
                out.append(frame)


# ---------------------------------------------------------------------------
# body packing helpers (hand-rolled struct, no external codec)
# ---------------------------------------------------------------------------


def _need(buf: bytes, off: int, n: int, what: str) -> None:
    if off + n > len(buf):
        raise FrameError(f"truncated {what}: need {n} bytes at offset {off}, "
                         f"have {len(buf) - off}")


def pack_str(s: str) -> bytes:
    b = s.encode()
    return struct.pack("<H", len(b)) + b


def unpack_str(buf: bytes, off: int) -> Tuple[str, int]:
    _need(buf, off, 2, "string length")
    (n,) = struct.unpack_from("<H", buf, off)
    off += 2
    _need(buf, off, n, "string body")
    return buf[off:off + n].decode(), off + n


def pack_key(key: DeltaKey) -> bytes:
    return struct.pack("<qqq", key.tsid, key.sid, key.pid) + pack_str(key.did)


def unpack_key(buf: bytes, off: int) -> Tuple[DeltaKey, int]:
    tsid, sid, pid = struct.unpack_from("<qqq", buf, off)
    did, off = unpack_str(buf, off + 24)
    return DeltaKey(tsid, sid, did, pid), off


# u16 0xFFFF marks "no projection" (fields=None: every column); 0 is a
# legal empty projection
_ALL_FIELDS = 0xFFFF


def pack_fields(fields: Optional[List[str]]) -> bytes:
    if fields is None:
        return struct.pack("<H", _ALL_FIELDS)
    assert len(fields) < _ALL_FIELDS
    return struct.pack("<H", len(fields)) + b"".join(pack_str(f) for f in fields)


def unpack_fields(buf: bytes, off: int) -> Tuple[Optional[List[str]], int]:
    (n,) = struct.unpack_from("<H", buf, off)
    off += 2
    if n == _ALL_FIELDS:
        return None, off
    out = []
    for _ in range(n):
        f, off = unpack_str(buf, off)
        out.append(f)
    return out, off


def pack_blob(b: bytes) -> bytes:
    return struct.pack("<I", len(b)) + b


def unpack_blob(buf: bytes, off: int) -> Tuple[bytes, int]:
    _need(buf, off, 4, "blob length")
    (n,) = struct.unpack_from("<I", buf, off)
    off += 4
    _need(buf, off, n, "blob body")
    return bytes(buf[off:off + n]), off + n


def pack_lanes(lanes: Dict[int, int]) -> bytes:
    """Per-lane ``{epoch: seq}`` map (floor maps, seal maps, ack maps),
    emitted in sorted epoch order so the bytes are a pure function of
    the mapping — lane maps ride ``feed.base`` and the byte-identity
    property extends to them."""
    out = [struct.pack("<I", len(lanes))]
    for epoch in sorted(lanes):
        out.append(struct.pack("<QQ", epoch, lanes[epoch]))
    return b"".join(out)


def unpack_lanes(buf: bytes, off: int) -> Tuple[Dict[int, int], int]:
    _need(buf, off, 4, "lane count")
    (n,) = struct.unpack_from("<I", buf, off)
    off += 4
    lanes: Dict[int, int] = {}
    for _ in range(n):
        _need(buf, off, 16, "lane entry")
        epoch, seq = struct.unpack_from("<QQ", buf, off)
        off += 16
        lanes[epoch] = seq
    return lanes, off


def pack_peers(peers: List[Tuple[str, int]]) -> bytes:
    """Cluster address list: LEASE acquire and RECONCILE seal frames
    carry it so cells learn the topology they need for lease-expiry
    reconciliation (anti-entropy pulls peer feeds)."""
    out = [struct.pack("<I", len(peers))]
    for host, port in peers:
        out.append(pack_str(host) + struct.pack("<H", port))
    return b"".join(out)


def unpack_peers(buf: bytes, off: int) -> Tuple[List[Tuple[str, int]], int]:
    _need(buf, off, 4, "peer count")
    (n,) = struct.unpack_from("<I", buf, off)
    off += 4
    peers: List[Tuple[str, int]] = []
    for _ in range(n):
        host, off = unpack_str(buf, off)
        _need(buf, off, 2, "peer port")
        (port,) = struct.unpack_from("<H", buf, off)
        off += 2
        peers.append((host, port))
    return peers, off


class FeedRecord(NamedTuple):
    """One change-feed entry: a client-stamped ``seq`` plus the write it
    carries.  ``seq`` is a *vseq* — the writer's fencing epoch and its
    lane-local counter packed into one u64 (``kvstore.make_vseq``), so
    the u64 order is the cluster-wide (epoch, seq) total order; legacy
    single-writer records live in epoch 0 unchanged.  ``blob`` is the
    encoded block verbatim (``raw_bytes`` rides along for storage
    accounting); DELETE records carry an empty blob.  Applying a record
    set in vseq order — or any order, once per-key conflicts resolve to
    the max vseq and a canonical vacuum pass orders the chunk bytes —
    reproduces a cell's files byte for byte: the catch-up convergence
    property, extended to N concurrent writer lanes."""

    seq: int
    op: int  # OP_PUT | OP_DELETE
    key: DeltaKey
    raw_bytes: int
    blob: bytes

    def pack(self) -> bytes:
        return (struct.pack("<QB", self.seq, self.op) + pack_key(self.key)
                + struct.pack("<Q", self.raw_bytes) + pack_blob(self.blob))

    @staticmethod
    def unpack(buf: bytes, off: int) -> Tuple["FeedRecord", int]:
        seq, op = struct.unpack_from("<QB", buf, off)
        key, off = unpack_key(buf, off + 9)
        (raw,) = struct.unpack_from("<Q", buf, off)
        blob, off = unpack_blob(buf, off + 8)
        return FeedRecord(seq, op, key, raw, blob), off


def pack_records(records: List[FeedRecord]) -> bytes:
    return struct.pack("<I", len(records)) + b"".join(r.pack() for r in records)


def unpack_records(buf: bytes, off: int = 0) -> List[FeedRecord]:
    (n,) = struct.unpack_from("<I", buf, off)
    off += 4
    out = []
    for _ in range(n):
        rec, off = FeedRecord.unpack(buf, off)
        out.append(rec)
    return out


def pack_err(code: str, message: str) -> bytes:
    return pack_str(code) + pack_str(message)


def unpack_err(buf: bytes) -> Tuple[str, str]:
    code, off = unpack_str(buf, 0)
    message, _ = unpack_str(buf, off)
    return code, message


# ---------------------------------------------------------------------------
# full-state transfer (bootstrap past a truncated feed)
# ---------------------------------------------------------------------------


class PlacementState(NamedTuple):
    """STATE_PULL reply for one ``(tsid, sid)`` placement: the replica's
    chunk + extent file bytes *verbatim* (chunk files are pure functions
    of the applied record set, so copying them preserves byte-identical
    convergence), plus the per-key accounting a restored cell needs:
    live ``(key, raw, enc)`` sizes and the per-key max-vseq watermark
    (including deleted keys, whose watermark guards replays), plus the
    serving cell's per-lane floor and seal maps at pull time."""

    floors: Dict[int, int]  # serving cell's per-lane feed floors
    seals: Dict[int, int]   # serving cell's sealed (fenced) lanes
    chunk: bytes
    ext: bytes
    sizes: List[Tuple[DeltaKey, int, int]]
    key_seqs: List[Tuple[DeltaKey, int]]

    def pack(self) -> bytes:
        out = [pack_lanes(self.floors), pack_lanes(self.seals),
               pack_blob(self.chunk),
               pack_blob(self.ext), struct.pack("<I", len(self.sizes))]
        for key, raw, enc in self.sizes:
            out.append(pack_key(key) + struct.pack("<QQ", raw, enc))
        out.append(struct.pack("<I", len(self.key_seqs)))
        for key, seq in self.key_seqs:
            out.append(pack_key(key) + struct.pack("<Q", seq))
        return b"".join(out)

    @staticmethod
    def unpack(buf: bytes) -> "PlacementState":
        floors, off = unpack_lanes(buf, 0)
        seals, off = unpack_lanes(buf, off)
        chunk, off = unpack_blob(buf, off)
        ext, off = unpack_blob(buf, off)
        _need(buf, off, 4, "state size count")
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        sizes = []
        for _ in range(n):
            key, off = unpack_key(buf, off)
            _need(buf, off, 16, "state key sizes")
            raw, enc = struct.unpack_from("<QQ", buf, off)
            off += 16
            sizes.append((key, raw, enc))
        _need(buf, off, 4, "state seq count")
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        key_seqs = []
        for _ in range(n):
            key, off = unpack_key(buf, off)
            _need(buf, off, 8, "state key seq")
            (seq,) = struct.unpack_from("<Q", buf, off)
            off += 8
            key_seqs.append((key, seq))
        return PlacementState(floors, seals, chunk, ext, sizes, key_seqs)


def pack_placements(placements: List[Tuple[int, int]]) -> bytes:
    return (struct.pack("<I", len(placements))
            + b"".join(struct.pack("<qq", t, s) for t, s in placements))


def unpack_placements(buf: bytes) -> List[Tuple[int, int]]:
    _need(buf, 0, 4, "placement count")
    (n,) = struct.unpack_from("<I", buf, 0)
    off = 4
    out = []
    for _ in range(n):
        _need(buf, off, 16, "placement entry")
        t, s = struct.unpack_from("<qq", buf, off)
        off += 16
        out.append((t, s))
    return out
