"""granite-3-8b [dense] — GQA.

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155
[hf:ibm-granite/granite-3.0 family; hf tier]

Full attention => long_500k SKIPPED.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab_size=49_155,
    head_dim=128,
    attn_kind="full",
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    norm_kind="rmsnorm",
    tie_embeddings=True,
    supports_long_context=False,
)
