"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent.

38L d_model=4096 16H (GQA kv=1, i.e. MQA) d_ff=12288 vocab=256000
[arXiv:2402.19427 (Griffin) / RecurrentGemma; unverified tier]

Pattern: repeating unit (rec, rec, attn); 38 = 12*3 + 2 — the two remainder
layers are recurrent blocks prepended before the scanned units (Griffin
starts with recurrent blocks).  Local attention window 2048 per the Griffin
paper.  Bounded state (RG-LRU state + windowed KV) => long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    head_dim=256,
    attn_kind="swa",
    window=2048,
    mlp_kind="swiglu",
    block_pattern=("rec", "rec", "attn"),
    rnn_width=4096,
    conv_width=4,
    pos_kind="rope",
    rope_theta=10_000.0,
    norm_kind="rmsnorm",
    tie_embeddings=True,
    attn_logit_softcap=0.0,
    supports_long_context=True,
)
