"""qwen3-1.7b [dense] — qk_norm, GQA.

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936
[hf:Qwen/Qwen3-8B family; hf tier]

Full attention => long_500k SKIPPED.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab_size=151_936,
    head_dim=128,
    attn_kind="full",
    qk_norm=True,
    qkv_bias=False,
    mlp_kind="swiglu",
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    tie_embeddings=True,
    supports_long_context=False,
)
