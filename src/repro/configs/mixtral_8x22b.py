"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2
[arXiv:2401.04088; hf tier]

SWA window 4096 (Mixtral lineage).  Windowed KV bounds the decode cache =>
long_500k runs with a ring-buffer cache of `window` tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32_768,
    head_dim=128,
    attn_kind="swa",
    window=4096,
    mlp_kind="swiglu",
    n_experts=8,
    top_k=2,
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    supports_long_context=True,
)
