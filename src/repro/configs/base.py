"""Model / run configuration system.

Every assigned architecture is a ``ModelConfig`` instance in its own
``src/repro/configs/<id>.py`` module, selectable via ``--arch <id>`` in the
launchers.  The config is a plain frozen dataclass: no registry magic, no
lazy imports — ``repro.configs.get_config(name)`` resolves by module name.

Input *shapes* (train_4k / prefill_32k / decode_32k / long_500k) are
``ShapeConfig`` instances shared by all LM archs; ``input_specs`` in
``repro.launch.specs`` turns (ModelConfig, ShapeConfig) into
``jax.ShapeDtypeStruct`` stand-ins for the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Shape configs (assigned per the task: seq_len x global_batch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: what step to lower and at what size."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture; exact numbers from the assignment table."""

    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    head_dim: int = 0  # 0 => d_model // n_heads
    attn_kind: str = "full"  # full | swa (sliding window) | local (block-local)
    window: int = 0  # sliding/local window size (tokens)
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_logit_softcap: float = 0.0

    # --- mlp ---
    mlp_kind: str = "swiglu"  # swiglu | relu2 | gelu
    mlp_bias: bool = False

    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "dense"  # dense (dispatch-einsum, FSDP weights) | ep (shard_map expert parallel)

    # --- hybrid / ssm block pattern ---
    # repeating unit of block kinds, e.g. ('rec','rec','attn') for griffin,
    # ('mlstm','mlstm','mlstm','slstm') for xlstm.  Empty => all 'attn'.
    block_pattern: Tuple[str, ...] = ()
    rnn_width: int = 0  # RG-LRU recurrence width (0 => d_model)
    conv_width: int = 4  # temporal conv in recurrent blocks
    mlstm_chunk: int = 256  # chunk size for chunkwise-parallel mLSTM

    # --- encoder-decoder (whisper) ---
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1_500  # whisper: 30s of audio at 50 fps after conv stride 2

    # --- vlm stub ---
    n_img_tokens: int = 0  # patch embeddings prepended to the text sequence

    # --- positions / norms / embeddings ---
    pos_kind: str = "rope"  # rope | learned | sinusoidal | none
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- precision & perf knobs (hillclimb levers) ---
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"  # master params (train); serving casts to dtype
    remat: str = "full"  # none | full | dots — activation checkpoint policy
    scan_layers: bool = True  # lax.scan over layer units (compile-size control)
    # 'blockwise' = flash-style online-softmax scan (memory-sane, used for
    # real execution); 'direct' = plain masked-softmax einsum — used by the
    # dry-run COST PROBE so cost_analysis sees attention FLOPs outside a
    # while body (scan bodies are counted once by XLA cost analysis).
    attn_impl: str = "blockwise"
    # Pad attention heads up to a multiple of the TP degree (0 = off).
    # Head counts that don't divide the model axis (qwen2: 28H, whisper:
    # 12H on a 16-way axis) otherwise fall back to head_dim-sharded
    # attention, whose contracting partial-sums are collective-bound.
    # Padded q heads have ZEROED output-projection rows (function-
    # preserving at init; training would mask their grads — §Perf).
    pad_heads_multiple: int = 0

    @property
    def n_heads_p(self) -> int:
        m = self.pad_heads_multiple
        if not m:
            return self.n_heads
        return ((self.n_heads + m - 1) // m) * m

    @property
    def n_kv_p(self) -> int:
        if not self.pad_heads_multiple:
            return self.n_kv_heads
        hp = self.n_heads_p
        return self.n_kv_heads if hp % self.n_kv_heads == 0 else hp

    # long-context capability: archs with bounded state (window attention,
    # recurrent state) can run the long_500k decode shape sub-quadratically.
    # Pure full-attention archs skip it (recorded in DESIGN.md).
    supports_long_context: bool = False

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_rnn_width(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def resolved_pattern(self) -> Tuple[str, ...]:
        return self.block_pattern or ("attn",)

    @property
    def unit_len(self) -> int:
        return len(self.resolved_pattern)

    @property
    def n_units(self) -> int:
        return self.n_layers // self.unit_len

    @property
    def n_rem_layers(self) -> int:
        """Layers that do not fill a whole repeating unit (prepended,
        un-scanned, using the first block kinds of the pattern)."""
        return self.n_layers - self.n_units * self.unit_len

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ----- reduced config for CPU smoke tests -----
    def reduced(self) -> "ModelConfig":
        """Same family/topology, tiny dimensions: one scanned unit (plus the
        remainder structure), small width, few experts, tiny vocab."""
        unit = self.unit_len
        n_layers = unit + (1 if self.n_rem_layers else 0) * min(self.n_rem_layers, unit - 1)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        return self.replace(
            n_layers=max(n_layers, unit),
            d_model=64,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            window=min(self.window, 32) if self.window else 0,
            rnn_width=64 if self.rnn_width or self.family in ("hybrid", "ssm") else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=24 if self.is_encdec else self.enc_seq,
            n_img_tokens=8 if self.n_img_tokens else 0,
            mlstm_chunk=16,
            param_dtype="float32",
            dtype="float32",
            remat="none",
        )


def get_config(name: str) -> ModelConfig:
    """Resolve an architecture id (e.g. 'mixtral-8x22b') to its config."""
    import importlib

    mod_name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


ARCH_IDS = (
    "recurrentgemma-9b",
    "xlstm-350m",
    "mixtral-8x22b",
    "phi3.5-moe-42b-a6.6b",
    "phi-3-vision-4.2b",
    "whisper-small",
    "qwen3-1.7b",
    "qwen2-7b",
    "minitron-8b",
    "granite-3-8b",
)
