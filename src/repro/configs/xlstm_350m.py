"""xlstm-350m [ssm] — sLSTM + mLSTM blocks.

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304
[arXiv:2405.04517; unverified tier]

d_ff=0: xLSTM blocks carry their own up/down projections (mLSTM uses a
pre-up-projection block with expansion 2, sLSTM a post-gated-FFN with
expansion 4/3) — there is no separate transformer FFN.  Pattern: the paper's
xLSTM[a:b] notation mixes mLSTM and sLSTM blocks; we use a repeating unit of
(m, m, m, s) => 6 units over 24 layers (an xLSTM[3:1]-style ratio).  O(1)
recurrent state => long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    head_dim=256,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    conv_width=4,
    mlstm_chunk=256,
    pos_kind="none",  # recurrence encodes position
    norm_kind="layernorm",
    tie_embeddings=True,
    supports_long_context=True,
)
