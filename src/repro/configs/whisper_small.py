"""whisper-small [audio] — encoder-decoder, conv frontend stubbed.

12L (12 encoder + 12 decoder) d_model=768 12H (kv=12) d_ff=3072 vocab=51865
[arXiv:2212.04356; unverified tier]

The conv1d mel frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings (enc_seq=1500 x d_model).  Decoder uses learned positions;
the published model caps decoder context at 448 tokens — the decode_32k /
prefill_32k shapes extend the learned-position table (mechanical config
change, recorded in DESIGN.md).  Full attention, enc-dec => long_500k
SKIPPED.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,  # decoder layers; n_enc_layers below
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    head_dim=64,
    attn_kind="full",
    qkv_bias=True,  # whisper uses biases (q,v and out; k has none — we use uniform bias)
    mlp_kind="gelu",
    mlp_bias=True,
    is_encdec=True,
    n_enc_layers=12,
    enc_seq=1500,
    pos_kind="learned",
    norm_kind="layernorm",
    tie_embeddings=True,
    supports_long_context=False,
)
