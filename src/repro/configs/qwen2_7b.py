"""qwen2-7b [dense] — GQA with QKV bias.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064
[arXiv:2407.10671; hf tier]

Full attention => long_500k SKIPPED.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152_064,
    head_dim=128,
    attn_kind="full",
    qkv_bias=True,
    mlp_kind="swiglu",
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    supports_long_context=False,
)
