"""minitron-8b [dense] — pruned Nemotron-4.

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000
[arXiv:2407.14679; hf tier]

Nemotron lineage: squared-ReLU MLP (non-gated), no bias.  Full attention
=> long_500k SKIPPED.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256_000,
    head_dim=128,
    attn_kind="full",
    mlp_kind="relu2",
    rope_theta=10_000.0,
    norm_kind="layernorm",  # nemotron uses LayerNorm-1p; plain LN here
    supports_long_context=False,
)
