"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stubbed).

32L d_model=3072 32H (GQA kv=32, i.e. MHA) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct; hf tier]

Per the assignment, the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (n_img_tokens x d_model) which the
backbone prepends to the text token embeddings.  576 patch tokens (24x24,
the CLIP-ViT-L/14 336px grid).  Full attention => long_500k SKIPPED.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
    head_dim=96,
    attn_kind="full",
    mlp_kind="swiglu",
    n_img_tokens=576,
    rope_theta=10_000.0,
    norm_kind="rmsnorm",
    supports_long_context=False,
)
