from repro.models import lm
from repro.models.sharding import (
    DEFAULT_RULES,
    ParamLeaf,
    Sharder,
    make_rules,
    n_kv_virtual,
    spec_for,
    split_tree,
)

__all__ = [
    "lm",
    "DEFAULT_RULES",
    "ParamLeaf",
    "Sharder",
    "make_rules",
    "n_kv_virtual",
    "spec_for",
    "split_tree",
]
