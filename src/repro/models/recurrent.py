"""Griffin/RecurrentGemma recurrent block: temporal conv + RG-LRU.

The RG-LRU recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * r_t) is a first-order linear
recurrence — full-sequence evaluation uses jax.lax.associative_scan
(log-depth) on (log_a, b) pairs; on TPU the Pallas kernel
repro.kernels.rglru_scan implements the chunked VMEM-resident variant
with this path as its oracle.  Gates are block-diagonal per head, as in
Griffin (keeps the 9B param count honest).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Init
from repro.models.sharding import Sharder

RGLRU_C = 8.0


def init_rec_block(ini: Init, cfg):
    D = cfg.d_model
    W = cfg.resolved_rnn_width
    H = cfg.n_heads
    bw = W // H  # block width for block-diagonal gates
    return {
        "w_x": ini.fan_in((D, W), ("embed", "rnn")),
        "w_gate": ini.fan_in((D, W), ("embed", "rnn")),
        "conv_w": ini.normal((cfg.conv_width, W), ("conv", "rnn"), scale=0.1),
        "conv_b": ini.zeros((W,), ("rnn",)),
        "gate_a_w": ini.fan_in((H, bw, bw), ("heads", None, "rnn"), fan_axes=(1,)),
        "gate_a_b": ini.zeros((H, bw), ("heads", "rnn")),
        "gate_x_w": ini.fan_in((H, bw, bw), ("heads", None, "rnn"), fan_axes=(1,)),
        "gate_x_b": ini.zeros((H, bw), ("heads", "rnn")),
        # Lambda parametrized so a = sigmoid(Lambda) starts near 0.9..0.999
        "lam": ini.const((W,), ("rnn",), 4.0),
        "w_out": ini.fan_in((W, D), ("rnn", "embed")),
    }


def causal_conv1d(x, w, b):
    """Depthwise causal conv. x: (B,S,W); w: (cw,W). Unrolled shifts —
    cw=4, so 4 shifted multiply-adds (cheap, fusion-friendly)."""
    cw = w.shape[0]
    y = jnp.zeros_like(x)
    for j in range(cw):
        shift = cw - 1 - j
        xj = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        y = y + xj * w[j].astype(x.dtype)
    return y + b.astype(x.dtype)


def _block_diag(u, w, b, H):
    """u: (B,S,W) -> per-head block-diagonal linear, w: (H,bw,bw)."""
    B, S, W = u.shape
    uh = u.reshape(B, S, H, W // H)
    y = jnp.einsum("bshi,hij->bshj", uh, w.astype(u.dtype)) + b.astype(u.dtype)
    return y.reshape(B, S, W)


def _rglru_coeffs(p, u, cfg):
    """Returns (log_a (B,S,W) f32, b (B,S,W) f32)."""
    H = cfg.n_heads
    r = jax.nn.sigmoid(
        _block_diag(u, p["gate_a_w"], p["gate_a_b"], H).astype(jnp.float32)
    )
    gi = jax.nn.sigmoid(
        _block_diag(u, p["gate_x_w"], p["gate_x_b"], H).astype(jnp.float32)
    )
    log_a = -RGLRU_C * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    b = mult * gi * u.astype(jnp.float32)
    return log_a, b


def rglru_scan(log_a, b):
    """Associative scan for h_t = exp(log_a_t) h_{t-1} + b_t over axis 1."""

    def combine(e1, e2):
        la1, b1 = e1
        la2, b2 = e2
        return la1 + la2, jnp.exp(la2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h


def rec_forward(p, x, cfg, shd: Sharder, use_pallas: bool = False):
    """Full-sequence Griffin recurrent mixer. x: (B,S,D) -> (B,S,D)."""
    dt = jnp.dtype(cfg.dtype)
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(dt))
    u = shd.act(u, "batch", "seq", "rnn")
    u = causal_conv1d(u, p["conv_w"], p["conv_b"])
    log_a, b = _rglru_coeffs(p, u, cfg)
    if use_pallas:
        from repro.kernels.rglru_scan import ops as rg_ops

        h = rg_ops.rglru(log_a, b)
    else:
        h = rglru_scan(log_a, b)
    h = h.astype(dt)
    h = shd.act(h, "batch", "seq", "rnn")
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(dt)))
    y = jnp.einsum("bsw,wd->bsd", h * gate, p["w_out"].astype(dt))
    return shd.act(y, "batch", "res_seq", "act_embed")


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_rec_cache(ini: Init, cfg, batch: int):
    W = cfg.resolved_rnn_width
    return {
        "h": ini.zeros((batch, W), ("batch", "rnn"), dtype=jnp.float32),
        "conv": ini.zeros(
            (batch, cfg.conv_width - 1, W), ("batch", None, "rnn"), dtype=jnp.dtype(cfg.dtype)
        ),
    }


def rec_decode(p, x, cache, cfg, shd: Sharder):
    """x: (B,1,D). cache: {'h': (B,W) f32, 'conv': (B,cw-1,W)}."""
    dt = jnp.dtype(cfg.dtype)
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(dt))  # (B,1,W)
    # conv over [state, u]
    hist = jnp.concatenate([cache["conv"], u], axis=1)  # (B,cw,W)
    w = p["conv_w"].astype(dt)
    u_c = jnp.einsum("bcw,cw->bw", hist, w)[:, None] + p["conv_b"].astype(dt)
    new_conv = hist[:, 1:]
    log_a, b = _rglru_coeffs(p, u_c, cfg)
    h = jnp.exp(log_a[:, 0]) * cache["h"] + b[:, 0]  # (B,W) f32
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(dt)))
    y = jnp.einsum("bsw,wd->bsd", h[:, None].astype(dt) * gate, p["w_out"].astype(dt))
    return y, {"h": h, "conv": new_conv}


def rec_prefill_cache(p, x, cfg, shd: Sharder):
    """Run the mixer over the full sequence, return final recurrent state
    and conv tail for subsequent decode."""
    dt = jnp.dtype(cfg.dtype)
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(dt))
    u_conv = causal_conv1d(u, p["conv_w"], p["conv_b"])
    log_a, b = _rglru_coeffs(p, u_conv, cfg)
    h = rglru_scan(log_a, b)
    cw = cfg.conv_width
    return {"h": h[:, -1], "conv": u[:, -(cw - 1) :]}
