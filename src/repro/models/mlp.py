"""Dense FFN variants: SwiGLU (llama lineage), squared-ReLU (nemotron),
GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Init
from repro.models.sharding import Sharder


def init_mlp(ini: Init, cfg):
    D, F = cfg.d_model, cfg.d_ff
    if cfg.mlp_kind == "swiglu":
        p = {
            "w_gate": ini.fan_in((D, F), ("embed", "mlp")),
            "w_up": ini.fan_in((D, F), ("embed", "mlp")),
            "w_down": ini.fan_in((F, D), ("mlp", "embed")),
        }
    else:
        p = {
            "w_up": ini.fan_in((D, F), ("embed", "mlp")),
            "w_down": ini.fan_in((F, D), ("mlp", "embed")),
        }
    if cfg.mlp_bias:
        p["b_up"] = ini.zeros((F,), ("act_mlp",))
        p["b_down"] = ini.zeros((D,), ("act_embed",))
    return p


def mlp_forward(p, x, cfg, shd: Sharder):
    dt = jnp.dtype(cfg.dtype)
    # hillclimb hook: decode-time resident-weight layout constrains the
    # FFN input's d_model over the data axis (contraction-aligned with the
    # weights' FSDP shards -> psum instead of per-step weight gathers);
    # default rules map both names to () = no-op.
    x = shd.act(x, "ffn_batch", None, "ffn_embed")
    if cfg.mlp_kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        if cfg.mlp_bias:
            h = h + p["b_up"].astype(dt)
        if cfg.mlp_kind == "relu2":
            h = jnp.square(jax.nn.relu(h))
        else:  # gelu
            h = jax.nn.gelu(h)
    h = shd.act(h, "batch", "seq", "act_mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
    if cfg.mlp_bias:
        y = y + p["b_down"].astype(dt)
    return shd.act(y, "batch", "res_seq", "act_embed")
