"""Mixture-of-Experts FFN (top-k routing, capacity-based token dropping).

Two dispatch implementations:

* ``scatter`` (default): tokens are scattered into per-expert slot buffers
  by (expert_id * C + position_in_expert) and gathered back weighted by the
  router — dispatch is pure data movement, no matmul FLOPs.  This is the
  TPU-native replacement for GShard's dispatch-einsum, which adds
  O(T·E·C·D) matmul FLOPs (~30% overhead at mixtral's shapes; see
  EXPERIMENTS.md §Perf napkin math).
* ``einsum`` (reference): the classical GShard one-hot dispatch/combine
  einsums — kept as an oracle for tests and as a baseline for the §Perf
  comparison.

Expert weights carry logical axes ('expert','embed','mlp'): baseline rules
FSDP the 'embed' dim over data and TP the 'mlp' dim over model; the
'expert' dim shards over model only when divisible (phi3.5's 16 experts
do, mixtral's 8 do not).  A shard_map expert-parallel variant is a §Perf
hillclimb (see repro.train.ep_moe).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Init
from repro.models.sharding import Sharder

GROUP = 1024  # tokens per routing group (keeps dispatch tensors bounded)


def init_moe(ini: Init, cfg):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ini.fan_in((D, E), ("embed", "act_expert")),
        "w_gate": ini.fan_in((E, D, F), ("expert", "embed", "mlp"), fan_axes=(1,)),
        "w_up": ini.fan_in((E, D, F), ("expert", "embed", "mlp"), fan_axes=(1,)),
        "w_down": ini.fan_in((E, F, D), ("expert", "mlp", "embed"), fan_axes=(1,)),
    }


def _route(p, x2d, cfg):
    """x2d: (T, D). Returns (weights (T,k), expert_idx (T,k), aux_loss)."""
    E, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum(
        "td,de->te", x2d, p["router"].astype(x2d.dtype), preferred_element_type=jnp.float32
    )
    top_logits, top_idx = jax.lax.top_k(logits, k)
    weights = jax.nn.softmax(top_logits, axis=-1)  # mixtral-style: softmax over top-k
    # Switch-style load-balancing auxiliary loss: E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_idx[:, 0], E, dtype=jnp.float32), axis=0)
        / x2d.shape[0],
        axis=0,
    )
    one_hot_all = jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), axis=1)  # (T,E)
    fe = jnp.mean(one_hot_all, axis=0) / k
    aux = E * jnp.sum(fe * me)
    del ce
    return weights, top_idx, aux


def _capacity(cfg, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, (c + 7) // 8 * 8)


def _positions_in_expert(top_idx, E: int):
    """top_idx: (g, k) expert ids. Returns pos (g,k) — the slot each
    (token, choice) takes within its expert's buffer, counting duplicates
    in routing order (flatten token-major so k=0 beats k=1)."""
    g, k = top_idx.shape
    flat = top_idx.reshape(g * k)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)  # (g*k, E)
    pos_flat = jnp.cumsum(onehot, axis=0) - 1  # 0-based rank within expert
    pos = jnp.take_along_axis(pos_flat, flat[:, None], axis=1)[:, 0]
    return pos.reshape(g, k)


def _scatter_group(x_g, w_g, idx_g, pos_g, p, cfg, dt):
    """One routing group. x_g: (g, D). Returns (g, D)."""
    E, k = cfg.n_experts, cfg.top_k
    g = x_g.shape[0]
    C = _capacity(cfg, g)
    keep = pos_g < C  # (g, k)
    slot = jnp.where(keep, idx_g * C + pos_g, E * C)  # OOB -> dropped

    # dispatch: scatter tokens into (E*C, D); duplicates impossible by
    # construction (pos is a per-expert rank)
    buf = jnp.zeros((E * C, x_g.shape[1]), dt)
    for j in range(k):  # k is 2 — unrolled
        buf = buf.at[slot[:, j]].set(x_g.astype(dt), mode="drop")
    xs = buf.reshape(E, C, -1)

    # expert FFN (swiglu)
    gate = jnp.einsum("ecd,edf->ecf", xs, p["w_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", xs, p["w_up"].astype(dt))
    h = jax.nn.silu(gate) * up
    ys = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt)).reshape(E * C, -1)

    # combine: gather back, weighted
    out = jnp.zeros_like(x_g, dtype=jnp.float32)
    for j in range(k):
        y_j = jnp.take(ys, jnp.minimum(slot[:, j], E * C - 1), axis=0)
        y_j = jnp.where(keep[:, j, None], y_j, 0.0)
        out = out + w_g[:, j, None].astype(jnp.float32) * y_j.astype(jnp.float32)
    return out.astype(dt)


def _einsum_group(x_g, w_g, idx_g, pos_g, p, cfg, dt):
    """GShard dispatch/combine einsum reference (same dropping semantics)."""
    E, k = cfg.n_experts, cfg.top_k
    g = x_g.shape[0]
    C = _capacity(cfg, g)
    keep = (pos_g < C).astype(jnp.float32)
    oh_e = jax.nn.one_hot(idx_g, E, dtype=jnp.float32)  # (g,k,E)
    oh_c = jax.nn.one_hot(jnp.minimum(pos_g, C - 1), C, dtype=jnp.float32)  # (g,k,C)
    disp = jnp.einsum("gke,gkc,gk->gec", oh_e, oh_c, keep)  # (g,E,C)
    comb = jnp.einsum("gec,gk,gke,gkc->gec", disp, w_g.astype(jnp.float32), oh_e, oh_c)
    xs = jnp.einsum("gec,gd->ecd", disp.astype(dt), x_g.astype(dt))
    gate = jnp.einsum("ecd,edf->ecf", xs, p["w_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", xs, p["w_up"].astype(dt))
    h = jax.nn.silu(gate) * up
    ys = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))
    return jnp.einsum("gec,ecd->gd", comb.astype(dt), ys)


def moe_forward(p, x, cfg, shd: Sharder, impl: str = None):
    """x: (B,S,D) -> (B,S,D). Adds aux loss via side channel return.

    Default dispatch is 'einsum' (GShard one-hot): the scatter variant is
    FLOP-free but GSPMD replicates the group axis around vmapped scatters
    (measured 10 GiB/device f32 expert buffers on mixtral train — see
    EXPERIMENTS.md §Perf iteration log), so einsum is the partitionable
    baseline; scatter remains a TPU-kernel candidate (sort-based dispatch
    belongs in a Pallas kernel, not in SPMD-visible HLO).
    """
    impl = impl or getattr(cfg, "moe_dispatch", "einsum")
    dt = jnp.dtype(cfg.dtype)
    x = shd.act(x, "ffn_batch", None, "ffn_embed")  # see mlp_forward note
    B, S, D = x.shape
    T = B * S
    # un-shard S (the residual stream is sequence-sharded over 'model')
    # BEFORE merging (B,S): reshaping across two differently-sharded dims
    # forces GSPMD into involuntary full replication of (B,S,D).
    x = shd.act(x, "batch", None, None)
    x2d = x.reshape(T, D)

    weights, top_idx, aux = _route(p, x2d, cfg)

    g = min(GROUP, T)
    n_groups = T // g
    xg = x2d.reshape(n_groups, g, D)
    wg = weights.reshape(n_groups, g, cfg.top_k)
    ig = top_idx.reshape(n_groups, g, cfg.top_k)
    pos = jax.vmap(lambda i: _positions_in_expert(i, cfg.n_experts))(ig)

    xg = shd.act(xg, "batch", None, "act_embed")
    fn = _scatter_group if impl == "scatter" else _einsum_group
    out = jax.vmap(lambda a, b, c, d: fn(a, b, c, d, p, cfg, dt))(xg, wg, ig, pos)
    out = shd.act(out, "batch", None, "act_embed")
    return out.reshape(B, S, D), aux
