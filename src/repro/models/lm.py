"""Top-level language models: decoder-only, encoder-decoder, VLM-stub.

One functional API for every assigned architecture:

    params_pl  = init(rng, cfg, max_seq, abstract=...)   # ParamLeaf tree
    logits,aux = forward(params, batch, cfg, shd)        # train path
    logits,cache = prefill(params, batch, cfg, shd, model_axis)
    logits,cache = decode_step(params, cache, tokens, pos, cfg, shd)

Layer stacks are grouped into repeating *units* (cfg.block_pattern) and
evaluated with lax.scan over stacked unit params — compile size stays
O(unit), not O(depth) (56-layer mixtral compiles the same program as a
3-layer toy).  Remainder layers that don't fill a unit (e.g. griffin's
38 = 12*3 + 2) run unscanned before the scan.  Remat wraps the unit body
(cfg.remat: none|full|dots).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import recurrent as rec_mod
from repro.models import xlstm_blocks as xl_mod
from repro.models.common import (
    Init,
    apply_norm,
    init_norm,
    padded_vocab,
    sinusoidal_positions,
)
from repro.models.sharding import ParamLeaf, Sharder, is_param_leaf


# ---------------------------------------------------------------------------
# Block init / forward dispatch
# ---------------------------------------------------------------------------


def _init_block(ini: Init, cfg, kind: str, decoder_cross: bool = False):
    p: Dict[str, Any] = {"norm1": init_norm(ini, cfg)}
    if kind == "attn":
        p["attn"] = attn_mod.init_attention(ini, cfg)
        if decoder_cross:
            p["norm_x"] = init_norm(ini, cfg)
            p["xattn"] = attn_mod.init_attention(ini, cfg, cross=True)
        if cfg.d_ff > 0:
            p["norm2"] = init_norm(ini, cfg)
            p["ffn"] = moe_mod.init_moe(ini, cfg) if cfg.is_moe else mlp_mod.init_mlp(ini, cfg)
    elif kind == "rec":
        p["rec"] = rec_mod.init_rec_block(ini, cfg)
        if cfg.d_ff > 0:
            p["norm2"] = init_norm(ini, cfg)
            p["ffn"] = mlp_mod.init_mlp(ini, cfg)
    elif kind == "mlstm":
        p["mix"] = xl_mod.init_mlstm_block(ini, cfg)
    elif kind == "slstm":
        p["mix"] = xl_mod.init_slstm_block(ini, cfg)
    else:
        raise ValueError(kind)
    return p


@dataclasses.dataclass
class Ctx:
    cfg: Any
    shd: Sharder
    mode: str  # 'train' | 'prefill' | 'decode'
    positions: Any = None  # (S,) int32 for full-seq modes
    pos: Any = None  # (B,) int32 for decode
    enc_out: Any = None
    causal: bool = True
    model_axis: int = 1
    seq_len: int = 0  # cache length basis (decode/prefill)
    skip_masked_blocks: bool = False
    cross: bool = False  # decoder-with-cross-attention blocks


def _block_full(kind: str, p, x, ctx: Ctx):
    """Full-sequence block (train). Returns (x, aux_loss)."""
    cfg, shd = ctx.cfg, ctx.shd
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x, cfg)
    if kind == "attn":
        y = attn_mod.attention_forward(
            p["attn"], h, cfg, shd, ctx.positions, causal=ctx.causal,
            skip_masked_blocks=ctx.skip_masked_blocks,
        )
        x = x + y
        if ctx.cross:
            hx = apply_norm(p["norm_x"], x, cfg)
            y = attn_mod.attention_forward(
                p["xattn"], hx, cfg, shd, ctx.positions, kv_x=ctx.enc_out,
                kv_positions=jnp.arange(ctx.enc_out.shape[1], dtype=jnp.int32),
            )
            x = x + y
        if cfg.d_ff > 0:
            h2 = apply_norm(p["norm2"], x, cfg)
            if cfg.is_moe:
                y, a = moe_mod.moe_forward(p["ffn"], h2, cfg, shd)
                aux = aux + a
            else:
                y = mlp_mod.mlp_forward(p["ffn"], h2, cfg, shd)
            x = x + y
    elif kind == "rec":
        x = x + rec_mod.rec_forward(p["rec"], h, cfg, shd)
        if cfg.d_ff > 0:
            h2 = apply_norm(p["norm2"], x, cfg)
            x = x + mlp_mod.mlp_forward(p["ffn"], h2, cfg, shd)
    elif kind == "mlstm":
        x = x + xl_mod.mlstm_forward(p["mix"], h, cfg, shd)
    elif kind == "slstm":
        x = x + xl_mod.slstm_forward(p["mix"], h, cfg, shd)
    return x, aux


def _block_decode(kind: str, p, x, cache, ctx: Ctx):
    """Single-token block. Returns (x, new_cache)."""
    cfg, shd = ctx.cfg, ctx.shd
    h = apply_norm(p["norm1"], x, cfg)
    if kind == "attn":
        y, cache_a = attn_mod.attention_decode(p["attn"], h, cache["attn"], ctx.pos, cfg, shd)
        x = x + y
        new = dict(cache, attn=cache_a)
        if ctx.cross:
            hx = apply_norm(p["norm_x"], x, cfg)
            y, _ = attn_mod.attention_decode(
                p["xattn"], hx, cache["attn"], ctx.pos, cfg, shd, cross=True
            )
            x = x + y
        if cfg.d_ff > 0:
            h2 = apply_norm(p["norm2"], x, cfg)
            if cfg.is_moe:
                y, _ = moe_mod.moe_forward(p["ffn"], h2, cfg, shd)
            else:
                y = mlp_mod.mlp_forward(p["ffn"], h2, cfg, shd)
            x = x + y
        return x, new
    if kind == "rec":
        y, cache_r = rec_mod.rec_decode(p["rec"], h, cache["rec"], cfg, shd)
        x = x + y
        if cfg.d_ff > 0:
            h2 = apply_norm(p["norm2"], x, cfg)
            x = x + mlp_mod.mlp_forward(p["ffn"], h2, cfg, shd)
        return x, dict(cache, rec=cache_r)
    if kind == "mlstm":
        y, cache_m = xl_mod.mlstm_decode(p["mix"], h, cache["mix"], cfg, shd)
        return x + y, dict(cache, mix=cache_m)
    if kind == "slstm":
        y, cache_s = xl_mod.slstm_decode(p["mix"], h, cache["mix"], cfg, shd)
        return x + y, dict(cache, mix=cache_s)
    raise ValueError(kind)


def _block_prefill_cache(kind: str, p, x, ctx: Ctx):
    """Cache contents produced by a full-sequence pass over pre-norm input x
    (the same normed activations the block consumed)."""
    cfg, shd = ctx.cfg, ctx.shd
    h = apply_norm(p["norm1"], x, cfg)
    if kind == "attn":
        c = {
            "attn": attn_mod.prefill_cache_entries(
                p["attn"], h, cfg, shd, ctx.positions, ctx.seq_len, ctx.model_axis
            )
        }
        if ctx.cross:
            dtc = jnp.dtype(cfg.dtype)
            _, ck, cv = attn_mod._project_qkv(
                p["xattn"],
                ctx.enc_out,
                ctx.enc_out,
                cfg,
                shd,
                jnp.arange(ctx.enc_out.shape[1], dtype=jnp.int32),
                jnp.arange(ctx.enc_out.shape[1], dtype=jnp.int32),
                False,
            )
            from repro.models.sharding import n_kv_virtual

            kvv = n_kv_virtual(cfg.n_heads_p, cfg.n_kv_p, ctx.model_axis)
            rep = kvv // cfg.n_kv_p
            if rep > 1:
                ck = jnp.repeat(ck, rep, axis=2)
                cv = jnp.repeat(cv, rep, axis=2)
            c["attn"]["ck"] = ck.astype(dtc)
            c["attn"]["cv"] = cv.astype(dtc)
        return c
    if kind == "rec":
        return {"rec": rec_mod.rec_prefill_cache(p["rec"], h, cfg, shd)}
    if kind == "mlstm":
        dt = jnp.dtype(cfg.dtype)
        up = jnp.einsum("bsd,dcf->bscf", h, p["mix"]["up"].astype(dt))
        x_in = up[:, :, 1]
        q, k, v, i_pre, f_pre, _ = xl_mod._mlstm_qkvif(p["mix"], x_in, cfg)
        _, (C, n, m) = xl_mod.mlstm_chunkwise(q, k, v, i_pre, f_pre, cfg.mlstm_chunk)
        conv = x_in[:, -(cfg.conv_width - 1) :]
        return {"mix": {"C": C, "n": n, "m": m, "conv": conv}}
    if kind == "slstm":
        dtf = jnp.float32
        B = x.shape[0]
        H, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
        z = jnp.zeros((B, H, dh), dtf)
        _, (cst, nst, hst, mst) = xl_mod.slstm_sequence(p["mix"], h, cfg, (z, z, z, z))
        return {"mix": {"c": cst, "n": nst, "h": hst, "m": mst}}
    raise ValueError(kind)


def init_block_cache(ini: Init, cfg, kind: str, batch: int, seq_len: int, model_axis: int, cross_len: int = 0):
    if kind == "attn":
        return {"attn": attn_mod.init_attn_cache(ini, cfg, batch, seq_len, model_axis, cross_len)}
    if kind == "rec":
        return {"rec": rec_mod.init_rec_cache(ini, cfg, batch)}
    if kind == "mlstm":
        return {"mix": xl_mod.init_mlstm_cache(ini, cfg, batch)}
    if kind == "slstm":
        return {"mix": xl_mod.slstm_init_state(ini, cfg, batch)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stacked-unit init
# ---------------------------------------------------------------------------


def _stack_init(ini: Init, n: int, fn):
    """Stack n inits along a leading 'layers' axis."""
    if n == 0:
        return None
    if ini.abstract:
        unit = fn()
        return jax.tree.map(
            lambda pl: ParamLeaf(
                jax.ShapeDtypeStruct((n,) + tuple(pl.value.shape), pl.value.dtype),
                ("layers",) + pl.axes,
            ),
            unit,
            is_leaf=is_param_leaf,
        )
    units = [fn() for _ in range(n)]
    return jax.tree.map(
        lambda *ls: ParamLeaf(
            jnp.stack([l.value for l in ls]), ("layers",) + ls[0].axes
        ),
        *units,
        is_leaf=is_param_leaf,
    )


def _unit_init(ini: Init, cfg, cross: bool = False):
    return {
        f"b{i}": _init_block(ini, cfg, kind, decoder_cross=cross)
        for i, kind in enumerate(cfg.resolved_pattern)
    }


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------


def init(rng, cfg, max_seq: int, abstract: bool = False):
    """Returns a ParamLeaf tree for the whole model."""
    ini = Init(rng=rng, param_dtype=jnp.dtype(cfg.param_dtype), abstract=abstract)
    Vp = padded_vocab(cfg.vocab_size)
    D = cfg.d_model
    p: Dict[str, Any] = {
        "embed": ini.normal((Vp, D), ("vocab", "embed"), scale=1.0),
        "final_norm": init_norm(ini, cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = ini.fan_in((D, Vp), ("embed", "vocab"))
    if cfg.pos_kind == "learned":
        p["pos"] = ini.normal((max_seq, D), ("pos", "embed"), scale=0.01)

    cross = cfg.is_encdec
    p["units"] = _stack_init(ini, cfg.n_units, lambda: _unit_init(ini, cfg, cross))
    if cfg.n_rem_layers:
        p["rem"] = {
            f"b{i}": _init_block(ini, cfg, cfg.resolved_pattern[i % cfg.unit_len], decoder_cross=cross)
            for i in range(cfg.n_rem_layers)
        }
    if cfg.is_encdec:
        enc_cfg = cfg.replace(block_pattern=(), is_encdec=False, n_layers=cfg.n_enc_layers)
        p["enc_units"] = _stack_init(
            ini, cfg.n_enc_layers, lambda: {"b0": _init_block(ini, enc_cfg, "attn")}
        )
        p["enc_norm"] = init_norm(ini, cfg)
    return p


# ---------------------------------------------------------------------------
# Shared full-sequence trunk
# ---------------------------------------------------------------------------


def _remat_wrap(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _run_units(params, x, ctx: Ctx, collect_cache: bool = False):
    """Remainder blocks then scanned units. Returns (x, aux, caches|None)."""
    cfg = ctx.cfg
    pattern = ctx.cfg.resolved_pattern
    aux = jnp.zeros((), jnp.float32)
    rem_caches = {}
    if cfg.n_rem_layers:
        for i in range(cfg.n_rem_layers):
            kind = pattern[i % cfg.unit_len]
            bp = params["rem"][f"b{i}"]
            if collect_cache:
                rem_caches[f"b{i}"] = _block_prefill_cache(kind, bp, x, ctx)
            x, a = _block_full(kind, bp, x, ctx)
            aux = aux + a

    if params.get("units") is None:
        return x, aux, (rem_caches if collect_cache else None)

    def unit_fn(x, unit_params):
        a_tot = jnp.zeros((), jnp.float32)
        caches = {}
        for i, kind in enumerate(pattern):
            if collect_cache:
                caches[f"b{i}"] = _block_prefill_cache(kind, unit_params[f"b{i}"], x, ctx)
            x, a = _block_full(kind, unit_params[f"b{i}"], x, ctx)
            a_tot = a_tot + a
        return x, a_tot, caches

    unit_fn_w = _remat_wrap(unit_fn, cfg) if cfg.remat != "none" else unit_fn

    if cfg.scan_layers:
        def body(carry, unit_params):
            x, aux = carry
            x, a, caches = unit_fn_w(x, unit_params)
            return (x, aux + a), (caches if collect_cache else None)

        (x, aux), unit_caches = jax.lax.scan(body, (x, aux), params["units"])
    else:
        # unrolled (dry-run cost probe / tiny models): python loop over
        # unit indices into the stacked params
        caches_list = []
        for i in range(cfg.n_units):
            up = jax.tree.map(lambda a: a[i], params["units"])
            x, a, caches_i = unit_fn_w(x, up)
            aux = aux + a
            caches_list.append(caches_i)
        unit_caches = (
            jax.tree.map(lambda *ls: jnp.stack(ls), *caches_list)
            if collect_cache and caches_list
            else None
        )
    caches = None
    if collect_cache:
        caches = {"rem": rem_caches, "units": unit_caches}
    return x, aux, caches


def _embed_tokens(params, tokens, cfg, shd: Sharder):
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    return shd.act(x, "batch", "res_seq", "act_embed")


def _lm_logits(params, x, cfg, shd: Sharder):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum(
        "bsd,dv->bsv", x, w.astype(x.dtype), preferred_element_type=jnp.float32
    )
    # logits stay sequence-sharded: (B, S/model, Vp) — the f32 logits
    # buffer is the single largest train-time activation otherwise
    return shd.act(logits, "batch", "res_seq", None)


def _encode(params, frames, cfg, shd: Sharder):
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    dt = jnp.dtype(cfg.dtype)
    S = frames.shape[1]
    pos_tab = jnp.asarray(sinusoidal_positions(S, cfg.d_model), dt)
    x = frames.astype(dt) + pos_tab[None]
    x = shd.act(x, "batch", "seq", "act_embed")
    enc_cfg = cfg.replace(block_pattern=(), is_encdec=False)
    ctx = Ctx(cfg=enc_cfg, shd=shd, mode="train",
              positions=jnp.arange(S, dtype=jnp.int32), causal=False)

    def unit_fn(x, up):
        x, a = _block_full("attn", up["b0"], x, ctx)
        return x, a

    ufn = _remat_wrap(unit_fn, cfg) if cfg.remat != "none" else unit_fn

    if cfg.scan_layers:
        def body(carry, up):
            x, aux = carry
            x, a = ufn(x, up)
            return (x, aux + a), None

        (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["enc_units"])
    else:
        for i in range(cfg.n_enc_layers):
            up = jax.tree.map(lambda a: a[i], params["enc_units"])
            x, _ = ufn(x, up)
    return apply_norm(params["enc_norm"], x, cfg)


def _assemble_inputs(params, batch, cfg, shd: Sharder):
    """Token embeddings (+ learned positions, + VLM image prefix).
    Returns (x, positions, enc_out)."""
    tokens = batch["tokens"]
    x = _embed_tokens(params, tokens, cfg, shd)
    enc_out = None
    if cfg.n_img_tokens:
        img = batch["img_embeds"].astype(x.dtype)  # (B, n_img, D) — stub frontend
        x = jnp.concatenate([img, x], axis=1)
        x = shd.act(x, "batch", "res_seq", "act_embed")
    if cfg.is_encdec:
        enc_out = _encode(params, batch["frames"], cfg, shd)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    if cfg.pos_kind == "learned":
        x = x + params["pos"][:S].astype(x.dtype)[None]
    return x, positions, enc_out


def forward(params, batch, cfg, shd: Sharder, skip_masked_blocks: bool = False):
    """Train-mode forward. batch: {'tokens', ['img_embeds'], ['frames']}.
    Returns (logits (B, S_total, Vp) f32, aux_loss)."""
    x, positions, enc_out = _assemble_inputs(params, batch, cfg, shd)
    ctx = Ctx(cfg=cfg, shd=shd, mode="train", positions=positions,
              enc_out=enc_out, cross=cfg.is_encdec,
              skip_masked_blocks=skip_masked_blocks)
    x, aux, _ = _run_units(params, x, ctx)
    x = apply_norm(params["final_norm"], x, cfg)
    return _lm_logits(params, x, cfg, shd), aux


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def prefill(params, batch, cfg, shd: Sharder, model_axis: int = 1, cache_len: int = 0):
    """Full-context pass that returns (last-token logits, cache).

    cache_len: total KV-cache allocation (>= prompt length + decode
    budget); defaults to the prompt length (no decode headroom).
    """
    x, positions, enc_out = _assemble_inputs(params, batch, cfg, shd)
    ctx = Ctx(cfg=cfg, shd=shd, mode="prefill", positions=positions,
              enc_out=enc_out, cross=cfg.is_encdec,
              model_axis=model_axis, seq_len=max(cache_len, x.shape[1]))
    x, _, caches = _run_units(params, x, ctx, collect_cache=True)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = _lm_logits(params, x[:, -1:], cfg, shd)
    return logits, caches


def init_cache(ini: Init, cfg, batch: int, seq_len: int, model_axis: int):
    """Abstract/concrete cache tree matching _run_units(collect_cache)."""
    pattern = cfg.resolved_pattern
    cross_len = cfg.enc_seq if cfg.is_encdec else 0
    rem = {
        f"b{i}": init_block_cache(
            ini, cfg, pattern[i % cfg.unit_len], batch, seq_len, model_axis, cross_len
        )
        for i in range(cfg.n_rem_layers)
    }
    unit = {
        f"b{i}": init_block_cache(ini, cfg, kind, batch, seq_len, model_axis, cross_len)
        for i, kind in enumerate(pattern)
    }
    units = (
        jax.tree.map(
            lambda pl: ParamLeaf(
                jax.ShapeDtypeStruct((cfg.n_units,) + tuple(pl.value.shape), pl.value.dtype)
                if ini.abstract
                else jnp.broadcast_to(pl.value[None], (cfg.n_units,) + tuple(pl.value.shape)).copy(),
                ("layers",) + pl.axes,
            ),
            unit,
            is_leaf=is_param_leaf,
        )
        if cfg.n_units
        else None
    )
    return {"rem": rem, "units": units}


def decode_step(params, cache, tokens, pos, cfg, shd: Sharder):
    """One token for every sequence in the batch.

    tokens: (B, 1) int32; pos: (B,) int32 absolute position of `tokens`.
    Returns (logits (B,1,Vp), new_cache).
    """
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = shd.act(x, "batch", None, "act_embed")
    if cfg.pos_kind == "learned":
        x = x + jnp.take(params["pos"], pos, axis=0).astype(dt)[:, None]
    pattern = cfg.resolved_pattern
    ctx = Ctx(cfg=cfg, shd=shd, mode="decode", pos=pos, cross=cfg.is_encdec)

    new_rem = {}
    for i in range(cfg.n_rem_layers):
        kind = pattern[i % cfg.unit_len]
        x, c = _block_decode(kind, params["rem"][f"b{i}"], x, cache["rem"][f"b{i}"], ctx)
        new_rem[f"b{i}"] = c

    new_units = None
    if cache.get("units") is not None:

        def body(x, xs):
            unit_params, unit_cache = xs
            new_cache = {}
            for i, kind in enumerate(pattern):
                x, c = _block_decode(kind, unit_params[f"b{i}"], x, unit_cache[f"b{i}"], ctx)
                new_cache[f"b{i}"] = c
            return x, new_cache

        if cfg.scan_layers:
            x, new_units = jax.lax.scan(body, x, (params["units"], cache["units"]))
        else:
            outs = []
            for i in range(cfg.n_units):
                xs_i = jax.tree.map(lambda a: a[i], (params["units"], cache["units"]))
                x, c_i = body(x, xs_i)
                outs.append(c_i)
            new_units = jax.tree.map(lambda *ls: jnp.stack(ls), *outs)

    x = apply_norm(params["final_norm"], x, cfg)
    logits = _lm_logits(params, x, cfg, shd)
    return logits, {"rem": new_rem, "units": new_units}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(logits, labels, weights=None, z_loss: float = 1e-4):
    """Masked softmax cross-entropy over (possibly padded) vocab.

    logits: (B,S,Vp) f32; labels: (B,S) int32; weights: (B,S) or None.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # gather (not one-hot einsum): avoids materializing a (B,S,V) one-hot
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = lse - gold
    if z_loss:
        ce = ce + z_loss * jnp.square(lse)
    if weights is None:
        weights = jnp.ones_like(ce)
    w = weights.astype(jnp.float32)
    return jnp.sum(ce * w) / jnp.maximum(jnp.sum(w), 1.0)
