"""Common model building blocks: inits, norms, rotary embeddings, masks.

All parameters are created through ``Init`` which bundles values with
logical axes (see repro.models.sharding).  ``Init.abstract=True`` produces
``jax.ShapeDtypeStruct`` leaves instead of real arrays — used by the
dry-run so no multi-hundred-GB model is ever materialized.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import ParamLeaf


@dataclasses.dataclass
class Init:
    """Parameter factory: tracks an rng fold-count, abstract mode, dtype."""

    rng: jax.Array
    param_dtype: jnp.dtype = jnp.float32
    abstract: bool = False
    _count: int = 0

    def _next_rng(self):
        self._count += 1
        return jax.random.fold_in(self.rng, self._count)

    def normal(self, shape, axes, scale=0.02, dtype=None) -> ParamLeaf:
        dtype = dtype or self.param_dtype
        if self.abstract:
            return ParamLeaf(jax.ShapeDtypeStruct(tuple(shape), dtype), tuple(axes))
        v = scale * jax.random.normal(self._next_rng(), tuple(shape), dtype=jnp.float32)
        return ParamLeaf(v.astype(dtype), tuple(axes))

    def fan_in(self, shape, axes, fan_axes=None, dtype=None) -> ParamLeaf:
        """Normal with 1/sqrt(fan_in) scale (fan = product of fan_axes dims,
        default: all but last dim)."""
        if fan_axes is None:
            fan = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
        else:
            fan = int(np.prod([shape[i] for i in fan_axes]))
        return self.normal(shape, axes, scale=1.0 / np.sqrt(max(fan, 1)), dtype=dtype)

    def zeros(self, shape, axes, dtype=None) -> ParamLeaf:
        dtype = dtype or self.param_dtype
        if self.abstract:
            return ParamLeaf(jax.ShapeDtypeStruct(tuple(shape), dtype), tuple(axes))
        return ParamLeaf(jnp.zeros(tuple(shape), dtype), tuple(axes))

    def ones(self, shape, axes, dtype=None) -> ParamLeaf:
        dtype = dtype or self.param_dtype
        if self.abstract:
            return ParamLeaf(jax.ShapeDtypeStruct(tuple(shape), dtype), tuple(axes))
        return ParamLeaf(jnp.ones(tuple(shape), dtype), tuple(axes))

    def const(self, shape, axes, fill, dtype=None) -> ParamLeaf:
        dtype = dtype or self.param_dtype
        if self.abstract:
            return ParamLeaf(jax.ShapeDtypeStruct(tuple(shape), dtype), tuple(axes))
        return ParamLeaf(jnp.full(tuple(shape), fill, dtype), tuple(axes))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def init_norm(ini: Init, cfg, width=None):
    width = width or cfg.d_model
    if cfg.norm_kind == "rmsnorm":
        return {"scale": ini.zeros((width,), ("act_embed",))}
    return {"scale": ini.ones((width,), ("act_embed",)), "bias": ini.zeros((width,), ("act_embed",))}


def apply_norm(p, x, cfg):
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def group_norm_heads(x, scale, eps=1e-5):
    """Per-head group norm over the feature dim. x: (..., H, dh)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_tables(positions, head_dim: int, theta: float):
    """positions: int (...,S). Returns (sin, cos) each (...,S, head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (...,S,half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: (B,S,H,D). sin/cos: (B,S,half) or (S,half). Split-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:  # (S, half) -> broadcast over batch & heads
        s = sin[None, :, None, :]
        c = cos[None, :, None, :]
    else:  # (B,S,half)
        s = sin[:, :, None, :]
        c = cos[:, :, None, :]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(dt)


def sinusoidal_positions(n_pos: int, dim: int) -> np.ndarray:
    """Whisper-style sinusoidal table (n_pos, dim), computed host-side."""
    half = dim // 2
    log_timescale = np.log(10_000.0) / max(half - 1, 1)
    inv = np.exp(-log_timescale * np.arange(half))
    ang = np.arange(n_pos)[:, None] * inv[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

NEG_INF = -2.0e38  # float32-safe additive mask


def causal_mask_bias(q_pos, k_pos, window: int = 0) -> jnp.ndarray:
    """Additive bias (…, Sq, Sk): 0 where k may be attended from q.

    window > 0 => sliding-window causal: q attends k iff
    q_pos - window < k_pos <= q_pos.
    """
    ok = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def padded_vocab(vocab_size: int, multiple: int = 128) -> int:
    return ((vocab_size + multiple - 1) // multiple) * multiple


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap > 0 else x
