"""Attention: GQA/MQA/MHA with full / sliding-window / local masking.

Full-sequence paths (train & prefill) use a two-level blockwise
online-softmax scan (flash-attention access pattern in pure jnp) so the
(Sq x Sk) score matrix is never materialized — mandatory for the 32k
shapes.  On TPU the Pallas kernel in repro.kernels.flash_attention
replaces the inner loop; the jnp path below is also its oracle's
structure (see kernels/flash_attention/ref.py for the naive oracle).

The baseline scan visits ALL (q-block, kv-block) pairs and masks — the
causal/window block-skipping variant (visiting only the valid band) is a
§Perf hillclimb lever, toggled by ``skip_masked_blocks``.

KV heads are repeated ("virtual KV heads", repro.models.sharding
.n_kv_virtual) to the smallest count that shards over the model axis and
divides n_heads; when impossible (qwen2: 28H/4kv), heads stay unsharded
and the head_dim picks up the model axis via the rule fallback.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Init, apply_rope, rope_tables, softcap
from repro.models.sharding import Sharder, n_kv_virtual

NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(ini: Init, cfg, cross: bool = False):
    D, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads_p, cfg.n_kv_p  # padded (== raw when padding off)
    p = {
        "wq": ini.fan_in((D, H, hd), ("embed", "heads", "head_dim"), fan_axes=(0,)),
        "wk": ini.fan_in((D, KV, hd), ("embed", "kv_heads", "head_dim"), fan_axes=(0,)),
        "wv": ini.fan_in((D, KV, hd), ("embed", "kv_heads", "head_dim"), fan_axes=(0,)),
        "wo": ini.fan_in((H, hd, D), ("heads", "head_dim", "embed"), fan_axes=(0, 1)),
    }
    if H != cfg.n_heads and not ini.abstract:
        # zero the padded heads' output rows: function-preserving padding
        import jax.numpy as _jnp

        mask = _jnp.arange(H)[:, None, None] < cfg.n_heads
        from repro.models.sharding import ParamLeaf as _PL

        p["wo"] = _PL(p["wo"].value * mask.astype(p["wo"].value.dtype), p["wo"].axes)
    if cfg.qkv_bias:
        p["bq"] = ini.zeros((H, hd), ("heads", "head_dim"))
        p["bk"] = ini.zeros((KV, hd), ("kv_heads", "head_dim"))
        p["bv"] = ini.zeros((KV, hd), ("kv_heads", "head_dim"))
        p["bo"] = ini.zeros((D,), ("act_embed",))
    if cfg.qk_norm and not cross:
        p["q_norm"] = ini.zeros((hd,), ("head_dim",))
        p["k_norm"] = ini.zeros((hd,), ("head_dim",))
    return p


def _project_qkv(p, x, kv_x, cfg, shd: Sharder, positions, kv_positions, use_rope):
    """Returns q (B,Sq,H,hd), k/v (B,Sk,KV,hd) — rope/norm applied."""
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm and "q_norm" in p:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    # Constrain BEFORE rope: the S-gather (residual stream is seq-sharded)
    # then moves bf16 projections, not the f32 tensors inside the rope/norm
    # islands — XLA otherwise hoists the f32 convert above the all-gather
    # and doubles the wire bytes.  Rope itself is per-position => local.
    # k/v are NOT constrained on the (pre-expansion) kv-head or head_dim
    # axes — for kv counts that don't divide the model axis a constraint
    # here forces an involuntary reshard; the post-expansion constraint in
    # _expand_kv is the authoritative one.
    q = shd.act(q, "batch", "seq", "act_heads", "head_dim")
    k = shd.act(k, "batch", "kv_seq", None, None)
    v = shd.act(v, "batch", "kv_seq", None, None)
    if use_rope:
        sin_q, cos_q = rope_tables(positions, hd, cfg.rope_theta)
        q = apply_rope(q, sin_q, cos_q)
        sin_k, cos_k = rope_tables(kv_positions, hd, cfg.rope_theta)
        k = apply_rope(k, sin_k, cos_k)
    return q, k, v


def _expand_kv(k, v, n_heads: int, shd: Sharder):
    """Repeat KV heads to n_heads (virtual heads). HF-consecutive grouping:
    q head h belongs to kv head h // (H // KV)."""
    kvh = k.shape[2]
    rep = n_heads // kvh
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    k = shd.act(k, "batch", "kv_seq", "act_heads", "head_dim")
    v = shd.act(v, "batch", "kv_seq", "act_heads", "head_dim")
    return k, v


# ---------------------------------------------------------------------------
# Blockwise full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def blockwise_attention(
    q,
    k,
    v,
    q_pos,
    k_pos,
    *,
    causal: bool = True,
    window: int = 0,
    logit_cap: float = 0.0,
    blk_q: int = 512,
    blk_k: int = 1024,
    skip_masked_blocks: bool = False,
):
    """q: (B,Sq,H,hd) k,v: (B,Sk,H,hd) (kv already expanded to H heads).
    q_pos: (Sq,) int32 absolute positions; k_pos: (Sk,) with -1 = invalid.
    Returns (B,Sq,H,hd) in q.dtype.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    out_dt = q.dtype
    scale = hd**-0.5

    blk_q = min(blk_q, max(Sq, 1))
    blk_k = min(blk_k, max(Sk, 1))
    qp = _pad_to(q, 1, blk_q)
    kp = _pad_to(k, 1, blk_k)
    vp = _pad_to(v, 1, blk_k)
    q_pos_p = _pad_to(q_pos.astype(jnp.int32), 0, blk_q)
    k_pos_p = jnp.pad(
        k_pos.astype(jnp.int32), (0, (-Sk) % blk_k), constant_values=-1
    )
    nq, nk = qp.shape[1] // blk_q, kp.shape[1] // blk_k

    # (n, B, H, blk, hd) layout so scan slices the leading axis
    qb = qp.reshape(B, nq, blk_q, H, hd).transpose(1, 0, 3, 2, 4)
    kb = kp.reshape(B, nk, blk_k, H, hd).transpose(1, 0, 3, 2, 4)
    vb = vp.reshape(B, nk, blk_k, H, hd).transpose(1, 0, 3, 2, 4)
    qpb = q_pos_p.reshape(nq, blk_q)
    kpb = k_pos_p.reshape(nk, blk_k)

    def one_pair(acc, q_i, qpos_i, k_j, v_j, kpos_j):
        m, l, o = acc
        s = jnp.einsum(
            "bhqd,bhkd->bhqk", q_i, k_j, preferred_element_type=jnp.float32
        ) * scale
        if logit_cap > 0:
            s = softcap(s, logit_cap)
        ok = kpos_j[None, :] >= 0
        if causal:
            ok &= kpos_j[None, :] <= qpos_i[:, None]
        if window > 0:
            ok &= kpos_j[None, :] > (qpos_i[:, None] - window)
        s = jnp.where(ok[None, None], s, NEG)
        m2 = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m2[..., None])
        alpha = jnp.exp(m - m2)
        l2 = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bhqk,bhkd->bhqd",
            p.astype(v_j.dtype),
            v_j,
            preferred_element_type=jnp.float32,
        )
        o2 = o * alpha[..., None] + pv
        return (m2, l2, o2)

    def q_body(q_i, qpos_i):
        init = (
            jnp.full((B, H, blk_q), NEG, jnp.float32),
            jnp.zeros((B, H, blk_q), jnp.float32),
            jnp.zeros((B, H, blk_q, hd), jnp.float32),
        )
        if skip_masked_blocks and causal:
            # Band-limited inner loop: only kv blocks intersecting
            # [q_lo - window, q_hi] can contribute.  We roll the kv block
            # index so the scan length can stay static while the *work* is
            # bounded by gathering only `n_needed` blocks via dynamic_slice
            # in a fori_loop (true FLOP skipping — hillclimb lever).
            q_hi = qpos_i[-1]
            lo_pos = jnp.maximum(qpos_i[0] - (window if window > 0 else 10**9) + 1, 0)
            j_lo = jnp.maximum(lo_pos // blk_k, 0)
            j_hi = jnp.minimum(q_hi // blk_k, nk - 1)

            def body(j, acc):
                k_j = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
                v_j = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
                kpos_j = jax.lax.dynamic_index_in_dim(kpb, j, 0, keepdims=False)
                return one_pair(acc, q_i, qpos_i, k_j, v_j, kpos_j)

            m, l, o = jax.lax.fori_loop(j_lo, j_hi + 1, body, init)
        else:

            def kv_step(acc, kj):
                k_j, v_j, kpos_j = kj
                return one_pair(acc, q_i, qpos_i, k_j, v_j, kpos_j), None

            (m, l, o), _ = jax.lax.scan(kv_step, init, (kb, vb, kpb))
        out_i = o / jnp.maximum(l, 1e-30)[..., None]
        return out_i.astype(out_dt)

    # Checkpoint per q-block: backward recomputes one q-row of score blocks
    # at a time instead of materializing all (nq x nk) f32 score blocks —
    # this is what makes the jnp path flash-memory-equivalent under
    # autodiff (the Pallas kernel does the same by construction).
    q_body_ckpt = jax.checkpoint(q_body)

    def q_step(_, qi):
        q_i, qpos_i = qi
        return None, q_body_ckpt(q_i, qpos_i)

    _, ob = jax.lax.scan(q_step, None, (qb, qpb))
    out = ob.transpose(1, 0, 3, 2, 4).reshape(B, nq * blk_q, H, hd)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# Full-sequence block forward (train / prefill)
# ---------------------------------------------------------------------------


def direct_attention(q, k, v, q_pos, k_pos, *, causal, window, logit_cap):
    """Plain masked-softmax attention (materializes Sq x Sk scores).
    Numerically equivalent to blockwise_attention — used as its oracle and
    as the dry-run cost-probe implementation (no inner while loops)."""
    s = jnp.einsum(
        "bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * (q.shape[-1] ** -0.5)
    if logit_cap > 0:
        s = softcap(s, logit_cap)
    ok = k_pos[None, :] >= 0
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(ok[None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhqk,bkhd->bqhd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return out.astype(q.dtype)


def attention_forward(
    p,
    x,
    cfg,
    shd: Sharder,
    positions,
    *,
    causal: bool = True,
    kv_x=None,
    kv_positions=None,
    use_rope: Optional[bool] = None,
    skip_masked_blocks: bool = False,
):
    """Full-sequence attention sub-layer (pre-norm residual handled by
    caller).  kv_x != None => cross attention (no rope, no causal)."""
    cross = kv_x is not None
    kv_x = x if kv_x is None else kv_x
    kv_positions = positions if kv_positions is None else kv_positions
    if use_rope is None:
        use_rope = cfg.pos_kind == "rope" and not cross
    q, k, v = _project_qkv(p, x, kv_x, cfg, shd, positions, kv_positions, use_rope)
    k, v = _expand_kv(k, v, cfg.n_heads_p, shd)
    window = cfg.window if cfg.attn_kind in ("swa", "local") else 0
    impl = direct_attention if cfg.attn_impl == "direct" else blockwise_attention
    kwargs = {} if cfg.attn_impl == "direct" else {"skip_masked_blocks": skip_masked_blocks}
    out = impl(
        q,
        k,
        v,
        positions,
        kv_positions,
        causal=causal and not cross,
        window=window,
        logit_cap=cfg.attn_logit_softcap,
        **kwargs,
    )
    out = shd.act(out, "batch", "seq", "act_heads", "head_dim")
    dt = jnp.dtype(cfg.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    if cfg.qkv_bias:
        y = y + p["bo"].astype(dt)
    return shd.act(y, "batch", "res_seq", "act_embed")


# ---------------------------------------------------------------------------
# Decode (single new token against a cache)
# ---------------------------------------------------------------------------


def cache_len(cfg, seq_len: int) -> int:
    """Ring-buffer length: window-bounded archs keep only `window` entries
    (this is what makes long_500k sub-quadratic for swa archs)."""
    if cfg.attn_kind in ("swa", "local") and cfg.window > 0:
        return min(cfg.window, seq_len)
    return seq_len


def init_attn_cache(ini: Init, cfg, batch: int, seq_len: int, model_axis: int, cross_len: int = 0):
    """Cache pytree (as ParamLeaf tree so the dry-run can shard it).

    k/v: (B, Sc, KVv, hd) with KVv virtual (sharded) kv heads;
    k_pos: (B, Sc) absolute positions of the stored entries, -1 = empty.
    """
    hd = cfg.resolved_head_dim
    kvv = n_kv_virtual(cfg.n_heads_p, cfg.n_kv_p, model_axis)
    sc = cache_len(cfg, seq_len)
    dt = jnp.dtype(cfg.dtype)
    c = {
        "k": ini.zeros((batch, sc, kvv, hd), ("batch", "kv_seq", "kv_heads", "head_dim"), dtype=dt),
        "v": ini.zeros((batch, sc, kvv, hd), ("batch", "kv_seq", "kv_heads", "head_dim"), dtype=dt),
        "k_pos": ini.const((batch, sc), ("batch", "kv_seq"), -1, dtype=jnp.int32),
    }
    if cross_len:
        c["ck"] = ini.zeros(
            (batch, cross_len, kvv, hd), ("batch", "kv_seq", "kv_heads", "head_dim"), dtype=dt
        )
        c["cv"] = ini.zeros(
            (batch, cross_len, kvv, hd), ("batch", "kv_seq", "kv_heads", "head_dim"), dtype=dt
        )
    return c


def _decode_mha(q, k, v, k_pos, pos, window, logit_cap):
    """q: (B,1,H,hd); k/v: (B,Sc,KVv,hd); k_pos: (B,Sc). -> (B,1,H,hd)"""
    B, _, H, hd = q.shape
    kvv = k.shape[2]
    rep = H // kvv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * (
        hd**-0.5
    )
    if logit_cap > 0:
        s = softcap(s, logit_cap)
    ok = (k_pos >= 0) & (k_pos <= pos[:, None])
    if window > 0:
        ok &= k_pos > (pos[:, None] - window)
    s = jnp.where(ok[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bhqk,bkhd->bqhd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    ).astype(q.dtype)


def attention_decode(p, x, cache, pos, cfg, shd: Sharder, cross: bool = False):
    """x: (B,1,D) current token activations; pos: (B,) int32 positions.
    Returns (y (B,1,D), new_cache)."""
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    use_rope = cfg.pos_kind == "rope" and not cross

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    if cfg.qk_norm and "q_norm" in p:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
    if use_rope:
        sin, cos = rope_tables(pos[:, None], hd, cfg.rope_theta)  # (B,1,half)
        q = apply_rope(q, sin, cos)

    if cross:
        out = _decode_mha(
            q,
            cache["ck"],
            cache["cv"],
            jnp.zeros(cache["ck"].shape[:2], jnp.int32),
            jnp.full((x.shape[0],), 2**30, jnp.int32),
            0,
            cfg.attn_logit_softcap,
        )
        new_cache = cache
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
        if cfg.qkv_bias:
            k = k + p["bk"].astype(dt)
            v = v + p["bv"].astype(dt)
        if cfg.qk_norm and "k_norm" in p:
            k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
        if use_rope:
            sin, cos = rope_tables(pos[:, None], hd, cfg.rope_theta)
            k = apply_rope(k, sin, cos)
        kvv = cache["k"].shape[2]
        rep = kvv // cfg.n_kv_p
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        sc = cache["k"].shape[1]
        slot = (pos % sc).astype(jnp.int32)  # ring-buffer write
        bidx = jnp.arange(x.shape[0])
        ck = cache["k"].at[bidx, slot].set(k[:, 0])
        cv = cache["v"].at[bidx, slot].set(v[:, 0])
        ckpos = cache["k_pos"].at[bidx, slot].set(pos.astype(jnp.int32))
        new_cache = dict(cache, k=ck, v=cv, k_pos=ckpos)
        window = cfg.window if cfg.attn_kind in ("swa", "local") else 0
        out = _decode_mha(q, ck, cv, ckpos, pos, window, cfg.attn_logit_softcap)

    y = jnp.einsum("bshk,hkd->bsd", out.astype(dt), p["wo"].astype(dt))
    if cfg.qkv_bias:
        y = y + p["bo"].astype(dt)
    return y, new_cache


def prefill_cache_entries(p, x, cfg, shd: Sharder, positions, seq_len: int, model_axis: int):
    """Build the k/v cache contents from a full-sequence pass (prefill).
    Returns cache dict with the last `cache_len` entries (ring layout)."""
    dt = jnp.dtype(cfg.dtype)
    _, k, v = _project_qkv(p, x, x, cfg, shd, positions, positions, cfg.pos_kind == "rope")
    kvv = n_kv_virtual(cfg.n_heads_p, cfg.n_kv_p, model_axis)
    rep = kvv // cfg.n_kv_p
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    sc = cache_len(cfg, seq_len)
    S = x.shape[1]
    if sc < S:
        # keep the trailing window; ring slot of position p is p % sc —
        # roll so entry order matches ring indexing
        k_tail, v_tail = k[:, S - sc :], v[:, S - sc :]
        pos_tail = positions[S - sc :]
        shift = (S - sc) % sc
        k_r = jnp.roll(k_tail, shift, axis=1)
        v_r = jnp.roll(v_tail, shift, axis=1)
        pos_r = jnp.roll(pos_tail, shift)
        kpos = jnp.broadcast_to(pos_r[None], (x.shape[0], sc)).astype(jnp.int32)
        return {"k": k_r.astype(dt), "v": v_r.astype(dt), "k_pos": kpos}
    pad = sc - S
    kk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kpos = jnp.pad(positions.astype(jnp.int32), (0, pad), constant_values=-1)
    kpos = jnp.broadcast_to(kpos[None], (x.shape[0], sc))
    return {"k": kk.astype(dt), "v": vv.astype(dt), "k_pos": kpos}
