"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly sequential).

mLSTM recurrence (per head, stabilized — xLSTM paper eq. 19-27):
    m_t = max(logsig(f_t) + m_{t-1}, i_t)
    C_t = exp(logsig(f_t)+m_{t-1}-m_t) C_{t-1} + exp(i_t - m_t) k_t v_t^T
    n_t = exp(logsig(f_t)+m_{t-1}-m_t) n_{t-1} + exp(i_t - m_t) k_t
    h_t = (q_t C_t) / max(|q_t . n_t|, exp(-m_t))
Full sequences use the *chunkwise* form (quadratic within a chunk,
recurrent across chunks) — the standard linear-attention trick that keeps
the S x (dk x dv) state off HBM; the stepwise recurrence is the decode
path AND the test oracle (tests/test_xlstm.py proves chunkwise == scan).

sLSTM is sequential by construction (memory mixing via block-diagonal
recurrent weights) — evaluated with lax.scan; that is the architecture's
documented property, not an implementation shortcut.

d_ff = 0 in the assignment: both blocks carry their own up/down
projections (mLSTM pre-up x2, sLSTM post gated-FFN x4/3), so no separate
transformer FFN exists.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Init, group_norm_heads
from repro.models.sharding import Sharder


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm_block(ini: Init, cfg):
    D = cfg.d_model
    F = 2 * D  # projection factor 2
    H = cfg.n_heads
    dk = F // H
    return {
        "up": ini.fan_in((D, 2, F), ("embed", None, "mlp"), fan_axes=(0,)),
        "conv_w": ini.normal((cfg.conv_width, F), ("conv", "mlp"), scale=0.1),
        "conv_b": ini.zeros((F,), ("mlp",)),
        "wq": ini.fan_in((F, H, dk), ("mlp", "heads", "head_dim"), fan_axes=(0,)),
        "wk": ini.fan_in((F, H, dk), ("mlp", "heads", "head_dim"), fan_axes=(0,)),
        "wv": ini.fan_in((F, H, dk), ("mlp", "heads", "head_dim"), fan_axes=(0,)),
        "w_i": ini.fan_in((F, H), ("mlp", "heads")),
        "b_i": ini.zeros((H,), ("heads",)),
        "w_f": ini.fan_in((F, H), ("mlp", "heads")),
        "b_f": ini.const((H,), ("heads",), 3.0),  # open forget gates at init
        "gn_scale": ini.ones((H, dk), ("heads", "head_dim")),
        "down": ini.fan_in((F, D), ("mlp", "embed")),
    }


def mlstm_chunkwise(q, k, v, i_pre, f_pre, chunk: int, state=None, unroll: bool = False):
    """q,k,v: (B,S,H,d); i_pre,f_pre: (B,S,H). Returns (h (B,S,H,d), state).

    state = (C (B,H,d,d), n (B,H,d), m (B,H)).  unroll=True replaces the
    cross-chunk lax.scan with a python loop (dry-run cost probe).
    """
    B, S, H, d = q.shape
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L
    scale = d**-0.5

    # (B,H,S,...) layout, f32 gates
    qT = q.transpose(0, 2, 1, 3).astype(jnp.float32) * scale
    kT = k.transpose(0, 2, 1, 3).astype(jnp.float32)
    vT = v.transpose(0, 2, 1, 3).astype(jnp.float32)
    ig = i_pre.transpose(0, 2, 1).astype(jnp.float32)
    lg = jax.nn.log_sigmoid(f_pre.transpose(0, 2, 1).astype(jnp.float32))

    qc = qT.reshape(B, H, nc, L, d).transpose(2, 0, 1, 3, 4)
    kc = kT.reshape(B, H, nc, L, d).transpose(2, 0, 1, 3, 4)
    vc = vT.reshape(B, H, nc, L, d).transpose(2, 0, 1, 3, 4)
    ic = ig.reshape(B, H, nc, L).transpose(2, 0, 1, 3)
    gc = lg.reshape(B, H, nc, L).transpose(2, 0, 1, 3)

    if state is None:
        C0 = jnp.zeros((B, H, d, d), jnp.float32)
        n0 = jnp.zeros((B, H, d), jnp.float32)
        m0 = jnp.zeros((B, H), jnp.float32)
    else:
        C0, n0, m0 = state

    tri = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(carry, xs):
        C, n, m = carry
        qj, kj, vj, ij, gj = xs
        Fc = jnp.cumsum(gj, axis=-1)  # (B,H,L) inclusive log-decay
        A = jax.lax.cummax(ij - Fc, axis=2)  # (B,H,L)
        m_loc = Fc + jnp.maximum(m[..., None], A)  # stabilizer per position
        inter_w = jnp.exp(Fc + m[..., None] - m_loc)  # (B,H,L)

        # intra-chunk decay-gate matrix W[t,s] = exp(F_t - F_s + i_s - m_t)
        lgm = (
            Fc[..., :, None]
            - Fc[..., None, :]
            + ij[..., None, :]
            - m_loc[..., :, None]
        )
        Wm = jnp.where(tri, jnp.exp(lgm), 0.0)  # (B,H,L,L)

        qk = jnp.einsum("bhtd,bhsd->bhts", qj, kj)
        h_intra = jnp.einsum("bhts,bhts,bhsv->bhtv", qk, Wm, vj)
        h_inter = jnp.einsum("bhtd,bhdv->bhtv", qj, C) * inter_w[..., None]
        num = h_intra + h_inter

        n_loc = jnp.einsum("bhts,bhsd->bhtd", Wm, kj) + n[:, :, None] * inter_w[
            ..., None
        ]
        qn = jnp.einsum("bhtd,bhtd->bht", qj, n_loc)
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_loc))
        h = num / denom[..., None]

        # end-of-chunk state
        FL = Fc[..., -1]
        m_next = FL + jnp.maximum(m, A[..., -1])
        decay = jnp.exp(FL + m - m_next)
        wts = jnp.exp(FL[..., None] - Fc + ij - m_next[..., None])  # (B,H,L)
        C_next = decay[..., None, None] * C + jnp.einsum(
            "bhs,bhsd,bhsv->bhdv", wts, kj, vj
        )
        n_next = decay[..., None] * n + jnp.einsum("bhs,bhsd->bhd", wts, kj)
        return (C_next, n_next, m_next), h

    if unroll:
        carry = (C0, n0, m0)
        hs = []
        for j in range(nc):
            carry, hj = chunk_step(carry, (qc[j], kc[j], vc[j], ic[j], gc[j]))
            hs.append(hj)
        (C, n, m), hc = carry, jnp.stack(hs)
    else:
        (C, n, m), hc = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, ic, gc))
    h = hc.transpose(1, 2, 0, 3, 4).reshape(B, H, S, d).transpose(0, 2, 1, 3)
    return h, (C, n, m)


def mlstm_step(q, k, v, i_pre, f_pre, state):
    """Single decode step. q,k,v: (B,H,d); i/f_pre: (B,H)."""
    C, n, m = state
    d = q.shape[-1]
    qf = q.astype(jnp.float32) * (d**-0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    ii = i_pre.astype(jnp.float32)
    m2 = jnp.maximum(lf + m, ii)
    fw = jnp.exp(lf + m - m2)
    iw = jnp.exp(ii - m2)
    C2 = fw[..., None, None] * C + iw[..., None, None] * (
        kf[..., :, None] * vf[..., None, :]
    )
    n2 = fw[..., None] * n + iw[..., None] * kf
    num = jnp.einsum("bhd,bhdv->bhv", qf, C2)
    qn = jnp.einsum("bhd,bhd->bh", qf, n2)
    h = num / jnp.maximum(jnp.abs(qn), jnp.exp(-m2))[..., None]
    return h, (C2, n2, m2)


def _mlstm_qkvif(p, x_in, cfg, decode_conv_state=None):
    """Shared projection path. x_in: (B,S,F) post-up-projection conv input.
    Returns (q,k,v (B,S,H,d), i,f (B,S,H), new_conv_state)."""
    from repro.models.recurrent import causal_conv1d

    dt = x_in.dtype
    if decode_conv_state is None:
        c = causal_conv1d(x_in, p["conv_w"], p["conv_b"])
        new_state = None
    else:
        hist = jnp.concatenate([decode_conv_state, x_in], axis=1)
        c = (
            jnp.einsum("bcw,cw->bw", hist, p["conv_w"].astype(dt))[:, None]
            + p["conv_b"].astype(dt)
        )
        new_state = hist[:, 1:]
    c = jax.nn.silu(c)
    q = jnp.einsum("bsf,fhd->bshd", c, p["wq"].astype(dt))
    k = jnp.einsum("bsf,fhd->bshd", c, p["wk"].astype(dt))
    v = jnp.einsum("bsf,fhd->bshd", x_in, p["wv"].astype(dt))
    i_pre = jnp.einsum("bsf,fh->bsh", c, p["w_i"].astype(dt)) + p["b_i"].astype(dt)
    f_pre = jnp.einsum("bsf,fh->bsh", c, p["w_f"].astype(dt)) + p["b_f"].astype(dt)
    return q, k, v, i_pre, f_pre, new_state


def mlstm_forward(p, x, cfg, shd: Sharder):
    """Full-sequence mLSTM mixer. x: (B,S,D) -> (B,S,D)."""
    dt = jnp.dtype(cfg.dtype)
    B, S, D = x.shape
    H = cfg.n_heads
    up = jnp.einsum("bsd,dcf->bscf", x, p["up"].astype(dt))
    z, x_in = up[:, :, 0], up[:, :, 1]
    x_in = shd.act(x_in, "batch", "seq", "act_mlp")
    q, k, v, i_pre, f_pre, _ = _mlstm_qkvif(p, x_in, cfg)
    h, _ = mlstm_chunkwise(
        q, k, v, i_pre, f_pre, cfg.mlstm_chunk, unroll=not cfg.scan_layers
    )
    h = group_norm_heads(h.astype(dt), p["gn_scale"])
    hf = h.reshape(B, S, -1)
    y = jnp.einsum("bsf,fd->bsd", hf * jax.nn.silu(z), p["down"].astype(dt))
    return shd.act(y, "batch", "res_seq", "act_embed")


def init_mlstm_cache(ini: Init, cfg, batch: int):
    F = 2 * cfg.d_model
    H = cfg.n_heads
    d = F // H
    return {
        "C": ini.zeros((batch, H, d, d), ("batch", "heads", "head_dim", None), dtype=jnp.float32),
        "n": ini.zeros((batch, H, d), ("batch", "heads", "head_dim"), dtype=jnp.float32),
        "m": ini.zeros((batch, H), ("batch", "heads"), dtype=jnp.float32),
        "conv": ini.zeros(
            (batch, cfg.conv_width - 1, F), ("batch", None, "act_mlp"), dtype=jnp.dtype(cfg.dtype)
        ),
    }


def mlstm_decode(p, x, cache, cfg, shd: Sharder):
    dt = jnp.dtype(cfg.dtype)
    B = x.shape[0]
    up = jnp.einsum("bsd,dcf->bscf", x, p["up"].astype(dt))
    z, x_in = up[:, :, 0], up[:, :, 1]
    q, k, v, i_pre, f_pre, new_conv = _mlstm_qkvif(p, x_in, cfg, cache["conv"])
    h, (C, n, m) = mlstm_step(
        q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0], (cache["C"], cache["n"], cache["m"])
    )
    h = group_norm_heads(h.astype(dt)[:, None], p["gn_scale"])  # (B,1,H,d)
    hf = h.reshape(B, 1, -1)
    y = jnp.einsum("bsf,fd->bsd", hf * jax.nn.silu(z), p["down"].astype(dt))
    return y, {"C": C, "n": n, "m": m, "conv": new_conv}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def _slstm_ffn_dim(D: int) -> int:
    f = (4 * D) // 3
    return (f + 127) // 128 * 128


def init_slstm_block(ini: Init, cfg):
    D = cfg.d_model
    H = cfg.n_heads
    dh = D // H
    Fs = _slstm_ffn_dim(D)
    return {
        "w": ini.fan_in((D, 4, H, dh), ("embed", None, "heads", "head_dim"), fan_axes=(0,)),
        "r": ini.fan_in((4, H, dh, dh), (None, "heads", None, "head_dim"), fan_axes=(2,)),
        "b": ini.zeros((4, H, dh), (None, "heads", "head_dim")),
        "gn_scale": ini.ones((H, dh), ("heads", "head_dim")),
        "ffn_up": ini.fan_in((D, 2, Fs), ("embed", None, "mlp"), fan_axes=(0,)),
        "ffn_down": ini.fan_in((Fs, D), ("mlp", "embed")),
    }


def slstm_cell(wx, state, r, ):
    """One step. wx: (B,4,H,dh) input preacts; state: (c,n,h,m) each (B,H,dh)."""
    c, n, h, m = state
    rec = jnp.einsum("bhd,ghde->bghe", h, r.astype(h.dtype))  # (B,4,H,dh)
    pre = (wx + rec).astype(jnp.float32)
    z = jnp.tanh(pre[:, 0])
    i_pre = pre[:, 1]
    f_pre = pre[:, 2]
    o = jax.nn.sigmoid(pre[:, 3])
    lf = jax.nn.log_sigmoid(f_pre)
    m2 = jnp.maximum(lf + m, i_pre)
    iw = jnp.exp(i_pre - m2)
    fw = jnp.exp(lf + m - m2)
    c2 = fw * c + iw * z
    n2 = fw * n + iw
    h2 = o * c2 / jnp.maximum(n2, 1e-6)
    return (c2, n2, h2, m2)


def slstm_sequence(p, x, cfg, state):
    """x: (B,S,D). Sequential scan over S. Returns (h_seq (B,S,H,dh), state)."""
    H = cfg.n_heads
    dh = cfg.d_model // H
    dt = x.dtype
    wx = jnp.einsum("bsd,dghe->bsghe", x, p["w"].astype(dt)) + p["b"].astype(dt)

    def step(carry, wx_t):
        new = slstm_cell(wx_t, carry, p["r"])
        return new, new[2].astype(dt)  # bf16 ys: halves the saved timeline

    state, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2, 3, 4))
    return hs.transpose(1, 0, 2, 3), state  # (B,S,H,dh)


def slstm_init_state(ini: Init, cfg, batch: int):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = lambda: ini.zeros((batch, H, dh), ("batch", "heads", "head_dim"), dtype=jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": z()}


def _slstm_out(p, hs, x, cfg, shd):
    """Group-norm heads, gated FFN, residual-ready output."""
    dt = jnp.dtype(cfg.dtype)
    B, S = hs.shape[:2]
    h = group_norm_heads(hs.astype(dt), p["gn_scale"]).reshape(B, S, -1)
    up = jnp.einsum("bsd,dcf->bscf", h, p["ffn_up"].astype(dt))
    g, u = up[:, :, 0], up[:, :, 1]
    y = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(g) * u, p["ffn_down"].astype(dt))
    return shd.act(y, "batch", "res_seq", "act_embed")


def slstm_forward(p, x, cfg, shd: Sharder):
    B = x.shape[0]
    H, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((B, H, dh), jnp.float32)
    hs, _ = slstm_sequence(p, x, cfg, (z, z, z, z))
    return _slstm_out(p, hs, x, cfg, shd)


def slstm_decode(p, x, cache, cfg, shd: Sharder):
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    hs, state = slstm_sequence(p, x, cfg, state)
    y = _slstm_out(p, hs, x, cfg, shd)
    c, n, h, m = state
    return y, {"c": c, "n": n, "h": h, "m": m}
