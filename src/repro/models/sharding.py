"""Logical-axis sharding: the single place where mesh layout decisions live.

Every parameter and activation is annotated with *logical* axis names
('embed', 'heads', 'mlp', ...).  A rule table maps logical names to an
ordered list of *mesh*-axis candidates; ``spec_for`` greedily assigns the
first candidate that (a) exists in the mesh, (b) is not already used by
another dim of the same tensor, and (c) divides the dim size.  Indivisible
or unavailable candidates fall through — e.g. qwen2's 28 heads cannot
shard over a 16-way model axis, so the 'head_dim' dim (128) picks up the
model axis instead; XLA then contracts over the sharded head_dim with a
reduce-scatter/all-reduce.  This keeps ONE rule table valid for every
assigned architecture and both production meshes.

FSDP: weight 'embed' dims shard over the data axis and are all-gathered
per layer inside the scan (XLA GSPMD inserts + overlaps the gathers).
Cross-pod: only the batch uses the 'pod' axis — parameters are replicated
pod-wise, so the inter-pod links carry gradient all-reduces only (which is
where optional compression applies, see repro.optim.compression).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# Candidate lists: each entry is a tuple of mesh axes used jointly for a dim.
Rules = Dict[str, Tuple[Tuple[str, ...], ...]]

# Default (baseline) rule table used by the dry-run.  Hillclimbs override.
DEFAULT_RULES: Rules = {
    # activations
    "batch": (("pod", "data"), ("data",)),
    "seq": (),
    "kv_seq": (),  # overridden to (('data',),) for long-context decode (SP)
    # Megatron-style sequence-parallel residual stream: between blocks the
    # (B,S,D) residual is sharded S->model, so each block entry all-gathers
    # S and each block exit's contracting matmul becomes a reduce-scatter
    # (instead of an all-reduce) — and the layer-scan carry shrinks 16x.
    # Only used at block boundaries ('res_seq'); intra-block tensors keep
    # full S ('seq').
    "res_seq": (("model",),),
    "act_embed": (),
    "act_heads": (("model",),),
    "act_mlp": (("model",),),
    "act_vocab": (("model",),),
    "act_expert": (),
    "ffn_batch": (),  # hillclimb hooks (see mlp_forward) — default no-op
    "ffn_embed": (),
    # parameters
    "embed": (("data",),),  # FSDP
    "vocab": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (("model",),),  # fallback when heads don't divide
    "mlp": (("model",),),
    "expert": (),  # baseline: dense dispatch, experts FSDP'd via 'embed'
    "rnn": (("model",),),
    "rnn_in": (("data",),),  # FSDP dim of recurrent weights
    "layers": (),
    "conv": (),
    "pos": (),
}

LONG_CONTEXT_OVERRIDES: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    # batch=1 cannot shard; put the KV sequence on the data axis instead
    # (sequence parallelism for the 500k cache).
    "kv_seq": (("data",), ("model",)),
}


def make_rules(**overrides) -> Rules:
    rules = dict(DEFAULT_RULES)
    rules.update(overrides)
    return rules


class ParamLeaf(NamedTuple):
    """A parameter value bundled with its logical axes at init time."""

    value: Any  # jnp.ndarray | jax.ShapeDtypeStruct
    axes: Tuple[Optional[str], ...]


def is_param_leaf(x) -> bool:
    return isinstance(x, ParamLeaf)


def split_tree(tree):
    """Split a tree of ParamLeaf into (values, axes) trees."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param_leaf)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param_leaf)
    return values, axes


def spec_for(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    rules: Rules,
    mesh: Mesh,
) -> PartitionSpec:
    """Greedy logical->mesh assignment with divisibility fallback."""
    assert len(shape) == len(axes), (shape, axes)
    used: set = set()
    out = []
    mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, name in zip(shape, axes):
        assigned = None
        if name is not None:
            for cand in rules.get(name, ()):
                if not all(a in mesh_axes for a in cand):
                    continue
                if any(a in used for a in cand):
                    continue
                size = int(np.prod([mesh_axes[a] for a in cand]))
                if size > 1 and dim % size == 0:
                    assigned = cand if len(cand) > 1 else cand[0]
                    used.update(cand)
                    break
        out.append(assigned)
    # trim trailing Nones for cleanliness
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


@dataclasses.dataclass
class Sharder:
    """Threads mesh+rules through model code.

    ``mesh=None`` (single-device smoke tests) makes every annotation a
    no-op, so the same model code runs un-meshed on CPU.
    """

    mesh: Optional[Mesh] = None
    rules: Rules = dataclasses.field(default_factory=lambda: dict(DEFAULT_RULES))

    def act(self, x, *axes: Optional[str]):
        """Constrain an activation's sharding by logical axis names.

        An all-None spec is a NO-OP (returning the constraint would force
        full replication — P() is not "unconstrained" to GSPMD)."""
        if self.mesh is None:
            return x
        spec = spec_for(x.shape, axes, self.rules, self.mesh)
        if not any(s is not None for s in spec):
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def param_sharding(self, value, axes) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, spec_for(value.shape, axes, self.rules, self.mesh))

    def tree_shardings(self, values_tree, axes_tree):
        """NamedSharding tree for a (values, axes) tree pair.

        Maps over the *axes* tree (whose leaves are tuples of logical axis
        names — tuples would otherwise be flattened as pytree containers,
        and None entries dropped) with the values tree alongside.
        """
        return jax.tree.map(
            lambda a, v: self.param_sharding(v, a),
            axes_tree,
            values_tree,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )


def n_kv_virtual(n_heads: int, n_kv: int, model_axis: int) -> int:
    """Smallest KV-head replication target that (a) is a multiple of n_kv,
    (b) divides n_heads, and (c) is divisible by the model-axis size, so the
    KV cache shards cleanly and every device keeps aligned q/kv groups.
    Falls back to n_kv (no replication) when impossible (e.g. qwen2 28H/4kv
    on a 16-way axis -> head_dim sharding takes over instead)."""
    if n_kv % model_axis == 0:
        return n_kv
    v = n_kv
    while v <= n_heads:
        if v % n_kv == 0 and n_heads % v == 0 and v % model_axis == 0:
            return v
        v += n_kv
    return n_kv
