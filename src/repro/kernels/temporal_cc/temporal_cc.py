"""Pallas TPU kernel: temporal connected components — min-label
propagation per timepoint batch.

Each node starts with its own row index as label; every round folds the
minimum label over the node's neighborhood (a masked min over the dense
adjacency tile — the VPU-wide min-fold variant of a psum), and labels
monotonically shrink to the component minimum.  ``iters`` rounds resolve
every component whose diameter is <= iters; the fused jnp path and the
host reference run the identical bounded propagation, so results are
bit-identical (int32) by construction.

Grid: (T,).  Blocks are (1, N, N) adjacency + (1, N) activity per
timepoint, N a multiple of 128 (ops.py pads).  Inactive (and padded)
nodes take label -1 and never win a min.  Validated in interpret mode
against ref.cc_ref (CPU container); on TPU the same pallas_call lowers
natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _cc_kernel(adj_ref, active_ref, out_ref, *, iters: int):
    a = adj_ref[0]  # (N, N) f32 symmetric, zero diagonal
    act = active_ref[0] != 0  # (1, N)
    N = a.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, N), 1)
    big = jnp.int32(N)  # sentinel: larger than any real label
    labels = jnp.where(act, iota, big)
    edge = a > 0  # (N, N); edges only join active endpoints
    for _ in range(iters):  # static unroll
        # min label over each node's neighborhood: broadcast labels down
        # the source axis, mask by adjacency, min-fold the columns
        src = jnp.broadcast_to(labels.reshape(-1, 1), (N, N))
        neigh = jnp.min(jnp.where(edge, src, big), axis=0, keepdims=True)
        labels = jnp.minimum(labels, neigh)
    out_ref[...] = jnp.where(act, labels, -1).reshape(out_ref.shape)


def cc_pallas(adj, active, iters: int = 32, interpret: bool = True):
    """adj: (T, N, N) f32 symmetric dense adjacency; active: (T, N) mask.
    Returns labels (T, N) int32 — min member-row index per component
    after ``iters`` propagation rounds, -1 on inactive nodes.  N must be
    a multiple of 128 (ops.py pads)."""
    T, N, _ = adj.shape
    assert N % LANE == 0, N
    return pl.pallas_call(
        functools.partial(_cc_kernel, iters=int(iters)),
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, N, N), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, N), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, N), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, N), jnp.int32),
        interpret=interpret,
    )(adj.astype(jnp.float32), active.astype(jnp.float32))
