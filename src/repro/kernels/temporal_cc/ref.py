"""Pure-jnp oracle for the temporal connected-components kernel: the
identical bounded min-label propagation vmapped over timepoints.
Integer labels — interpret-mode and native runs are bit-identical."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cc_ref(adj, active, iters: int = 32):
    """adj: (T, N, N) symmetric dense adjacency; active: (T, N) mask.
    Returns labels (T, N) int32 (-1 on inactive nodes)."""
    adj = jnp.asarray(adj, jnp.float32)
    active = jnp.asarray(active)
    N = adj.shape[-1]

    def one(a, act_row):
        act = (act_row != 0).reshape(1, N)
        iota = jax.lax.broadcasted_iota(jnp.int32, (1, N), 1)
        big = jnp.int32(N)
        labels = jnp.where(act, iota, big)
        edge = a > 0
        for _ in range(iters):
            src = jnp.broadcast_to(labels.reshape(-1, 1), (N, N))
            neigh = jnp.min(jnp.where(edge, src, big), axis=0, keepdims=True)
            labels = jnp.minimum(labels, neigh)
        return jnp.where(act, labels, -1).reshape(-1)

    return jax.vmap(one)(adj, active)
