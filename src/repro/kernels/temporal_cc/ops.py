"""Jit'd wrapper for the temporal connected-components kernel: node-axis
padding, interpret-mode fallback (CPU) / native lowering (TPU)."""
from __future__ import annotations

import jax

from repro.kernels.temporal_cc import ref
from repro.kernels.temporal_cc.temporal_cc import cc_pallas
from repro.kernels.temporal_pagerank.ops import pad_nodes


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def temporal_cc(adj, active, iters: int = 32, use_pallas: bool = True):
    """Component labels (T, N) int32 at every timepoint from dense
    adjacency (min member-row index per component after ``iters``
    propagation rounds; -1 on inactive nodes).

    adj: (T, N, N) symmetric 0/1 adjacency; active: (T, N) mask.
    Accepts numpy or jnp.  Runs the Pallas kernel in interpret mode
    off-TPU and natively on TPU, or the pure-jnp reference with
    ``use_pallas=False``.
    """
    if not use_pallas:
        return ref.cc_ref(adj, active, iters=iters)
    padded, act, N = pad_nodes(adj, active)
    out = cc_pallas(padded, act, iters=iters, interpret=not _on_tpu())
    return out[:, :N]
