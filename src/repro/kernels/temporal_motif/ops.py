"""Jit'd wrapper for the temporal motif kernel: node-axis padding,
interpret-mode fallback (CPU) / native lowering (TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.temporal_motif import ref
from repro.kernels.temporal_motif.temporal_motif import LANE, motif_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def temporal_motif(adj, use_pallas: bool = True):
    """Per-node triangle counts (T, N) int32 at every timepoint from
    dense adjacency.

    adj: (T, N, N) symmetric 0/1 adjacency (zero diagonal).  Accepts
    numpy or jnp.  Runs the Pallas kernel in interpret mode off-TPU and
    natively on TPU, or the pure-jnp reference with ``use_pallas=False``.
    """
    if not use_pallas:
        return ref.motif_ref(adj)
    adj = jnp.asarray(adj, jnp.float32)
    N = adj.shape[-1]
    pad = (-N) % LANE
    if pad:
        adj = jnp.pad(adj, ((0, 0), (0, pad), (0, pad)))
    out = motif_pallas(adj, interpret=not _on_tpu())
    return out[:, :N]
