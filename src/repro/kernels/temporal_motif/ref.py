"""Pure-jnp oracle for the temporal motif kernel: per-node triangle
counts via diag(A^3)/2, vmapped over timepoints.  Integer counts (exact
in f32 below 2^24) — interpret-mode and native runs are bit-identical."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def motif_ref(adj):
    """adj: (T, N, N) symmetric dense adjacency (zero diagonal).
    Returns per-node triangle counts (T, N) int32."""
    adj = jnp.asarray(adj, jnp.float32)

    def one(a):
        a2 = jnp.dot(a, a, preferred_element_type=jnp.float32)
        tri = jnp.sum(a2 * a, axis=0) * 0.5
        return tri.astype(jnp.int32)

    return jax.vmap(one)(adj)
