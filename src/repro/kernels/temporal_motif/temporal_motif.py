"""Pallas TPU kernel: temporal triangle/motif counting per timepoint
batch over the packed pair table's dense adjacency.

Per-node triangle participation at timepoint t is diag(A_t^3) / 2; the
kernel computes it as one MXU matmul (A^2) plus a masked row reduction
(sum_j (A^2)[i, j] * A[i, j] / 2) — counting, for every incident edge,
the common neighbors that close a wedge into a triangle.  Counts are
exact: float32 accumulators stay below 2^24 for any N this kernel can
tile, and the result is cast to int32.

Grid: (T,).  Blocks are (1, N, N) adjacency per timepoint, N a multiple
of 128 (ops.py pads; padded nodes have no edges).  Validated in
interpret mode against ref.motif_ref (CPU container); on TPU the same
pallas_call lowers natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _motif_kernel(adj_ref, out_ref):
    a = adj_ref[0]  # (N, N) f32 symmetric 0/1, zero diagonal
    a2 = jnp.dot(a, a, preferred_element_type=jnp.float32)
    tri = jnp.sum(a2 * a, axis=0, keepdims=True) * 0.5  # (1, N)
    out_ref[...] = tri.astype(jnp.int32).reshape(out_ref.shape)


def motif_pallas(adj, interpret: bool = True):
    """adj: (T, N, N) f32 symmetric dense adjacency (zero diagonal).
    Returns per-node triangle counts (T, N) int32.  N must be a multiple
    of 128 (ops.py pads)."""
    T, N, _ = adj.shape
    assert N % LANE == 0, N
    return pl.pallas_call(
        _motif_kernel,
        grid=(T,),
        in_specs=[pl.BlockSpec((1, N, N), lambda t: (t, 0, 0))],
        out_specs=pl.BlockSpec((1, N), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, N), jnp.int32),
        interpret=interpret,
    )(adj.astype(jnp.float32))
