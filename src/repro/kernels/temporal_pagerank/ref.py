"""Pure-jnp oracle for the temporal PageRank kernel: the identical
damped power iteration (uniform dangling-mass redistribution, fixed
iteration count, inactive nodes pinned to 0), vmapped over timepoints.
Operation order matches the kernel exactly, so interpret-mode runs are
bit-identical; native TPU lowering stays within float32 tolerance."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pagerank_ref(adj, active, damping: float = 0.85, iters: int = 20):
    """adj: (T, N, N) symmetric dense adjacency; active: (T, N) mask.
    Returns ranks (T, N) f32."""
    adj = jnp.asarray(adj, jnp.float32)
    active = jnp.asarray(active, jnp.float32)

    def one(a, act):
        act = act.reshape(1, -1)
        deg = jnp.sum(a, axis=0, keepdims=True)
        n = jnp.maximum(jnp.sum(act), 1.0)
        r = act / n
        dangling_mask = act * (deg == 0).astype(jnp.float32)
        for _ in range(iters):
            contrib = jnp.where(deg > 0, r / jnp.maximum(deg, 1.0), 0.0)
            nxt = jnp.dot(contrib, a, preferred_element_type=jnp.float32)
            dangling = jnp.sum(r * dangling_mask)
            r = act * ((1.0 - damping) / n + damping * (nxt + dangling / n))
        return r.reshape(-1)

    return jax.vmap(one)(adj, active)
