"""Pallas TPU kernel: temporal PageRank — power iteration per timepoint
batch over dense per-timepoint adjacency tiles.

The whole-plan compiler (repro.taf.compile) materializes the operand's
``EdgeReplay`` pair table at T timepoints; this kernel runs the damped
power iteration for every timepoint in one launch.  Layout choice: on
TPU the per-timepoint graph becomes a dense (N, N) float32 tile so every
iteration's gather-scatter (rank mass flowing along edges) is ONE MXU
matmul — the dense tile is the csr_at gather re-laid-out for the
systolic array, and it stays resident in VMEM across all ``iters``
iterations (the fused jnp path in taf.compile uses the equivalent
pair-table gather/scatter formulation; both are parity-tested).

Grid: (T,).  Blocks are (1, N, N) adjacency + (1, N) activity per
timepoint, N a multiple of 128 (ops.py pads; padded nodes are inactive).
Validated in interpret mode against ref.pagerank_ref (CPU container); on
TPU the same pallas_call lowers natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _pagerank_kernel(adj_ref, active_ref, out_ref, *, damping: float,
                     iters: int):
    a = adj_ref[0]  # (N, N) f32, symmetric, zero diagonal
    act = active_ref[0].astype(jnp.float32)  # (1, N)
    # symmetric adjacency: column sums == row sums == degree
    deg = jnp.sum(a, axis=0, keepdims=True)  # (1, N)
    n = jnp.maximum(jnp.sum(act), 1.0)  # live node count (scalar)
    r = act / n
    dangling_mask = act * (deg == 0).astype(jnp.float32)
    for _ in range(iters):  # static unroll: iters is small
        contrib = jnp.where(deg > 0, r / jnp.maximum(deg, 1.0), 0.0)
        nxt = jnp.dot(contrib, a, preferred_element_type=jnp.float32)
        dangling = jnp.sum(r * dangling_mask)
        r = act * ((1.0 - damping) / n + damping * (nxt + dangling / n))
    out_ref[...] = r.reshape(out_ref.shape)


def pagerank_pallas(adj, active, damping: float = 0.85, iters: int = 20,
                    interpret: bool = True):
    """adj: (T, N, N) f32 symmetric dense adjacency (zero diagonal);
    active: (T, N) int8/f32 node-present mask.  Returns ranks (T, N) f32
    (0 on inactive nodes).  N must be a multiple of 128 (ops.py pads)."""
    T, N, _ = adj.shape
    assert N % LANE == 0, N
    return pl.pallas_call(
        functools.partial(_pagerank_kernel, damping=float(damping),
                          iters=int(iters)),
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, N, N), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, N), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, N), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, N), jnp.float32),
        interpret=interpret,
    )(adj.astype(jnp.float32), active.astype(jnp.float32))
