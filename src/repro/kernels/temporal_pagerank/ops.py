"""Jit'd wrapper for the temporal PageRank kernel: node-axis padding to
the 128-lane tile, interpret-mode fallback (CPU container) / native
lowering (TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.temporal_pagerank import ref
from repro.kernels.temporal_pagerank.temporal_pagerank import (
    LANE,
    pagerank_pallas,
)


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def pad_nodes(adj, active):
    """Pad the node axis to a multiple of 128 (padded nodes inactive,
    no incident edges — they cannot perturb live ranks/labels/counts)."""
    adj = jnp.asarray(adj, jnp.float32)
    active = jnp.asarray(active, jnp.float32)
    N = adj.shape[-1]
    pad = (-N) % LANE
    if pad:
        adj = jnp.pad(adj, ((0, 0), (0, pad), (0, pad)))
        active = jnp.pad(active, ((0, 0), (0, pad)))
    return adj, active, N


def temporal_pagerank(adj, active, damping: float = 0.85, iters: int = 20,
                      use_pallas: bool = True):
    """Ranks (T, N) f32 at every timepoint from dense adjacency.

    adj: (T, N, N) symmetric 0/1 adjacency (zero diagonal);
    active: (T, N) present mask.  Accepts numpy or jnp.  Runs the Pallas
    kernel in interpret mode off-TPU and natively on TPU, or the pure-jnp
    reference with ``use_pallas=False``.
    """
    if not use_pallas:
        return ref.pagerank_ref(adj, active, damping=damping, iters=iters)
    padded, act, N = pad_nodes(adj, active)
    out = pagerank_pallas(padded, act, damping=damping, iters=iters,
                          interpret=not _on_tpu())
    return out[:, :N]
