"""Pallas TPU kernel: chunked RG-LRU linear-recurrence scan.

The associative-scan lowering materializes O(log S) intermediate
(B,S,W) tensors in HBM; the chunked kernel streams (CHUNK, TILE_W) tiles
through VMEM, carrying the recurrent state h (TILE_W lanes) in scratch
across the sequential chunk axis — one HBM read of (log_a, b) and one
write of h, which is the bandwidth floor for this memory-bound op.

Grid (B, W//TILE_W, S//CHUNK): last axis sequential (carries state).
Within a chunk the recurrence is a static unrolled loop over CHUNK steps
of (TILE_W,)-lane vector ops — sequential in time, parallel across lanes,
exactly the TPU-native shape of a depthwise recurrence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 64
TILE_W = 128


def _rglru_kernel(la_ref, b_ref, o_ref, h_ref, *, chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    h = h_ref[...]  # (1, TILE_W)
    la = la_ref[0]  # (chunk, TILE_W)
    bb = b_ref[0]
    out = jnp.zeros_like(bb)
    for t in range(chunk):  # static unroll; vectorized over TILE_W lanes
        h = jnp.exp(la[t : t + 1]) * h + bb[t : t + 1]
        out = jax.lax.dynamic_update_slice(out, h, (t, 0))
    o_ref[0] = out
    h_ref[...] = h


def rglru_pallas(log_a, b, *, chunk=CHUNK, tile_w=TILE_W, interpret=True):
    """log_a, b: (B, S, W) f32, S % chunk == 0, W % tile_w == 0."""
    B, S, W = log_a.shape
    assert S % chunk == 0 and W % tile_w == 0, (S, W)
    grid = (B, W // tile_w, S // chunk)
    spec = pl.BlockSpec((1, chunk, tile_w), lambda bdim, w, c: (bdim, c, w))
    return pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, tile_w), jnp.float32)],
        interpret=interpret,
    )(log_a, b)
