"""Oracle for the rglru_scan kernel: log-depth associative scan of the
first-order linear recurrence h_t = exp(log_a_t) * h_{t-1} + b_t."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(log_a, b):
    """log_a, b: (B, S, W) f32 -> h: (B, S, W) f32."""

    def combine(e1, e2):
        la1, b1 = e1
        la2, b2 = e2
        return la1 + la2, jnp.exp(la2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h
