"""Jit'd wrapper for the rglru_scan kernel: padding + dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.rglru_scan import CHUNK, TILE_W, rglru_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("chunk", "tile_w"))
def rglru(log_a, b, chunk=CHUNK, tile_w=TILE_W):
    """h_t = exp(log_a_t) h_{t-1} + b_t over axis 1.  (B,S,W) f32.

    Padding: S padded with log_a=0, b=0 (state passthrough, sliced off);
    W padded with zero lanes."""
    B, S, W = log_a.shape
    chunk = min(chunk, max(S, 8))
    tile_w = min(tile_w, max(W, 8))
    ps = (-S) % chunk
    pw = (-W) % tile_w
    la = jnp.pad(log_a.astype(jnp.float32), ((0, 0), (0, ps), (0, pw)))
    bb = jnp.pad(b.astype(jnp.float32), ((0, 0), (0, ps), (0, pw)))
    h = rglru_pallas(la, bb, chunk=chunk, tile_w=tile_w, interpret=not _on_tpu())
    return h[:, :S, :W]
