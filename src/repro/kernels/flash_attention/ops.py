"""Jit'd wrapper: padding, head layout, interpret/native dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("causal", "window", "blk_q", "blk_k"))
def flash_attention(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                    blk_q=128, blk_k=128):
    """q: (B,H,Sq,D); k,v: (B,H,Sk,D) (KV pre-expanded to H heads);
    q_pos (Sq,), k_pos (Sk,).  Pads S to block multiples; padded k rows
    carry k_pos=-1 (masked), padded q rows are sliced off."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    blk_q = min(blk_q, max(Sq, 8))
    blk_k = min(blk_k, max(Sk, 8))
    pq = (-Sq) % blk_q
    pk = (-Sk) % blk_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    qpos = jnp.pad(q_pos.astype(jnp.int32), (0, pq))
    kpos = jnp.pad(k_pos.astype(jnp.int32), (0, pk), constant_values=-1)
    out = flash_attention_pallas(
        qp, kp, vp, qpos, kpos, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, interpret=not _on_tpu()
    )
    return out[:, :, :Sq]
