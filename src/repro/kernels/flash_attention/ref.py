"""Pure-jnp oracle for the flash_attention kernel: naive masked softmax
(materializes the full score matrix — small test shapes only)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


def attention_ref(q, k, v, q_pos, k_pos, *, causal=True, window=0, scale=None):
    """q: (B,H,Sq,D); k,v: (B,H,Sk,D); q_pos: (Sq,); k_pos: (Sk,) (-1 pad).
    Returns (B,H,Sq,D) f32."""
    D = q.shape[-1]
    scale = scale or D**-0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    ok = k_pos[None, :] >= 0
    if causal:
        ok = ok & (k_pos[None, :] <= q_pos[:, None])
    if window > 0:
        ok = ok & (k_pos[None, :] > (q_pos[:, None] - window))
    s = jnp.where(ok[None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (padded queries): zero output
    any_ok = ok.any(axis=-1)[None, None, :, None]
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return jnp.where(any_ok, out, 0.0)
