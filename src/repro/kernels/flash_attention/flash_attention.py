"""Pallas TPU flash attention (GQA-ready: callers expand KV heads).

Online-softmax attention with KV tiling: grid (B, H, nq, nk), the last
axis sequential ('arbitrary') carrying (m, l, acc) in VMEM scratch.
Causal and sliding-window masks are composed from position blocks, so
ring-buffer caches (k_pos with -1 holes) work unchanged.

BlockSpecs: q (1,1,BQ,D), k/v (1,1,BK,D), positions (BQ,1)/(BK,1) int32 —
D and BQ/BK multiples of the (8,128) TPU tile.  Validated in interpret
mode against ref.attention_ref; lowers natively on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


def _fa_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, *, scale, causal, window, nk):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    qpos = qpos_ref[:, 0]  # (BQ,)
    kpos = kpos_ref[:, 0]  # (BK,)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (BQ, BK)
    ok = (kpos >= 0)[None, :]
    if causal:
        ok = ok & (kpos[None, :] <= qpos[:, None])
    if window > 0:
        ok = ok & (kpos[None, :] > (qpos[:, None] - window))
    s = jnp.where(ok, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(ok, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                           blk_q=128, blk_k=128, interpret=True):
    """q: (B,H,Sq,D); k,v: (B,H,Sk,D); q_pos (Sq,), k_pos (Sk,).
    Shapes must be pre-padded to block multiples (ops.py does this)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    assert Sq % blk_q == 0 and Sk % blk_k == 0, (Sq, Sk)
    nq, nk = Sq // blk_q, Sk // blk_k
    scale = D**-0.5
    grid = (B, H, nq, nk)

    qpos2 = q_pos.astype(jnp.int32).reshape(Sq, 1)
    kpos2 = k_pos.astype(jnp.int32).reshape(Sk, 1)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window, nk=nk
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_q, 1), lambda b, h, iq, ik: (iq, 0)),
            pl.BlockSpec((blk_k, 1), lambda b, h, iq, ik: (ik, 0)),
            pl.BlockSpec((1, 1, blk_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, blk_k, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, blk_k, D), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),  # m (running max)
            pltpu.VMEM((blk_q, 1), jnp.float32),  # l (running denom)
            pltpu.VMEM((blk_q, D), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(qpos2, kpos2, q, k, v)
    return out
