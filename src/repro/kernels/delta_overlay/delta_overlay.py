"""Pallas TPU kernel: fused h-way last-writer-wins delta overlay.

Snapshot reconstruction (paper Alg. 1) folds h snapshot deltas + e
eventlist deltas.  A naive chain does h+e HBM round-trips over the slot
tiles; this kernel reads all h stacked tiles into VMEM once and writes a
single output tile — bandwidth-optimal for the memory-bound fold.

Grid: (P, psize // TILE_S).  BlockSpec tiles are (h, 1, TILE_S[, K]) —
TILE_S a multiple of 128 (VPU lanes); the h axis is a static python loop
inside the kernel (h = tree height + replayed eventlists, typically <= 8).
Validated in interpret mode against ref.overlay_ref (CPU container); on
TPU the same pallas_call lowers natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_S = 256


def _overlay_kernel(valid_ref, present_ref, attrs_ref,
                    o_valid_ref, o_present_ref, o_attrs_ref, *, h: int):
    acc_v = valid_ref[0]  # (1, TILE_S) int8
    acc_p = present_ref[0]
    acc_a = attrs_ref[0]  # (1, TILE_S, K) int32
    for i in range(1, h):  # static unroll: h is small
        vi = valid_ref[i] != 0
        acc_p = jnp.where(vi, present_ref[i], acc_p)
        ai = attrs_ref[i]
        acc_a = jnp.where(vi[..., None] & (ai != -1), ai, acc_a)
        acc_a = jnp.where((acc_p == 0)[..., None], -1, acc_a)
        acc_v = jnp.maximum(acc_v, vi.astype(acc_v.dtype))
    o_valid_ref[...] = acc_v
    o_present_ref[...] = acc_p
    o_attrs_ref[...] = acc_a


def overlay_pallas(valid, present, attrs, interpret: bool = True):
    """valid/present: (h, P, S) int8; attrs: (h, P, S, K) int32.
    S must be a multiple of TILE_S (ops.py pads)."""
    h, P, S = valid.shape
    K = attrs.shape[-1]
    assert S % TILE_S == 0, S
    grid = (P, S // TILE_S)
    vp_spec = pl.BlockSpec((h, 1, TILE_S), lambda p, s: (0, p, s))
    at_spec = pl.BlockSpec((h, 1, TILE_S, K), lambda p, s: (0, p, s, 0))
    out_vp = pl.BlockSpec((1, TILE_S), lambda p, s: (p, s))
    out_at = pl.BlockSpec((1, TILE_S, K), lambda p, s: (p, s, 0))
    return pl.pallas_call(
        functools.partial(_overlay_kernel, h=h),
        grid=grid,
        in_specs=[vp_spec, vp_spec, at_spec],
        out_specs=[out_vp, out_vp, out_at],
        out_shape=[
            jax.ShapeDtypeStruct((P, S), valid.dtype),
            jax.ShapeDtypeStruct((P, S), present.dtype),
            jax.ShapeDtypeStruct((P, S, K), attrs.dtype),
        ],
        interpret=interpret,
    )(valid, present, attrs)


# ---------------------------------------------------------------------------
# Time-batched variant: one launch folds T timepoints over shared layers
# ---------------------------------------------------------------------------


def _overlay_batch_kernel(tmask_ref, valid_ref, present_ref, attrs_ref,
                          o_valid_ref, o_present_ref, o_attrs_ref,
                          *, h: int, T: int):
    """Per output timepoint t, fold the stacked layers whose
    ``tmask[i, t]`` bit is set (neutral start: valid=0/present=0/attrs=-1)
    with the same last-writer-wins overlay as ``_overlay_kernel``.  The
    stacked tiles are read into VMEM ONCE and reused for every timepoint
    — the bandwidth saving over T independent launches; h and T are
    static python loops (both small: tree height + one eventlist layer
    per timepoint)."""
    vs, ps, as_ = [], [], []
    for t in range(T):
        acc_v = jnp.zeros_like(valid_ref[0])  # (1, TILE_S)
        acc_p = jnp.zeros_like(present_ref[0])
        acc_a = jnp.full_like(attrs_ref[0], -1)  # (1, TILE_S, K)
        for i in range(h):
            use = tmask_ref[i, t] != 0  # scalar: layer i feeds timepoint t
            vi = (valid_ref[i] != 0) & use
            acc_p = jnp.where(vi, present_ref[i], acc_p)
            ai = attrs_ref[i]
            acc_a = jnp.where(vi[..., None] & (ai != -1), ai, acc_a)
            acc_a = jnp.where((acc_p == 0)[..., None], -1, acc_a)
            acc_v = jnp.maximum(acc_v, vi.astype(acc_v.dtype))
        vs.append(acc_v)
        ps.append(acc_p)
        as_.append(acc_a)
    o_valid_ref[...] = jnp.stack(vs, axis=-1)  # (1, TILE_S, T)
    o_present_ref[...] = jnp.stack(ps, axis=-1)
    o_attrs_ref[...] = jnp.stack(as_, axis=2)  # (1, TILE_S, T, K)


def overlay_batch_pallas(valid, present, attrs, tmask, interpret: bool = True):
    """valid/present: (h, P, S) int8; attrs: (h, P, S, K) int32;
    tmask: (h, T) int32 layer->timepoint validity mask.  Returns
    valid/present (P, S, T) and attrs (P, S, T, K).  S must be a multiple
    of TILE_S (ops.py pads)."""
    h, P, S = valid.shape
    K = attrs.shape[-1]
    T = tmask.shape[-1]
    assert S % TILE_S == 0, S
    grid = (P, S // TILE_S)
    mk_spec = pl.BlockSpec((h, T), lambda p, s: (0, 0))
    vp_spec = pl.BlockSpec((h, 1, TILE_S), lambda p, s: (0, p, s))
    at_spec = pl.BlockSpec((h, 1, TILE_S, K), lambda p, s: (0, p, s, 0))
    out_vp = pl.BlockSpec((1, TILE_S, T), lambda p, s: (p, s, 0))
    out_at = pl.BlockSpec((1, TILE_S, T, K), lambda p, s: (p, s, 0, 0))
    return pl.pallas_call(
        functools.partial(_overlay_batch_kernel, h=h, T=T),
        grid=grid,
        in_specs=[mk_spec, vp_spec, vp_spec, at_spec],
        out_specs=[out_vp, out_vp, out_at],
        out_shape=[
            jax.ShapeDtypeStruct((P, S, T), valid.dtype),
            jax.ShapeDtypeStruct((P, S, T), present.dtype),
            jax.ShapeDtypeStruct((P, S, T, K), attrs.dtype),
        ],
        interpret=interpret,
    )(tmask, valid, present, attrs)
