"""Jit'd wrapper for the delta_overlay kernel: padding, dtype handling,
interpret-mode fallback (CPU container) / native lowering (TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.delta_overlay import ref
from repro.kernels.delta_overlay.delta_overlay import (
    TILE_S,
    overlay_batch_pallas,
    overlay_pallas,
)


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def overlay(valid, present, attrs, use_pallas: bool = True):
    """Fold stacked deltas (h, P, S[, K]) -> (P, S[, K]).

    Accepts numpy or jnp; bool valid is cast to int8 for the kernel.
    """
    valid = jnp.asarray(valid)
    present = jnp.asarray(present)
    attrs = jnp.asarray(attrs)
    v8 = valid.astype(jnp.int8)
    if not use_pallas:
        return ref.overlay_ref(valid, present, attrs)
    S = valid.shape[-1]
    pad = (-S) % TILE_S
    if pad:
        v8 = jnp.pad(v8, ((0, 0), (0, 0), (0, pad)))
        present = jnp.pad(present, ((0, 0), (0, 0), (0, pad)))
        attrs = jnp.pad(attrs, ((0, 0), (0, 0), (0, pad), (0, 0)), constant_values=-1)
    out_v, out_p, out_a = overlay_pallas(
        v8, present, attrs, interpret=not _on_tpu()
    )
    if pad:
        out_v, out_p, out_a = out_v[:, :S], out_p[:, :S], out_a[:, :S]
    return out_v.astype(valid.dtype) != 0, out_p, out_a


def overlay_batch(valid, present, attrs, tmask, use_pallas: bool = True):
    """Time-batched fold: stacked deltas (h, P, S[, K]) + layer->timepoint
    mask (h, T) -> per-timepoint outputs (P, S, T[, K]).

    Timepoint t folds exactly the layers with ``tmask[i, t]`` set
    (typically: every shared hierarchy-path layer + that timepoint's own
    eventlist layer).  Accepts numpy or jnp; runs the Pallas kernel in
    interpret mode off-TPU and natively on TPU, or the pure-jnp reference
    with ``use_pallas=False``.
    """
    valid = jnp.asarray(valid)
    present = jnp.asarray(present)
    attrs = jnp.asarray(attrs)
    tmask = jnp.asarray(tmask, jnp.int32)
    v8 = valid.astype(jnp.int8)
    if not use_pallas:
        out_v, out_p, out_a = ref.overlay_batch_ref(v8, present, attrs, tmask)
        return out_v.astype(valid.dtype) != 0, out_p, out_a
    S = valid.shape[-1]
    pad = (-S) % TILE_S
    if pad:
        v8 = jnp.pad(v8, ((0, 0), (0, 0), (0, pad)))
        present = jnp.pad(present, ((0, 0), (0, 0), (0, pad)))
        attrs = jnp.pad(attrs, ((0, 0), (0, 0), (0, pad), (0, 0)),
                        constant_values=-1)
    out_v, out_p, out_a = overlay_batch_pallas(
        v8, present, attrs, tmask, interpret=not _on_tpu()
    )
    if pad:
        out_v, out_p, out_a = out_v[:, :S], out_p[:, :S], out_a[:, :S]
    return out_v.astype(valid.dtype) != 0, out_p, out_a
