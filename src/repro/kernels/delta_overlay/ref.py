"""Pure-jnp oracle for the delta_overlay kernel: sequential last-writer-
wins fold over a stacked delta chain (node payload of Algorithm 1's
Σ Δ_si + Σ Δ_ei).  Semantics mirror repro.core.delta._node_sum exactly,
including the per-step attribute clear on deletion."""
from __future__ import annotations

import jax.numpy as jnp


def overlay_ref(valid, present, attrs):
    """valid: (h, P, S) int8/bool; present: (h, P, S) int8;
    attrs: (h, P, S, K) int32.  Returns folded (valid, present, attrs)."""
    acc_v = valid[0].astype(jnp.bool_)
    acc_p = present[0]
    acc_a = attrs[0]
    for i in range(1, valid.shape[0]):
        vi = valid[i].astype(jnp.bool_)
        acc_p = jnp.where(vi, present[i], acc_p)
        ai = attrs[i]
        acc_a = jnp.where(vi[..., None] & (ai != -1), ai, acc_a)
        acc_a = jnp.where((acc_p == 0)[..., None], -1, acc_a)
        acc_v = acc_v | vi
    return acc_v, acc_p, acc_a
