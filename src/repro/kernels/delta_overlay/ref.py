"""Pure-jnp oracle for the delta_overlay kernel: sequential last-writer-
wins fold over a stacked delta chain (node payload of Algorithm 1's
Σ Δ_si + Σ Δ_ei).  Semantics mirror repro.core.delta._node_sum exactly,
including the per-step attribute clear on deletion."""
from __future__ import annotations

import jax.numpy as jnp


def overlay_ref(valid, present, attrs):
    """valid: (h, P, S) int8/bool; present: (h, P, S) int8;
    attrs: (h, P, S, K) int32.  Returns folded (valid, present, attrs)."""
    acc_v = valid[0].astype(jnp.bool_)
    acc_p = present[0]
    acc_a = attrs[0]
    for i in range(1, valid.shape[0]):
        vi = valid[i].astype(jnp.bool_)
        acc_p = jnp.where(vi, present[i], acc_p)
        ai = attrs[i]
        acc_a = jnp.where(vi[..., None] & (ai != -1), ai, acc_a)
        acc_a = jnp.where((acc_p == 0)[..., None], -1, acc_a)
        acc_v = acc_v | vi
    return acc_v, acc_p, acc_a


def overlay_batch_ref(valid, present, attrs, tmask):
    """Time-batched oracle: per timepoint t, fold the layers whose
    ``tmask[i, t]`` is set, from a neutral accumulator (valid=0,
    present=0, attrs=-1) — a masked-out layer behaves exactly like an
    all-invalid delta.  Returns valid/present (P, S, T), attrs
    (P, S, T, K); on slots whose validity comes from any folded layer the
    result equals the pairwise ``overlay_ref`` chain of those layers."""
    h = valid.shape[0]
    T = tmask.shape[-1]
    vs, ps, as_ = [], [], []
    for t in range(T):
        acc_v = jnp.zeros(valid.shape[1:], valid.dtype)
        acc_p = jnp.zeros(present.shape[1:], present.dtype)
        acc_a = jnp.full(attrs.shape[1:], -1, attrs.dtype)
        for i in range(h):
            use = tmask[i, t] != 0
            vi = (valid[i] != 0) & use
            acc_p = jnp.where(vi, present[i], acc_p)
            ai = attrs[i]
            acc_a = jnp.where(vi[..., None] & (ai != -1), ai, acc_a)
            acc_a = jnp.where((acc_p == 0)[..., None], -1, acc_a)
            acc_v = jnp.maximum(acc_v, vi.astype(acc_v.dtype))
        vs.append(acc_v)
        ps.append(acc_p)
        as_.append(acc_a)
    return (jnp.stack(vs, axis=-1), jnp.stack(ps, axis=-1),
            jnp.stack(as_, axis=2))
