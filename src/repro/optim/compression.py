"""Error-feedback gradient compression for the cross-pod all-reduce.

On a multi-pod mesh the inter-pod links (DCN / sparse ICI) are the thin
pipe — parameters are replicated pod-wise, so each step moves one full
gradient copy across pods.  We compress that traffic int8 with
per-chunk scaling and error feedback (residual carried in the optimizer
state), the standard 1-bit-Adam/EF-SGD recipe:

    q = quantize(g + e);  e' = (g + e) - dequant(q);  allreduce(q)

Under a single jit, the all-reduce is XLA's — we can't intercept the
collective itself, so compression is expressed with shard_map over the
'pod' axis: gradients arrive pod-local (summed over data via psum inside
the step), are quantized, jax.lax.psum'd as int32 (XLA carries the small
payload), and dequantized.  4x traffic reduction on the pod axis at the
cost of one extra residual buffer (int8-sized savings accounting is in
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

CHUNK = 2048


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-chunk symmetric int8 quantization. x: f32 (n,) padded to CHUNK."""
    xc = x.reshape(-1, CHUNK)
    scale = jnp.max(jnp.abs(xc), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xc / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).reshape(-1)


def ef_compress_leaf(g: jnp.ndarray, err: jnp.ndarray, axis_name: str):
    """Inside shard_map over the pod axis: error-feedback int8 all-reduce
    of one gradient leaf.  Returns (g_hat mean-reduced, new_err)."""
    n = g.size
    flat = g.astype(jnp.float32).reshape(-1) + err.reshape(-1)
    pad = (-n) % CHUNK
    flat_p = jnp.pad(flat, (0, pad))
    q, scale = _quantize(flat_p)
    local = _dequantize(q, scale)[:n]
    new_err = (flat - local).reshape(g.shape)
    # int8 payload summed as int32 (XLA collective carries 1B/elt wire
    # format when the operand is int8; we model the math exactly)
    # Wire format: int8 payload + f32 per-chunk scale.  Exact decoding of a
    # sum of differently-scaled int8 chunks requires scale * q summed in
    # f32 — we model it as psum(q * scale) which XLA computes on the int8
    # payloads' dequantized values; traffic accounting uses the int8+scale
    # wire size (see EXPERIMENTS.md §Perf).
    npods = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    contrib = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    ghat = (jax.lax.psum(contrib, axis_name) / npods).reshape(g.shape)
    return ghat, new_err


def compress_grads_podwise(grads, err_tree, mesh):
    """shard_map wrapper: apply EF-int8 all-reduce over the 'pod' axis to
    every gradient leaf.  No-op (identity + psum) when the mesh has no pod
    axis."""
    if mesh is None or "pod" not in mesh.axis_names:
        return grads, err_tree

    flat, treedef = jax.tree.flatten(grads)
    errs, _ = jax.tree.flatten(err_tree)

    def body(*args):
        k = len(args) // 2
        gs, es = args[:k], args[k:]
        outs = [ef_compress_leaf(g, e, "pod") for g, e in zip(gs, es)]
        return tuple(o[0] for o in outs) + tuple(o[1] for o in outs)

    from jax.experimental.shard_map import shard_map

    # each leaf keeps its existing sharding spec on non-pod axes; we mark
    # everything replicated on 'pod' inputs as split? gradients at this
    # point are *unreduced over pod* — they are per-pod partial means.
    specs = tuple(P() for _ in flat) + tuple(P() for _ in errs)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=specs,
        out_specs=specs,
        check_rep=False,
    )
    outs = fn(*flat, *errs)
    k = len(flat)
    new_g = jax.tree.unflatten(treedef, outs[:k])
    new_e = jax.tree.unflatten(treedef, outs[k:])
    return new_g, new_e


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
