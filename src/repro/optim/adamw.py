"""AdamW with decoupled weight decay + global-norm clipping + LR schedule.

Purely functional; optimizer state (m, v) is a pytree mirroring the
parameters, so it inherits each parameter's sharding (FSDP'd params =>
FSDP'd optimizer state — the ZeRO-style memory story).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 200
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(ocfg: AdamWConfig, count):
    """Linear warmup then cosine decay to min_lr_ratio."""
    count = count.astype(jnp.float32)
    warm = count / jnp.maximum(ocfg.warmup_steps, 1)
    prog = jnp.clip(
        (count - ocfg.warmup_steps) / jnp.maximum(ocfg.decay_steps - ocfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = ocfg.min_lr_ratio + (1 - ocfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return ocfg.lr * jnp.where(count < ocfg.warmup_steps, warm, cos)


def init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {
        "m": zeros(params),
        "v": zeros(params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def update(grads, state, params, ocfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, ocfg.clip_norm)
    count = state["count"] + 1
    lr = schedule(ocfg, count)
    b1, b2 = ocfg.b1, ocfg.b2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        pf = p.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / bc1
        vh = v2 / bc2
        step = mh / (jnp.sqrt(vh) + ocfg.eps) + ocfg.weight_decay * pf
        return (pf - lr * step).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
