"""Deterministic, shardable input pipeline.

Every batch is a pure function of (step, shard) — the property the
elastic coordinator relies on: restore at step s and the stream continues
with neither duplicated nor dropped samples, on any shard count.

Sources:
* ``SyntheticLM`` — seeded token streams (throughput/correctness work);
* ``GraphWalkLM`` — random walks over TGI snapshots at a step-dependent
  timepoint, tokenized as node ids: the graph plane feeding the LM plane
  (temporal graphs as a corpus — quickstart example 3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    n_shards: int = 1
    prefetch: int = 2


class SyntheticLM:
    def __init__(self, cfg: PipelineConfig, seed: int = 0):
        self.cfg = cfg
        self.seed = seed

    def shard_batch(self, step: int, shard: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per = cfg.global_batch // cfg.n_shards
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 131 + shard) % (2**31)
        )
        toks = rng.randint(0, cfg.vocab_size, size=(per, cfg.seq_len + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        parts = [self.shard_batch(step, s) for s in range(self.cfg.n_shards)]
        return {k: np.concatenate([p[k] for p in parts]) for k in parts[0]}


class GraphWalkLM:
    """Random walks on historical snapshots: the walk's timepoint advances
    with the training step, so the model sees the graph's evolution."""

    def __init__(self, cfg: PipelineConfig, tgi, seed: int = 0, n_times: int = 8):
        self.cfg = cfg
        self.tgi = tgi
        self.seed = seed
        t0, t1 = tgi._events.time_range()
        self.times = np.linspace(t0, t1, n_times).astype(np.int64)
        self._cache: Dict[int, tuple] = {}

    def _adj_at(self, t: int):
        if t not in self._cache:
            g = self.tgi.get_snapshot(int(t))
            src, dst, _ = g.edges()
            both_s = np.concatenate([src, dst])
            both_d = np.concatenate([dst, src])
            order = np.argsort(both_s, kind="stable")
            bs, bd = both_s[order], both_d[order]
            nodes = g.node_ids()
            indptr = np.searchsorted(bs, np.arange(len(g.present) + 1))
            self._cache[t] = (nodes, indptr, bd)
        return self._cache[t]

    def shard_batch(self, step: int, shard: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per = cfg.global_batch // cfg.n_shards
        rng = np.random.RandomState(
            (self.seed * 7_368_787 + step * 131 + shard) % (2**31)
        )
        L = cfg.seq_len + 1
        out = np.zeros((per, L), np.int32)
        for b in range(per):
            # fixed per-slot timepoint mixture: every batch sees the same
            # blend of graph epochs (stationary distribution for training)
            t = int(self.times[(b + shard * per) % len(self.times)])
            nodes, indptr, nbrs = self._adj_at(t)
            cur = int(nodes[rng.randint(len(nodes))]) if len(nodes) else 0
            for j in range(L):
                out[b, j] = cur % cfg.vocab_size
                lo, hi = indptr[cur], indptr[cur + 1]
                if hi > lo:
                    cur = int(nbrs[lo + rng.randint(hi - lo)])
                elif len(nodes):
                    cur = int(nodes[rng.randint(len(nodes))])  # restart
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        parts = [self.shard_batch(step, s) for s in range(self.cfg.n_shards)]
        return {k: np.concatenate([p[k] for p in parts]) for k in parts[0]}
