"""Synthetic temporal-graph event streams.

The paper's datasets (Wikipedia citation history, Friendster+synthetic
events) are not redistributable; this generator produces streams with the
two skews the paper calls out (§4.4): *temporal* skew (bursty activity)
and *topological* skew (preferential attachment).  Deterministic by seed.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.events import (
    EDGE_ADD,
    EDGE_DEL,
    EATTR_SET,
    NATTR_SET,
    NODE_ADD,
    NODE_DEL,
    EventLog,
)


def generate(
    n_events: int = 20_000,
    n_nodes_hint: int = 0,
    seed: int = 0,
    p_edge_del: float = 0.1,
    p_nattr: float = 0.15,
    p_eattr: float = 0.05,
    p_node_del: float = 0.01,
    n_attr_keys: int = 4,
    n_labels: int = 16,
    burstiness: float = 2.0,
    pa_alpha: float = 0.8,
) -> EventLog:
    """Preferential-attachment growth + deletions + attribute churn.

    burstiness > 1 concentrates events into hot periods (temporal skew);
    pa_alpha in [0,1] interpolates uniform -> preferential attachment
    (topological skew).
    """
    rng = np.random.RandomState(seed)
    n_nodes_hint = n_nodes_hint or max(n_events // 8, 16)

    t = 0
    ts, kinds, srcs, dsts, keys, vals = [], [], [], [], [], []
    alive: list = []
    alive_set = set()
    deg: dict = {}
    edges: set = set()
    edge_list: list = []
    next_node = 0

    def emit(kind, src, dst=-1, key=-1, val=-1):
        nonlocal t
        # bursty clock: hot periods advance slowly, cold ones jump
        if rng.rand() < 0.1:
            t += int(rng.exponential(burstiness * 10)) + 1
        elif rng.rand() < 0.5:
            t += 1
        ts.append(t)
        kinds.append(kind)
        srcs.append(src)
        dsts.append(dst)
        keys.append(key)
        vals.append(val)

    def add_node():
        nonlocal next_node
        nid = next_node
        next_node += 1
        alive.append(nid)
        alive_set.add(nid)
        deg[nid] = 0
        emit(NODE_ADD, nid)
        emit(NATTR_SET, nid, key=0, val=int(rng.randint(n_labels)))

    def pick_node():
        if pa_alpha > 0 and rng.rand() < pa_alpha and edge_list:
            e = edge_list[rng.randint(len(edge_list))]
            cand = e[rng.randint(2)]
            if cand in alive_set:
                return cand
        return alive[rng.randint(len(alive))]

    for _ in range(4):
        add_node()

    while len(ts) < n_events:
        r = rng.rand()
        if len(alive) < n_nodes_hint and r < 0.25:
            add_node()
            # connect the newcomer preferentially
            u = alive[-1]
            for _ in range(min(1 + rng.poisson(1.0), len(alive) - 1)):
                v = pick_node()
                if v == u:
                    continue
                a, b = min(u, v), max(u, v)
                if (a, b) not in edges:
                    edges.add((a, b))
                    edge_list.append((a, b))
                    deg[a] += 1
                    deg[b] += 1
                    emit(EDGE_ADD, a, b, val=int(rng.randint(1, 8)))
        elif r < 0.25 + p_edge_del and edges:
            i = rng.randint(len(edge_list))
            a, b = edge_list[i]
            if (a, b) in edges:
                edges.discard((a, b))
                deg[a] -= 1
                deg[b] -= 1
                emit(EDGE_DEL, a, b)
        elif r < 0.25 + p_edge_del + p_nattr and alive:
            u = pick_node()
            emit(NATTR_SET, u, key=int(rng.randint(n_attr_keys)),
                 val=int(rng.randint(n_labels)))
        elif r < 0.25 + p_edge_del + p_nattr + p_eattr and edge_list:
            i = rng.randint(len(edge_list))
            a, b = edge_list[i]
            if (a, b) in edges:
                emit(EATTR_SET, a, b, key=0, val=int(rng.randint(1, 8)))
        elif r < 0.25 + p_edge_del + p_nattr + p_eattr + p_node_del and len(alive) > 8:
            # delete an isolated-ish node (edges first)
            u = alive[rng.randint(len(alive))]
            incident = [(a, b) for (a, b) in list(edges) if a == u or b == u]
            for a, b in incident:
                edges.discard((a, b))
                deg[a] -= 1
                deg[b] -= 1
                emit(EDGE_DEL, a, b)
            alive.remove(u)
            alive_set.discard(u)
            emit(NODE_DEL, u)
        else:
            # add an edge between existing nodes
            if len(alive) >= 2:
                u, v = pick_node(), pick_node()
                if u != v:
                    a, b = min(u, v), max(u, v)
                    if (a, b) not in edges:
                        edges.add((a, b))
                        edge_list.append((a, b))
                        deg[a] += 1
                        deg[b] += 1
                        emit(EDGE_ADD, a, b, val=int(rng.randint(1, 8)))

    return EventLog.from_arrays(
        ts[:n_events], kinds[:n_events], srcs[:n_events], dsts[:n_events],
        keys[:n_events], vals[:n_events], sort=True
    )


def naive_state_at(events: EventLog, t: int, n_attrs: int = 4):
    """Oracle: full replay to time t (the Log approach, paper §2)."""
    from repro.core.snapshot import GraphState

    g = GraphState.empty(events.n_nodes, n_attrs)
    ev = events.up_to(t)
    # strict chronological replay, one timestamp at a time
    if len(ev):
        bounds = np.r_[0, np.nonzero(np.diff(ev.t))[0] + 1, len(ev)]
        for i in range(len(bounds) - 1):
            g.apply_bucket(ev.take(slice(int(bounds[i]), int(bounds[i + 1]))))
    return g
