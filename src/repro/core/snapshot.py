"""Graph state, bucket replay, and the Δ-fold used by snapshot retrieval.

``GraphState`` is the host-side ground truth used during index
construction (and by the naive oracle the property tests compare
against).  ``events_to_delta`` turns an event bucket into a partitioned
Delta under a SlotMap; ``overlay_fold`` is the Σ Δ_si + Σ Δ_ei of
Algorithm 1 — the jnp path mirrors the Pallas `delta_overlay` kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import delta as delta_mod
from repro.core.delta import SENTINEL, Delta
from repro.core.events import (
    EDGE_ADD,
    EDGE_DEL,
    EATTR_SET,
    NATTR_SET,
    NODE_ADD,
    NODE_DEL,
    EventLog,
)
from repro.core.slots import SlotMap


# ---------------------------------------------------------------------------
# Edge-key packing (one source of truth for GraphState.edge_key)
# ---------------------------------------------------------------------------

_EDGE_KEY_SHIFT = 32
_EDGE_KEY_MASK = np.int64((1 << _EDGE_KEY_SHIFT) - 1)
_MAX_NODE_ID = 1 << 31  # ids must stay below this for a collision-free pack


def pack_edge_key(src, dst) -> np.ndarray:
    """Pack an (src, dst) pair into one sortable int64 key via a 32-bit
    shift.  The old ``src * 2**31 + dst`` arithmetic pack silently
    collides once ids reach 2^31; here ids are range-checked and the
    shift keeps the halves disjoint."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    if len(src) and (int(src.min()) < 0 or int(dst.min()) < 0
                     or int(src.max()) >= _MAX_NODE_ID
                     or int(dst.max()) >= _MAX_NODE_ID):
        raise ValueError(
            f"edge endpoints must be in [0, 2^31) for int64 key packing; "
            f"got range [{int(min(src.min(), dst.min()))}, "
            f"{int(max(src.max(), dst.max()))}]")
    return (src << _EDGE_KEY_SHIFT) | dst


def unpack_edge_key(key) -> Tuple[np.ndarray, np.ndarray]:
    key = np.asarray(key, np.int64)
    return ((key >> _EDGE_KEY_SHIFT).astype(np.int32),
            (key & _EDGE_KEY_MASK).astype(np.int32))


# ---------------------------------------------------------------------------
# Host graph state (construction-time ground truth / test oracle)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GraphState:
    """Dense-by-node-id graph state. K node-attribute slots."""

    present: np.ndarray  # (N,) int8
    attrs: np.ndarray  # (N, K) int32
    edge_key: np.ndarray  # (E,) int64 sorted (pack_edge_key, canonical src<dst)
    edge_val: np.ndarray  # (E,) int32

    @classmethod
    def empty(cls, n_nodes: int, K: int) -> "GraphState":
        return cls(
            present=np.zeros(n_nodes, np.int8),
            attrs=np.full((n_nodes, K), -1, np.int32),
            edge_key=np.empty(0, np.int64),
            edge_val=np.empty(0, np.int32),
        )

    def copy(self) -> "GraphState":
        return GraphState(self.present.copy(), self.attrs.copy(),
                          self.edge_key.copy(), self.edge_val.copy())

    def nbytes(self) -> int:
        """Materialized size — the storage benchmark's working-set
        reference against FetchCost.n_bytes_decompressed."""
        return (self.present.nbytes + self.attrs.nbytes
                + self.edge_key.nbytes + self.edge_val.nbytes)

    def grow(self, n_nodes: int):
        if n_nodes > len(self.present):
            extra = n_nodes - len(self.present)
            self.present = np.r_[self.present, np.zeros(extra, np.int8)]
            self.attrs = np.concatenate(
                [self.attrs, np.full((extra, self.attrs.shape[1]), -1, np.int32)]
            )

    # ---- replay ----
    def apply_bucket(self, ev: EventLog):
        """Apply one chronological event bucket (vectorized last-wins; a
        bucket is the atomic replay unit — checkpoints sit on bucket
        boundaries, so intra-bucket ordering only needs last-wins)."""
        if not len(ev):
            return
        self.grow(ev.n_nodes)
        # node add/del: last op per node
        m = (ev.kind == NODE_ADD) | (ev.kind == NODE_DEL)
        if m.any():
            nids = ev.src[m]
            ops = (ev.kind[m] == NODE_ADD).astype(np.int8)
            # keep last per node (stable order)
            _, last_idx = np.unique(nids[::-1], return_index=True)
            last_idx = len(nids) - 1 - last_idx
            self.present[nids[last_idx]] = ops[last_idx]
            deleted = nids[last_idx][ops[last_idx] == 0]
            self.attrs[deleted] = -1
        # node attrs: last per (node, key)
        m = ev.kind == NATTR_SET
        if m.any():
            nid, key, val = ev.src[m], ev.key[m].astype(np.int64), ev.val[m]
            ck = nid.astype(np.int64) * 64 + key
            _, last_idx = np.unique(ck[::-1], return_index=True)
            last_idx = len(ck) - 1 - last_idx
            self.attrs[nid[last_idx], key[last_idx].astype(np.int32)] = val[last_idx]
        # edges: last op per (src,dst); EATTR_SET counts as presence-keeping
        m = (ev.kind == EDGE_ADD) | (ev.kind == EDGE_DEL) | (ev.kind == EATTR_SET)
        if m.any():
            src, dst = ev.src[m], ev.dst[m]
            kinds = ev.kind[m]
            vals = ev.val[m]
            key = pack_edge_key(src, dst)
            _, last_idx = np.unique(key[::-1], return_index=True)
            last_idx = np.sort(len(key) - 1 - last_idx)
            key, kinds, vals = key[last_idx], kinds[last_idx], vals[last_idx]
            add = kinds != EDGE_DEL
            # merge into sorted edge set
            self._merge_edges(key[add], vals[add], key[~add])

    def _merge_edges(self, add_keys, add_vals, del_keys):
        if len(add_keys):
            pos = np.searchsorted(self.edge_key, add_keys)
            pos_c = np.clip(pos, 0, max(len(self.edge_key) - 1, 0))
            exists = np.zeros(len(add_keys), bool)
            if len(self.edge_key):
                exists = self.edge_key[pos_c] == add_keys
            # update attrs of existing; EATTR_SET with val -1 keeps old
            upd = exists & (add_vals >= 0)
            self.edge_val[pos_c[upd]] = add_vals[upd]
            new_keys = add_keys[~exists]
            new_vals = add_vals[~exists]
            if len(new_keys):
                keys = np.concatenate([self.edge_key, new_keys])
                vals = np.concatenate([self.edge_val, new_vals])
                order = np.argsort(keys, kind="stable")
                self.edge_key, self.edge_val = keys[order], vals[order]
        if len(del_keys):
            keep = ~np.isin(self.edge_key, del_keys)
            self.edge_key = self.edge_key[keep]
            self.edge_val = self.edge_val[keep]

    # ---- views ----
    def node_ids(self) -> np.ndarray:
        return np.nonzero(self.present)[0].astype(np.int32)

    def edges(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        src, dst = unpack_edge_key(self.edge_key)
        return src, dst, self.edge_val.copy()

    def degree(self) -> np.ndarray:
        deg = np.zeros(len(self.present), np.int64)
        src, dst, _ = self.edges()
        np.add.at(deg, src, 1)
        np.add.at(deg, dst, 1)
        return deg

    def to_delta(self, smap: SlotMap, K: Optional[int] = None) -> Delta:
        """Full-state snapshot Delta (paper Ex. 4: G(t) - G(-inf))."""
        K = K or self.attrs.shape[1]
        d = Delta.empty(smap.n_parts, smap.psize, K, ecap=max(len(self.edge_key), 1))
        nids = self.node_ids()
        pid, slot, found = smap.lookup(nids)
        assert found.all(), "snapshot contains node outside slot map"
        d.valid[pid, slot] = True
        d.present[pid, slot] = 1
        d.attrs[pid, slot] = self.attrs[nids]
        src, dst, val = self.edges()
        # mirror each edge under BOTH endpoints' slots so a partition's
        # micro-delta holds every edge with >=1 endpoint in it (the
        # paper's partitioned-snapshot definition, Ex. 5); duplicates are
        # canonicalized away at materialization
        m_src = np.concatenate([src, dst])
        m_dst = np.concatenate([dst, src])
        m_val = np.concatenate([val, val])
        spid, sslot, sfound = smap.lookup(m_src)
        assert sfound.all()
        gslot = spid.astype(np.int64) * smap.psize + sslot
        order = np.lexsort((m_dst, gslot))
        d.e_src = gslot[order].astype(np.int32)
        d.e_dst = m_dst[order].astype(np.int32)
        d.e_op = np.ones(len(order), np.int8)
        d.e_val = m_val[order].astype(np.int32)
        return d


# ---------------------------------------------------------------------------
# Events -> partitioned Delta (the eventlist overlay of Algorithm 1)
# ---------------------------------------------------------------------------


def events_to_delta(ev: EventLog, smap: SlotMap, K: int,
                    base_attrs: Optional[Dict] = None) -> Delta:
    """Collapse a chronological event bucket into a Delta under `smap`.

    Note NATTR_SET on a node the bucket doesn't otherwise touch yields a
    valid slot whose `present` must reflect the node's existing state —
    the paper's events are post-state diffs; we mark present=1 (an attr
    set implies the node exists).
    """
    d = Delta.empty(smap.n_parts, smap.psize, K, ecap=max(int(((ev.kind == EDGE_ADD) | (ev.kind == EDGE_DEL) | (ev.kind == EATTR_SET)).sum()), 1))
    if not len(ev):
        return d
    # --- nodes ---
    m = (ev.kind == NODE_ADD) | (ev.kind == NODE_DEL) | (ev.kind == NATTR_SET)
    if m.any():
        nids = ev.src[m]
        kinds = ev.kind[m]
        keys = ev.key[m]
        vals = ev.val[m]
        pid, slot, found = smap.lookup(nids)
        assert found.all(), "event touches node outside timespan slot map"
        # chronological apply (vectorized last-wins per (node) for
        # presence, per (node,key) for attrs)
        pres_m = kinds != NATTR_SET
        if pres_m.any():
            n2, p2, s2 = nids[pres_m], pid[pres_m], slot[pres_m]
            ops = (kinds[pres_m] == NODE_ADD).astype(np.int8)
            _, last = np.unique(n2[::-1], return_index=True)
            last = len(n2) - 1 - last
            d.valid[p2[last], s2[last]] = True
            d.present[p2[last], s2[last]] = ops[last]
        at_m = kinds == NATTR_SET
        if at_m.any():
            n2, p2, s2 = nids[at_m], pid[at_m], slot[at_m]
            k2, v2 = keys[at_m].astype(np.int64), vals[at_m]
            ck = n2.astype(np.int64) * 64 + k2
            _, last = np.unique(ck[::-1], return_index=True)
            last = len(ck) - 1 - last
            newly = ~d.valid[p2[last], s2[last]]
            d.valid[p2[last], s2[last]] = True
            # attr-set implies existence unless an explicit later delete
            d.present[p2[last], s2[last]] = np.where(
                newly, 1, d.present[p2[last], s2[last]]
            )
            d.attrs[p2[last], s2[last], k2[last].astype(np.int32)] = v2[last]
    # --- edges ---
    m = (ev.kind == EDGE_ADD) | (ev.kind == EDGE_DEL) | (ev.kind == EATTR_SET)
    if m.any():
        src, dst, kinds, vals = ev.src[m], ev.dst[m], ev.kind[m], ev.val[m]
        key = pack_edge_key(src, dst)
        _, last = np.unique(key[::-1], return_index=True)
        last = np.sort(len(key) - 1 - last)
        src, dst, kinds, vals = src[last], dst[last], kinds[last], vals[last]
        # mirror to both endpoints (see GraphState.to_delta)
        m_src = np.concatenate([src, dst])
        m_dst = np.concatenate([dst, src])
        m_kinds = np.concatenate([kinds, kinds])
        m_vals = np.concatenate([vals, vals])
        pid, slot, found = smap.lookup(m_src)
        assert found.all()
        gslot = pid.astype(np.int64) * smap.psize + slot
        order = np.lexsort((m_dst, gslot))
        n = len(order)
        need = n
        if need > len(d.e_src):
            pad = need - len(d.e_src)
            d.e_src = np.r_[d.e_src, np.full(pad, SENTINEL, np.int32)]
            d.e_dst = np.r_[d.e_dst, np.full(pad, SENTINEL, np.int32)]
            d.e_op = np.r_[d.e_op, np.zeros(pad, np.int8)]
            d.e_val = np.r_[d.e_val, np.full(pad, -1, np.int32)]
        d.e_src[:n] = gslot[order].astype(np.int32)
        d.e_dst[:n] = m_dst[order]
        d.e_op[:n] = (m_kinds[order] != EDGE_DEL).astype(np.int8)
        d.e_val[:n] = m_vals[order]
    return d


def overlay_fold(deltas: List[Delta], ecap: Optional[int] = None,
                 use_kernel: bool = False) -> Delta:
    """Σ over an ordered delta chain (Algorithm 1's merge).  The node
    payload uses the fused overlay (Pallas kernel on TPU; numpy/jnp ref
    here); edges use the sorted last-wins merge."""
    assert deltas
    if use_kernel:
        from repro.kernels.delta_overlay import ops as ov_ops

        node_part = ov_ops.overlay(
            np.stack([d.valid for d in deltas]),
            np.stack([d.present for d in deltas]),
            np.stack([d.attrs for d in deltas]),
        )
        acc = deltas[0].copy()
        acc.valid, acc.present, acc.attrs = (np.asarray(x) for x in node_part)
        for d in deltas[1:]:
            acc.e_src, acc.e_dst, acc.e_op, acc.e_val = delta_mod._edge_sum(acc, d, ecap)
        return acc
    acc = deltas[0]
    for d in deltas[1:]:
        acc = delta_mod.delta_sum(acc, d, ecap)
    return acc


def delta_to_graph(d: Delta, smap: SlotMap) -> GraphState:
    """Materialize a reconstructed snapshot Delta back to GraphState."""
    K = d.attrs.shape[-1]
    rev = smap.reverse()  # (P, psize) -> nid
    n_nodes = int(smap.node_ids.max()) + 1 if len(smap.node_ids) else 0
    g = GraphState.empty(n_nodes, K)
    on = d.valid & (d.present == 1)
    nids = rev[on]
    g.present[nids] = 1
    g.attrs[nids] = d.attrs[on]
    ne = int((d.e_src != SENTINEL).sum())
    if ne:
        keep = d.e_op[:ne] == 1
        gslot = d.e_src[:ne][keep].astype(np.int64)
        pid = (gslot // smap.psize).astype(np.int32)
        slot = (gslot % smap.psize).astype(np.int32)
        src = rev[pid, slot]
        dst = d.e_dst[:ne][keep]
        # canonicalize mirrored copies (edges stored under both endpoints)
        lo = np.minimum(src.astype(np.int64), dst.astype(np.int64))
        hi = np.maximum(src.astype(np.int64), dst.astype(np.int64))
        key = pack_edge_key(lo, hi)
        val = d.e_val[:ne][keep]
        order = np.argsort(key, kind="stable")
        key, val = key[order], val[order]
        uniq = np.ones(len(key), bool)
        if len(key) > 1:
            uniq[1:] = key[1:] != key[:-1]
        g.edge_key = key[uniq]
        g.edge_val = val[uniq]
    return g
