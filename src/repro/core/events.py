"""Event encoding: the atomic unit of graph history (paper §3.1, Ex. 1-2).

Events are held as a structure-of-arrays (SoA) — int32/int8 columns — the
TPU-native replacement for the paper's pickled event objects.  The host
``EventLog`` is the ingest/index-construction view (numpy); query
execution converts padded slices to jnp.

Kinds:
  NODE_ADD/NODE_DEL        — src = node id
  EDGE_ADD/EDGE_DEL        — (src, dst); undirected edges are stored once
                             with src < dst and mirrored at query time
  NATTR_SET                — (src, key, val): node attribute write
  EATTR_SET                — (src, dst, key, val): edge attribute write
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterable, Optional, Tuple

import numpy as np

NODE_ADD, NODE_DEL, EDGE_ADD, EDGE_DEL, NATTR_SET, EATTR_SET = range(6)
KIND_NAMES = ("NODE_ADD", "NODE_DEL", "EDGE_ADD", "EDGE_DEL", "NATTR_SET", "EATTR_SET")

COLUMNS = ("t", "kind", "src", "dst", "key", "val")
DTYPES = dict(t=np.int64, kind=np.int8, src=np.int32, dst=np.int32,
              key=np.int16, val=np.int32)


@dataclasses.dataclass
class EventLog:
    """Chronologically sorted event columns (stable order within a t)."""

    t: np.ndarray
    kind: np.ndarray
    src: np.ndarray
    dst: np.ndarray
    key: np.ndarray
    val: np.ndarray

    # ---- construction ----
    @classmethod
    def empty(cls) -> "EventLog":
        return cls(**{c: np.empty(0, DTYPES[c]) for c in COLUMNS})

    @classmethod
    def from_arrays(cls, t, kind, src, dst=None, key=None, val=None,
                    sort: bool = True) -> "EventLog":
        n = len(t)
        mk = lambda a, c, fill: (
            np.asarray(a, DTYPES[c]) if a is not None else np.full(n, fill, DTYPES[c])
        )
        ev = cls(
            t=np.asarray(t, DTYPES["t"]),
            kind=np.asarray(kind, DTYPES["kind"]),
            src=np.asarray(src, DTYPES["src"]),
            dst=mk(dst, "dst", -1),
            key=mk(key, "key", -1),
            val=mk(val, "val", -1),
        )
        if sort:
            order = np.argsort(ev.t, kind="stable")
            ev = ev.take(order)
        return ev

    # ---- basics ----
    def __len__(self) -> int:
        return len(self.t)

    def take(self, idx) -> "EventLog":
        return EventLog(**{c: getattr(self, c)[idx] for c in COLUMNS})

    def concat(self, other: "EventLog", sort: bool = True) -> "EventLog":
        ev = EventLog(
            **{c: np.concatenate([getattr(self, c), getattr(other, c)]) for c in COLUMNS}
        )
        if sort:
            ev = ev.take(np.argsort(ev.t, kind="stable"))
        return ev

    def slice_time(self, t0: int, t1: int) -> "EventLog":
        """Events with t in (t0, t1] — the paper's eventlist scope."""
        lo = np.searchsorted(self.t, t0, side="right")
        hi = np.searchsorted(self.t, t1, side="right")
        return self.take(slice(lo, hi))

    def up_to(self, t: int) -> "EventLog":
        return self.take(slice(0, int(np.searchsorted(self.t, t, side="right"))))

    def filter_nodes(self, nids: np.ndarray) -> "EventLog":
        """Events touching any node in `nids` (as src or dst)."""
        s = np.isin(self.src, nids)
        s |= np.isin(self.dst, nids)
        return self.take(np.nonzero(s)[0])

    @property
    def n_nodes(self) -> int:
        m = -1
        if len(self.src):
            m = max(m, int(self.src.max()))
        if len(self.dst):
            m = max(m, int(self.dst.max()))
        return m + 1

    def time_range(self) -> Tuple[int, int]:
        if not len(self.t):
            return (0, 0)
        return int(self.t[0]), int(self.t[-1])

    def to_dict(self):
        return {c: getattr(self, c) for c in COLUMNS}


def normalize_edges(src, dst):
    """Undirected canonical order: src < dst."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    return lo, hi


class ChunkedEventLog:
    """Append-only event log held as a list of column segments.

    ``TGI._events`` used to be one flat ``EventLog`` extended by
    ``concat`` per ingest batch — an O(total-history) memcpy every time.
    This holds the log as segments instead: ``append`` is O(1) (the
    segment list grows; nothing is copied), and readers go through
    ``flat()`` — or the ``t`` / ``take`` / ``time_range`` conveniences —
    which concatenates lazily, at most once per read-after-append burst.
    ``TGI.compact()`` calls ``fold()`` explicitly, so steady-state reads
    between compactions are zero-copy."""

    def __init__(self, base: Optional[EventLog] = None):
        self._flat = base if base is not None else EventLog.empty()
        self._tail: list = []
        self._tail_len = 0
        # fold/append are internally locked so the background
        # maintenance thread may fold outside TGI's _mvcc lock while
        # readers capture views under it
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._flat) + self._tail_len

    def append(self, ev: EventLog) -> None:
        """O(1): queue a segment; no bytes move until the next read."""
        if not len(ev):
            return
        with self._lock:
            self._tail.append(ev)
            self._tail_len += len(ev)

    def fold(self) -> EventLog:
        """Concatenate pending segments into the flat log (idempotent)."""
        with self._lock:
            if self._tail:
                logs = [self._flat] + self._tail
                self._flat = EventLog(**{
                    c: np.concatenate([getattr(log, c) for log in logs])
                    for c in COLUMNS
                })
                self._tail = []
                self._tail_len = 0
            return self._flat

    # readers (EventLog-compatible views used by TGI/son/pipeline)
    flat = fold

    @property
    def t(self) -> np.ndarray:
        return self.fold().t

    def take(self, idx) -> EventLog:
        return self.fold().take(idx)

    def time_range(self) -> Tuple[int, int]:
        """First/last event time — segment bounds only, never folds."""
        with self._lock:
            if len(self._flat) + self._tail_len == 0:
                return (0, 0)
            first = self._flat if len(self._flat) else self._tail[0]
            last = self._tail[-1] if self._tail else self._flat
            return int(first.t[0]), int(last.t[-1])

    @property
    def n_segments(self) -> int:
        with self._lock:
            return (1 if len(self._flat) else 0) + len(self._tail)
