"""Temporal Graph Index (paper §4): build + retrieval.

Index anatomy per timespan (all stored in the DeltaStore under
``{tsid, sid, did, pid}`` keys, placement-keyed by ``(tsid, sid)``):

* ``E:<bucket>``            partitioned micro-eventlists (paper §4.3a) —
                            event columns, replicated to both endpoints'
                            shards, carrying a pid column for micro reads;
* ``S:<level>:<idx>``       the derived-partitioned-snapshot hierarchy
                            (§4.3b): leaf idx at level 0 = checkpoint
                            state diffs vs. their parent; one root per
                            span stored fully; parents are intersections
                            and are NOT stored (paper Fig. 3a);
* ``X:<bucket>``            auxiliary 1-hop replication micro-deltas
                            (§4.5, Fig. 5d) when enabled — read only by
                            neighborhood queries;
* version chains + slot maps + span table: index metadata (``META``).

Retrieval implements Algorithms 1-5.  Fetch cost accounting (deltas
fetched, bytes) is recorded per query for the Table-1 benchmarks.

The write path lives in ``repro.core.ingest``: one ``SpanBuilder``
serves batch ``build``, incremental ``update``, the streaming
``append``/``flush`` front-end (open-span reads overlay the not-yet-
sealed buffer), and ``compact`` (micro-span merging + store GC).

The read path layers caches with truthful accounting: the snapshot LRU
(whole states; hits replay logical FetchCost), the store's decoded-
block pool (columns; pool bytes reported separately from physical
decodes), and byte-grounded cost estimators (``estimate_fetch_cost``,
``explain_k_hop``) that the query planner uses for snapshot-vs-expand
and pruning decisions.

Concurrency (MVCC, see docs/api.md "Concurrency model"): readers pin
the epoch they started under via ``read_guard()`` and resolve every
lookup through an immutable :class:`ReadView`; writers and the
background maintenance thread publish layout changes under one lock
(``_mvcc``) with a single atomic swap + epoch bump; superseded store
keys are epoch-tagged and GC'd only after the last reader pinned at an
older epoch drains, so an in-flight query never sees a torn span list
or a vanished chunk.
"""
from __future__ import annotations

import collections
import concurrent.futures
import contextlib
import dataclasses
import math
import threading
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import delta as delta_mod
from repro.core import faultpoints
from repro.core import ingest as ingest_mod
from repro.core.delta import (
    FIELDS as DELTA_FIELDS,
    SENTINEL,
    Delta,
    delta_sum,
)
from repro.core.events import ChunkedEventLog, EventLog
from repro.core.slots import SlotMap
from repro.core.snapshot import (
    GraphState,
    delta_to_graph,
    events_to_delta,
    overlay_fold,
    pack_edge_key,
)
from repro.core.timespan import TimeSpan, split_timespans
from repro.core.version_chain import VersionChains
from repro.storage.kvstore import DeltaKey, DeltaStore, ReadSizes


@dataclasses.dataclass
class TGIConfig:
    n_shards: int = 4  # horizontal partitions (sid) — placement width
    parts_per_shard: int = 4  # micro-delta partitions per shard (pid)
    events_per_span: int = 4096  # timespan length (in events)
    eventlist_size: int = 256  # micro-eventlist bucket size l
    checkpoints_per_span: int = 4  # leaves of the derived hierarchy (r)
    n_attrs: int = 4  # node-attribute slots K
    partition_strategy: str = "hash"  # hash | locality
    omega: str = "union_max"  # time-collapse for locality partitioning
    replicate_1hop: bool = False  # auxiliary edge-cut replication
    pad_multiple: int = 128
    # streaming ingest: also seal a span once the buffered events cover
    # this many time units (None = cut on events_per_span alone)
    span_seal_time: Optional[int] = None

    @property
    def n_parts(self) -> int:
        return self.n_shards * self.parts_per_shard


@dataclasses.dataclass
class SpanIndex:
    span: TimeSpan
    smap: SlotMap
    checkpoint_ts: List[int]  # state times of hierarchy leaves
    bucket_bounds: List[Tuple[int, int]]  # event-index ranges per bucket


@dataclasses.dataclass
class FetchCost:
    n_deltas: int = 0
    n_bytes: int = 0  # encoded bytes physically read off storage
    sum_cardinality: int = 0
    n_bytes_decompressed: int = 0  # raw bytes physically decoded
    n_bytes_pool: int = 0  # raw bytes served from the decoded-block pool
    n_pool_hits: int = 0  # pooled columns served (never physical decodes)

    def add(self, n=1, b=0, card=0, raw=0, pool=0, pool_hits=0):
        self.n_deltas += n
        self.n_bytes += b
        self.sum_cardinality += card
        self.n_bytes_decompressed += raw
        self.n_bytes_pool += pool
        self.n_pool_hits += pool_hits

    def copy(self) -> "FetchCost":
        return dataclasses.replace(self)

    @property
    def n_bytes_raw_total(self) -> int:
        """Logical raw bytes the query touched, however they were served
        (physical decode + pool).  Invariant: identical with the pool on
        or off — the pool moves bytes between the two buckets, it never
        changes what a query logically reads."""
        return self.n_bytes_decompressed + self.n_bytes_pool


@dataclasses.dataclass(frozen=True)
class ReadView:
    """One reader's frozen view of the index, captured atomically under
    the MVCC lock when its ``read_guard()`` opened.  Every structure is
    either immutable or an owned shallow copy: published arrays are
    never mutated in place (writers rebind), so the view stays
    bit-stable for the guard's whole lifetime no matter what ingest or
    background compaction publishes meanwhile."""
    epoch: int
    spans: Tuple[SpanIndex, ...]
    span_by_tsid: Dict[int, SpanIndex]
    vc: Optional[VersionChains]
    events: EventLog  # folded flat log as of the capture
    pending: EventLog  # streaming buffer (rebound, never mutated)
    n_nodes: int


class TGI:
    """Build with ``TGI.build(events, cfg, store)``; query with
    get_snapshot / get_node_history / get_k_hop / get_node_1hop_history."""

    SNAP_CACHE_MAX = 16  # LRU entries of (t, pids, projection) snapshots

    def __init__(self, cfg: TGIConfig, store: DeltaStore):
        self.cfg = cfg
        self.store = store
        self.spans: List[SpanIndex] = []  # chronological
        self._span_by_tsid: Dict[int, SpanIndex] = {}
        self._next_tsid = 0  # monotonic — compaction rewrites under fresh ids
        self.vc: Optional[VersionChains] = None
        self.n_nodes = 0
        # chunked: ingest appends O(1) segments, reads concat lazily
        self._events = ChunkedEventLog()
        self._pending = EventLog.empty()  # streaming ingest buffer
        self._final_state = GraphState.empty(0, cfg.n_attrs)
        # MVCC: _mvcc guards every published structure (spans,
        # _span_by_tsid, vc, _events, _pending, n_nodes, read_epoch, the
        # snapshot LRU, pins, deferred GC); _ingest_lock serializes
        # writers (update/append/flush and the compaction publish step);
        # _maint_lock admits one maintenance pass at a time.  Lock order:
        # _maint_lock -> _ingest_lock -> _mvcc.
        self._mvcc = threading.RLock()
        self._ingest_lock = threading.RLock()
        self._maint_lock = threading.Lock()
        self._pinned: Dict[int, int] = {}  # epoch -> open read guards
        self._tls = threading.local()  # per-thread view + cost accounting
        self.last_cost = FetchCost()
        # reconstructed-snapshot LRU: key -> (GraphState, logical FetchCost)
        self._snap_cache: "collections.OrderedDict" = collections.OrderedDict()
        # bumped by every cache invalidation (ingest, compaction, manual):
        # the plan layer's cross-plan fetch cache keys on it, so a shared
        # operand can never outlive the index state it was fetched from
        self.read_epoch = 0
        self._mean_degree_cache: Optional[Tuple[int, float]] = None
        self.maintenance_stats = {"passes": 0, "failed_passes": 0,
                                  "gc_deferred_keys": 0}

    # ------------------------------------------------------------------
    # MVCC read guards (epoch pinning)
    # ------------------------------------------------------------------

    def _capture_view_locked(self) -> ReadView:
        # caller holds _mvcc; fold() is internally locked (the
        # maintenance thread folds outside _mvcc) and amortized O(1)
        # per capture
        return ReadView(
            epoch=self.read_epoch,
            spans=tuple(self.spans),
            span_by_tsid=dict(self._span_by_tsid),
            vc=self.vc.snapshot() if self.vc is not None else None,
            events=self._events.fold(),
            pending=self._pending,
            n_nodes=self.n_nodes,
        )

    @contextlib.contextmanager
    def read_guard(self) -> Iterator[ReadView]:
        """Pin the current epoch and yield its :class:`ReadView`.  Every
        retrieval issued inside resolves against the view, so a
        multi-call read (a batched fetch, a 1-hop history, a plan) is
        consistent to one instant even while ingest appends and
        background compaction swaps the layout.  Nested guards on the
        same thread reuse the outer view (one pin, one epoch).  Store
        keys superseded while any guard pins an older epoch are parked
        in the deferred-GC queue and deleted only after the last such
        guard exits."""
        tls = self._tls
        view = getattr(tls, "view", None)
        if view is not None:
            yield view
            return
        with self._mvcc:
            view = self._capture_view_locked()
            self._pinned[view.epoch] = self._pinned.get(view.epoch, 0) + 1
        tls.view = view
        try:
            yield view
        finally:
            tls.view = None
            with self._mvcc:
                n = self._pinned.get(view.epoch, 1) - 1
                if n <= 0:
                    self._pinned.pop(view.epoch, None)
                else:
                    self._pinned[view.epoch] = n
            self._gc_drain()

    def _tls_view(self) -> Optional[ReadView]:
        return getattr(self._tls, "view", None)

    def pinned_epochs(self) -> List[int]:
        with self._mvcc:
            return sorted(self._pinned)

    def _gc_drain(self) -> Tuple[int, int]:
        """Delete deferred keys whose tag epoch is no longer protected by
        any pinned reader.  Returns (keys deleted, bytes deleted)."""
        with self._mvcc:
            floor = min(self._pinned) if self._pinned else None
        return self.store.gc_drain(min_pinned_epoch=floor)

    # ------------------------------------------------------------------
    # Query-planner hooks (used by repro.taf.plan / repro.taf.query)
    # ------------------------------------------------------------------

    @property
    def last_cost(self) -> FetchCost:
        """Fetch cost of this *thread's* most recent retrieval — thread-
        local so concurrent queries (and the background maintenance
        pass) never clobber each other's accounting."""
        lc = getattr(self._tls, "last_cost", None)
        if lc is None:
            lc = FetchCost()
            self._tls.last_cost = lc
        return lc

    @last_cost.setter
    def last_cost(self, value: FetchCost) -> None:
        self._tls.last_cost = value

    @property
    def _cost_accum(self) -> Optional[FetchCost]:
        return getattr(self._tls, "cost_accum", None)

    @_cost_accum.setter
    def _cost_accum(self, value: Optional[FetchCost]) -> None:
        self._tls.cost_accum = value

    def _record_cost(self, n=1, b=0, card=0, raw=0, pool=0, pool_hits=0):
        self.last_cost.add(n, b, card, raw, pool, pool_hits)
        if self._cost_accum is not None:
            self._cost_accum.add(n, b, card, raw, pool, pool_hits)

    @contextlib.contextmanager
    def cost_scope(self) -> Iterator[FetchCost]:
        """Accumulate fetch cost across every retrieval issued inside the
        scope — one FetchCost per compiled query plan, even when the plan
        runs several get_* calls (each of which resets ``last_cost``).
        Thread-local: a scope only sees its own thread's retrievals."""
        prev = self._cost_accum
        acc = FetchCost()
        self._cost_accum = acc
        try:
            yield acc
        finally:
            self._cost_accum = prev
            if prev is not None:  # nested scopes roll up
                prev.add(acc.n_deltas, acc.n_bytes, acc.sum_cardinality,
                         acc.n_bytes_decompressed, acc.n_bytes_pool,
                         acc.n_pool_hits)

    def pids_for_nodes(self, node_ids: np.ndarray, t: int) -> List[int]:
        """Partition-pruning pushdown: the micro-partitions that cover
        ``node_ids`` in the timespan containing t.  A selection over a
        known node set fetches only these pids instead of all n_parts."""
        with self.read_guard() as view:
            si = self._span_index(t, view)
            pid, _, found = si.smap.lookup(np.asarray(node_ids, np.int32))
            return sorted(set(int(p) for p in pid[found]))

    def has_cached_snapshot(self, t: int, projection=None, c: int = 1) -> bool:
        """Non-destructive snapshot-LRU probe (planner hook): a warm
        *full* snapshot at t makes an unpruned fetch cheaper than a cold
        pruned one — the executor asks before committing to pruning."""
        with self._mvcc:
            return self._snap_key(int(t), None, projection, c) in self._snap_cache

    def _span_fetch_keys(self, t: int, pids: Optional[Sequence[int]] = None,
                         ) -> Tuple[List[DeltaKey], List[DeltaKey]]:
        """The store keys Algorithm 1 would touch for a snapshot at ``t``:
        ``(hierarchy path keys, eventlist keys)`` for the covering span,
        leaf, and partition subset — the cost model's key enumeration
        (shares the exact logic of ``get_snapshot``'s fetch)."""
        with self.read_guard() as view:
            if not view.spans:
                return [], []
            si = self._span_index(t, view)
            leaf = self._leaf_for(si, t)
            plist = list(range(self.cfg.n_parts)) if pids is None else list(pids)
            hier = [
                k for did in self._hierarchy_path(si, leaf)
                for k in self._delta_keys(si.span.tsid, did, plist)
            ]
            t_ck = si.checkpoint_ts[leaf]
            sids = sorted({self._sid_of_pid(int(p)) for p in plist})
            ev_keys = []
            bs = self._ev_buckets(si, t_ck, t, view)
            if bs:  # the real fetch reads the contiguous [min, max] range
                for b in range(min(bs), max(bs) + 1):
                    for sid in sids:
                        ev_keys.append(DeltaKey(si.span.tsid, sid, f"E:{b}", 0))
            return hier, ev_keys

    def estimate_fetch_cost(self, t: int,
                            pids: Optional[Sequence[int]] = None,
                            ) -> Dict[str, float]:
        """Planner estimate of one snapshot fetch at ``t``: encoded and
        raw bytes of every key the fetch would touch — real write-time
        sizes from ``store.key_sizes``, not guesses — split by component
        and discounted by the decoded-block pool's residency.  The
        ``physical_raw_bytes`` dimension is what cost-based plan
        selection compares: it is the ``FetchCost.n_bytes_decompressed``
        the fetch would actually pay, given what the pool already holds."""
        with self.read_guard():
            return self._estimate_fetch_cost_guarded(t, pids)

    def _estimate_fetch_cost_guarded(self, t, pids):
        hier, ev_keys = self._span_fetch_keys(t, pids)
        out = {"enc_bytes": 0.0, "raw_bytes": 0.0, "physical_raw_bytes": 0.0,
               "hier_raw_bytes": 0.0, "ev_raw_bytes": 0.0,
               "hier_physical_bytes": 0.0, "ev_physical_bytes": 0.0}
        for comp, keys in (("hier", hier), ("ev", ev_keys)):
            for k in keys:
                raw, enc = self.store.key_sizes.get(k, (0, 0))
                phys = raw * (1.0 - self.store.pool_residency(k))
                out["enc_bytes"] += enc
                out["raw_bytes"] += raw
                out["physical_raw_bytes"] += phys
                out[f"{comp}_raw_bytes"] += raw
                out[f"{comp}_physical_bytes"] += phys
        return out

    def _mean_degree(self) -> float:
        """Mean degree of the final state (cached per read_epoch) — the
        k-hop cost model's frontier-growth rate.  Probe, compute, and
        store all happen under the MVCC lock so the cached value can
        never pair a bumped epoch with a stale degree."""
        with self._mvcc:
            cached = self._mean_degree_cache
            if cached is not None and cached[0] == self.read_epoch:
                return cached[1]
            g = self._final_state
            n_alive = int((g.present == 1).sum())
            dbar = (2.0 * len(g.edge_key)) / max(n_alive, 1)
            self._mean_degree_cache = (self.read_epoch, dbar)
            return dbar

    def explain_k_hop(self, nid: int, t: int, k: int) -> Dict[str, float]:
        """The cost model behind ``get_k_hop(method="auto")``.

        * ``snapshot_bytes`` — physical raw bytes of a full-span fetch
          (pool-discounted ``estimate_fetch_cost``).
        * ``expand_bytes`` — hierarchy bytes scaled by the expected
          fraction of partitions a k-hop frontier touches (balls-into-
          bins over the expected frontier size under the mean degree),
          plus eventlist bytes for the covering shards (fetched once
          physically: the pool absorbs the per-hop re-reads).

        Grounded in ``FetchCost.n_bytes_decompressed`` units: both
        estimates are the raw bytes the method would physically decode,
        given current pool residency.  Ties fall back to the paper's
        ``k <= 2 -> expand`` heuristic."""
        with self.read_guard() as view:
            return self._explain_k_hop_guarded(view, t, k)

    def _explain_k_hop_guarded(self, view: ReadView, t: int, k: int):
        full = self.estimate_fetch_cost(t)
        n_parts, n_shards = self.cfg.n_parts, self.cfg.n_shards
        dbar = self._mean_degree()
        m = 1.0
        fr = 1.0
        for _ in range(k):
            fr *= max(dbar, 1e-9)
            m += fr
        m = min(m, float(max(view.n_nodes, 1)))
        # expected distinct partitions/shards hit by m uniform nodes
        part_frac = 1.0 - (1.0 - 1.0 / max(n_parts, 1)) ** m
        shard_frac = 1.0 - (1.0 - 1.0 / max(n_shards, 1)) ** m
        snapshot_bytes = full["physical_raw_bytes"]
        expand_bytes = (full["hier_physical_bytes"] * part_frac
                        + full["ev_physical_bytes"] * shard_frac)
        if expand_bytes < snapshot_bytes:
            method = "expand"
        elif expand_bytes > snapshot_bytes:
            method = "snapshot"
        else:
            method = "expand" if k <= 2 else "snapshot"
        return {
            "snapshot_bytes": snapshot_bytes,
            "expand_bytes": expand_bytes,
            "mean_degree": dbar,
            "expected_frontier": m,
            "partition_fraction": part_frac,
            "shard_fraction": shard_frac,
            "method": method,
        }

    # ------------------------------------------------------------------
    # Construction (paper §4.4 'Construction and Update')
    # ------------------------------------------------------------------

    @classmethod
    def build(cls, events: EventLog, cfg: TGIConfig, store: DeltaStore) -> "TGI":
        tgi = cls(cfg, store)
        tgi._build_from(events, GraphState.empty(events.n_nodes, cfg.n_attrs))
        return tgi

    def _alloc_tsid(self) -> int:
        """Allocate a fresh timespan id — the one writer/maintenance
        counter races on, so it hands out ids under the MVCC lock."""
        with self._mvcc:
            tsid = self._next_tsid
            self._next_tsid += 1
            return tsid

    def _build_from(self, events: EventLog, state: GraphState):
        with self._ingest_lock:
            with self._mvcc:
                self.spans = []
                self._span_by_tsid = {}
                self._next_tsid = 0
                self._events = ChunkedEventLog()
                self._pending = EventLog.empty()
                self._final_state = state
                self.n_nodes = max(events.n_nodes, len(state.present))
                z = np.empty(0, np.int32)
                self.vc = VersionChains.build(EventLog.empty(), z, z, 0)
            self._ingest_spans(events)
            with self._mvcc:
                self.vc.consolidate()  # a bulk build lands as one base CSR
                self.invalidate_caches()

    def _ingest_spans(self, new_events: EventLog,
                      pending_after: Optional[EventLog] = None) -> None:
        """Seal append-only events into spans via the shared SpanBuilder
        (one write path for build/update/flush) and extend the version
        chains incrementally — O(batch), not O(total history).

        Store writes happen first (new tsids: invisible to readers until
        published); the layout then publishes in one short ``_mvcc``
        critical section — span list, tsid map, event log, version
        chains, epoch bump, and (when sealing from the streaming buffer)
        the trimmed ``_pending`` all swap atomically, so a concurrent
        ``read_guard()`` sees each event exactly once: either still
        buffered or sealed, never both, never neither."""
        assert self._ingest_lock._is_owned()  # writers are serialized
        base = len(self._events)
        state = self._final_state
        builder = ingest_mod.SpanBuilder(self.cfg, self.store)
        spans = split_timespans(new_events, self.cfg.events_per_span)
        span_of = np.empty(len(new_events), np.int32)
        bucket_of = np.empty(len(new_events), np.int32)
        new_sis: List[SpanIndex] = []
        for sp in spans:
            sp2 = TimeSpan(self._alloc_tsid(), sp.t_start, sp.t_end,
                           base + sp.ev_lo, base + sp.ev_hi)
            ev_span = new_events.take(slice(sp.ev_lo, sp.ev_hi))
            si, b_of = builder.build_span(sp2, ev_span, state)
            span_of[sp.ev_lo:sp.ev_hi] = sp2.tsid
            bucket_of[sp.ev_lo:sp.ev_hi] = b_of
            new_sis.append(si)
        with self._mvcc:
            self.spans = self.spans + new_sis  # rebind: views keep the old list
            m = dict(self._span_by_tsid)
            m.update({si.span.tsid: si for si in new_sis})
            self._span_by_tsid = m
            # O(1) segment append — the flat view folds lazily on next read
            self._events.append(new_events)
            self.n_nodes = max(self.n_nodes, new_events.n_nodes,
                               len(state.present))
            if pending_after is not None:
                self._pending = pending_after
            if len(new_events):
                self.vc.append(new_events, span_of, bucket_of, self.n_nodes)
                # snapshots strictly before the new events are untouched
                self.invalidate_caches(t_from=int(new_events.t[0]))

    def update(self, new_events: EventLog):
        """Batch update (paper: 'accepts updates in batches of timespan
        length').  Spans for the new events are cut by the shared
        SpanBuilder on the running state — the same layout policy as
        ``build`` (locality partitioning and 1-hop replication included)
        — and the version chains extend incrementally instead of being
        re-derived from the full log."""
        assert len(new_events)
        with self._ingest_lock:
            self.flush()  # seal any streaming buffer first: global order
            # time_range() reads segment bounds only — no fold on ingest
            t_last = (self._events.time_range()[1] if len(self._events)
                      else -(2**62))
            assert new_events.t[0] >= t_last, "updates must be append-only"
            self._ingest_spans(new_events)

    # ------------------------------------------------------------------
    # Streaming ingest (buffered append + span sealing + flush)
    # ------------------------------------------------------------------

    def append(self, new_events: EventLog) -> None:
        """Streaming front-end: buffer events, cutting spans whenever the
        buffer holds ``events_per_span`` events (and/or covers
        ``cfg.span_seal_time`` time units).  Queries remain correct while
        ingest is mid-flight: reads at t past the sealed history overlay
        the buffer's live events (open-span reads); ``flush()`` seals the
        remainder into a final (possibly short) span."""
        if not len(new_events):
            return
        with self._ingest_lock:
            t_tail = self._pending.t[-1] if len(self._pending) else (
                self._events.time_range()[1] if len(self._events) else None)
            assert t_tail is None or new_events.t[0] >= t_tail, \
                "appends must be append-only"
            with self._mvcc:
                self._pending = self._pending.concat(new_events, sort=False)
                # buffered events shadow cached snapshots at t >= their start
                self.invalidate_caches(t_from=int(new_events.t[0]))
            self._seal_ready(force=False)

    def flush(self) -> None:
        """Seal every buffered event into spans."""
        with self._ingest_lock:
            self._seal_ready(force=True)

    def _seal_ready(self, force: bool) -> None:
        epb = self.cfg.events_per_span
        window = self.cfg.span_seal_time
        while True:
            n = len(self._pending)
            if n == 0:
                return
            timed_out = (window is not None and
                         int(self._pending.t[-1]) - int(self._pending.t[0])
                         >= window)
            if not force and n < epb and not timed_out:
                return
            if force and n <= epb:
                hi = n
            elif n < epb:  # timed_out: close the window [t0, t0 + window)
                hi = max(int(np.searchsorted(
                    self._pending.t,
                    int(self._pending.t[0]) + window, side="left")), 1)
            else:
                hi = epb
            if hi < n:  # span boundaries never split a timestamp
                t_edge = int(self._pending.t[hi - 1])
                hi = int(np.searchsorted(self._pending.t, t_edge, side="right"))
            # the sealed spans and the trimmed buffer publish in ONE
            # atomic step: no reader view can see the head events both
            # sealed and still pending
            self._ingest_spans(self._pending.take(slice(0, hi)),
                               pending_after=self._pending.take(slice(hi, n)))

    def _pending_floor(self, view: Optional[ReadView] = None) -> Optional[int]:
        """First buffered (unsealed) timestamp, or None when fully sealed.
        Reads at t >= this floor are open-span reads."""
        pend = view.pending if view is not None else self._pending
        return int(pend.t[0]) if len(pend) else None

    def _overlay_pending(self, g: GraphState, t: int, si: SpanIndex,
                         pids: Optional[Sequence[int]],
                         view: Optional[ReadView] = None) -> GraphState:
        """Open-span read: apply the buffered events with t' <= t on top
        of the sealed-index state.  With a pid subset, only events with an
        endpoint in the subset are applied (mirroring the sealed eventlist
        filter); events touching nodes the sealed SlotMap has never seen
        (brand-new nodes, not yet in any partition) are kept
        conservatively so histories and k-hop expansion stay complete."""
        pend = (view.pending if view is not None else self._pending).up_to(t)
        if not len(pend):
            return g
        if pids is not None:
            sel = np.asarray(pids)
            pid_s, _, found_s = si.smap.lookup(pend.src)
            keep = (found_s & np.isin(pid_s, sel)) | ~found_s
            has_dst = pend.dst >= 0
            if has_dst.any():
                pid_d, _, found_d = si.smap.lookup(pend.dst)
                keep |= has_dst & ((found_d & np.isin(pid_d, sel)) | ~found_d)
            pend = pend.take(np.nonzero(keep)[0])
        g.apply_bucket(pend)
        return g

    # ------------------------------------------------------------------
    # Compaction (micro-span merging + store GC)
    # ------------------------------------------------------------------

    def compact(self, min_run: int = 2, wait: bool = True):
        """Merge runs of adjacent micro-spans (spans shorter than
        ``events_per_span``, as accreted by small update/append batches)
        into full-size spans, on a background maintenance thread.

        The pass pins a read epoch, shadow-builds the merged spans'
        SlotMaps, eventlist buckets, and hierarchy through the shared
        SpanBuilder under fresh tsids (invisible to readers until
        published), then publishes the new layout in one atomic swap +
        epoch bump; superseded store keys are epoch-tagged in the
        deferred-GC queue and deleted only after the last reader pinned
        at an older epoch drains — queries and ingest run concurrently
        throughout and never see a torn layout or a vanished chunk.

        With ``wait=True`` (default) blocks for the pass and returns its
        :class:`CompactionStats` (re-raising any maintenance failure);
        with ``wait=False`` returns a ``concurrent.futures.Future``
        resolving to the stats.  One pass runs at a time.  A run is only
        rewritten when it actually reduces the span count (``min_run``
        adjacent micro-spans merging into fewer full spans)."""
        fut: "concurrent.futures.Future" = concurrent.futures.Future()

        def _run():
            try:
                fut.set_result(self._compact_pass(min_run))
            except BaseException as e:  # surfaced via fut.result()
                with self._mvcc:
                    self.maintenance_stats["failed_passes"] += 1
                fut.set_exception(e)

        threading.Thread(target=_run, name="tgi-maintenance",
                         daemon=True).start()
        return fut.result() if wait else fut

    def _compact_runs(self, spans: Sequence[SpanIndex],
                      min_run: int) -> List[Tuple[int, int]]:
        sizes = [s.span.ev_hi - s.span.ev_lo for s in spans]
        runs: List[Tuple[int, int]] = []
        i = 0
        while i < len(spans):
            if sizes[i] >= self.cfg.events_per_span:
                i += 1
                continue
            j = i
            while j < len(spans) and sizes[j] < self.cfg.events_per_span:
                j += 1
            total = sum(sizes[i:j])
            if (j - i >= min_run
                    and j - i > math.ceil(total / self.cfg.events_per_span)):
                runs.append((i, j))
            i = j
        return runs

    def _discard_shadow(self, shadow: Sequence[SpanIndex]) -> None:
        """Delete never-published shadow spans' store keys (crash before
        the swap): no reader can reach their fresh tsids, so a direct
        delete is safe and a retried pass starts clean."""
        for si in shadow:
            for sid in range(self.cfg.n_shards):
                for k in self.store.keys_for_placement(si.span.tsid, sid):
                    self.store.delete(k)

    def _compact_pass(self, min_run: int) -> "ingest_mod.CompactionStats":
        with self._maint_lock:
            self.flush()
            cfg = self.cfg
            bytes_w0 = self.store.stats.bytes_written
            builder = ingest_mod.SpanBuilder(cfg, self.store)
            shadow: List[SpanIndex] = []
            # pin the pass's own epoch: the shadow build (including its
            # seed-state get_snapshot calls, which nest under this
            # guard) sees one frozen layout even while ingest publishes
            with self.read_guard() as view:
                spans0 = view.spans
                stats = ingest_mod.CompactionStats(spans_before=len(spans0))
                runs = self._compact_runs(spans0, min_run)
                if not runs:
                    stats.spans_after = len(spans0)
                    stats.cost = FetchCost()
                    # still drain: a pass retried after a post-swap crash
                    # finds no runs but must finish the interrupted GC
                    d, b = self._gc_drain()
                    stats.keys_deleted += d
                    stats.bytes_deleted += b
                    with self._mvcc:
                        self.maintenance_stats["passes"] += 1
                    return stats
                built: List[Tuple[int, int, List[SpanIndex]]] = []
                try:
                    with self.cost_scope() as acc:
                        for (i, j) in runs:
                            faultpoints.fire("compact.shadow_build")
                            first, last = spans0[i], spans0[j - 1]
                            ev_lo, ev_hi = first.span.ev_lo, last.span.ev_hi
                            ev_run = view.events.take(slice(ev_lo, ev_hi))
                            # starting state = reconstructed state just
                            # before the run (earlier spans untouched)
                            if i == 0:
                                state = GraphState.empty(0, cfg.n_attrs)
                            else:
                                state = self.get_snapshot(
                                    spans0[i - 1].span.t_end)
                            replacement = []
                            for sp in split_timespans(ev_run,
                                                      cfg.events_per_span):
                                sp2 = TimeSpan(self._alloc_tsid(),
                                               sp.t_start, sp.t_end,
                                               ev_lo + sp.ev_lo,
                                               ev_lo + sp.ev_hi)
                                t_b = time.perf_counter()
                                si, _ = builder.build_span(
                                    sp2,
                                    ev_run.take(slice(sp.ev_lo, sp.ev_hi)),
                                    state)
                                replacement.append(si)
                                shadow.append(si)
                                # throttle: the shadow build is CPU-bound
                                # and invisible to readers, so its latency
                                # is free — cap the pass at a ~50% duty
                                # cycle (sleep as long as each span build
                                # took) so foreground queries keep the
                                # GIL at least half the time instead of
                                # stalling behind a whole run rewrite
                                time.sleep(
                                    min(time.perf_counter() - t_b, 0.02))
                            built.append((i, j, replacement))
                            stats.events_rewritten += ev_hi - ev_lo
                            stats.runs_merged += 1
                    faultpoints.fire("compact.pre_swap")
                except BaseException:
                    self._discard_shadow(shadow)
                    raise
            # guard released: the pass's own pin must not defer the GC it
            # is about to queue.  Enumerate superseded keys before the
            # swap (the old chunks are immutable until deleted).
            replaced = {spans0[x].span.tsid
                        for (i, j, _) in built for x in range(i, j)}
            head = {spans0[i].span.tsid: rep for (i, j, rep) in built}
            gc_keys = [
                k for tsid in sorted(replaced)
                for sid in range(cfg.n_shards)
                for k in self.store.keys_for_placement(tsid, sid)
            ]
            with self._ingest_lock:
                # _ingest_lock freezes the span list and the log (ingest
                # publishes only under it), so the heavy part of the
                # publish — splice + version-chain re-derivation over the
                # whole log — runs BEFORE touching _mvcc.  Readers only
                # ever wait on the O(1) reference swap below, never on
                # the O(n) rebuild.
                #
                # splice by tsid into the CURRENT span list: spans sealed
                # by concurrent ingest since the view was pinned stay in
                # place (the log is append-only, so they sort after every
                # rewritten run)
                new_spans: List[SpanIndex] = []
                for s in self.spans:
                    tsid = s.span.tsid
                    if tsid in head:
                        new_spans.extend(head[tsid])
                    elif tsid not in replaced:
                        new_spans.append(s)
                new_map = {s.span.tsid: s for s in new_spans}
                span_of, bucket_of = ingest_mod.span_bucket_arrays(
                    new_spans)
                new_vc = VersionChains.build(self._events.fold(),
                                             span_of, bucket_of,
                                             self.n_nodes)
                affected = [(spans0[i].span.t_start,
                             spans0[j - 1].span.t_end)
                            for (i, j, _) in built]
                with self._mvcc:
                    self.spans = new_spans
                    self._span_by_tsid = new_map
                    self.vc = new_vc
                    self.invalidate_caches(t_ranges=affected)
                    # epoch-tagged deferral: deletable once no reader
                    # pins an epoch older than the published layout's
                    self.store.delete_deferred(gc_keys, self.read_epoch)
                    self.maintenance_stats["passes"] += 1
                    self.maintenance_stats["gc_deferred_keys"] += len(gc_keys)
            faultpoints.fire("compact.post_swap")
            d, b = self._gc_drain()
            stats.keys_deleted += d
            stats.bytes_deleted += b
            stats.spans_after = len(self.spans)
            stats.bytes_written = self.store.stats.bytes_written - bytes_w0
            stats.cost = acc
            return stats

    def _bucket_of_old(self, old_spans) -> np.ndarray:
        # shim over the vectorized helper (was a per-event Python loop)
        return ingest_mod.span_bucket_arrays(old_spans)[1]

    # ---- storage helpers ----
    def _sid_of_pid(self, pid: int) -> int:
        return pid // self.cfg.parts_per_shard

    def _delta_keys(self, tsid: int, did: str,
                    pids: Sequence[int]) -> List[DeltaKey]:
        """Store keys of one delta restricted to a partition subset —
        THE key layout of the fetch path; the cost model enumerates
        through this same helper so estimates can't drift from reads."""
        return [
            DeltaKey(tsid, self._sid_of_pid(p), did,
                     p % self.cfg.parts_per_shard)
            for p in pids
        ]

    def _ev_buckets(self, si: SpanIndex, t_ck: int, t_hi: int,
                    view: Optional[ReadView] = None) -> List[int]:
        """Micro-eventlist buckets of ``si`` whose events intersect
        (t_ck, t_hi] — shared by the real fetch (``_span_events_until``)
        and the cost model (``_span_fetch_keys``)."""
        ev_t = (view.events if view is not None else self._events).t
        return [
            b for b, (lo, hi) in enumerate(si.bucket_bounds)
            if hi > lo and ev_t[lo] <= t_hi and ev_t[hi - 1] > t_ck
        ]

    # ------------------------------------------------------------------
    # Retrieval
    # ------------------------------------------------------------------

    def _span_index(self, t: int,
                    view: Optional[ReadView] = None) -> SpanIndex:
        spans = view.spans if view is not None else self.spans
        for si in reversed(spans):
            if t >= si.span.t_start:
                return si
        return spans[0]

    def _hierarchy_path(self, si: SpanIndex, leaf: int) -> List[str]:
        """did names root->leaf for a given leaf index."""
        n_leaves = len(si.checkpoint_ts)
        # reconstruct the tree shape
        names = []
        level = 0
        idx = leaf
        width = n_leaves
        while width > 1:
            names.append(f"S:{level}:{idx}")
            idx //= 2
            width = (width + 1) // 2
            level += 1
        names.append(f"S:{level}:0")
        return list(reversed(names))

    def _fetch_delta(self, tsid: int, did: str, pids: Optional[Sequence[int]],
                     si: SpanIndex, c: int = 1,
                     projection: Optional[Sequence[str]] = None) -> Delta:
        cfg = self.cfg
        pids = list(range(cfg.n_parts)) if pids is None else list(pids)
        keys = self._delta_keys(tsid, did, pids)
        fields = None
        if projection is not None and "attrs" not in projection:
            # attribute-projection pushdown: the attrs tile (the widest
            # column) is never read off storage
            fields = tuple(f for f in DELTA_FIELDS if f != "attrs")
        sizes: Dict[DeltaKey, ReadSizes] = {}
        got = self.store.multiget(keys, c=c, fields=fields, sizes=sizes)
        psize = si.smap.psize
        d = Delta.empty(cfg.n_parts, psize, cfg.n_attrs, ecap=1)
        e_parts = []
        for p, k in zip(pids, keys):
            a = got[k]
            d.valid[p] = a["valid"]
            d.present[p] = a["present"]
            if "attrs" in a:
                d.attrs[p] = a["attrs"]
            ne = int((a["e_src"] != SENTINEL).sum())
            e_parts.append((a["e_src"][:ne], a["e_dst"][:ne], a["e_op"][:ne], a["e_val"][:ne]))
            s = sizes[k]
            self._record_cost(1, s.enc, int(a["valid"].sum()) + ne, s.raw,
                              s.pool, s.pool_cols)
        if e_parts:
            d.e_src = np.concatenate([e[0] for e in e_parts])
            d.e_dst = np.concatenate([e[1] for e in e_parts])
            d.e_op = np.concatenate([e[2] for e in e_parts])
            d.e_val = np.concatenate([e[3] for e in e_parts])
            if len(d.e_src) == 0:
                d.e_src = np.full(1, SENTINEL, np.int32)
                d.e_dst = np.full(1, SENTINEL, np.int32)
                d.e_op = np.zeros(1, np.int8)
                d.e_val = np.full(1, -1, np.int32)
        return d

    def _fetch_eventlists(self, si: SpanIndex, b_lo: int, b_hi: int,
                          c: int = 1,
                          sids: Optional[Sequence[int]] = None) -> EventLog:
        """Micro-eventlists for buckets [b_lo, b_hi).  Events are
        replicated to both endpoints' shards, so a fetch restricted to
        the shards covering a partition subset still sees every event
        with >=1 endpoint there (planner shard pruning)."""
        keys = []
        for b in range(b_lo, b_hi):
            for sid in (range(self.cfg.n_shards) if sids is None else sids):
                keys.append(DeltaKey(si.span.tsid, sid, f"E:{b}", 0))
        out = EventLog.empty()
        # a bucket may have no events on a given shard -> key absent;
        # the stored pid column is for micro reads only — project it
        # away so it is seeked over, never decoded
        sizes: Dict[DeltaKey, ReadSizes] = {}
        got = self.store.multiget(keys, c=c, missing_ok=True, sizes=sizes,
                                  fields=("t", "kind", "src", "dst", "key", "val"))
        logs = []
        for k in keys:
            if k not in got:
                continue
            a = got[k]
            s = sizes[k]
            self._record_cost(1, s.enc, len(a["t"]), s.raw, s.pool, s.pool_cols)
            logs.append(a)
        if not logs:
            return out
        cat = {c2: np.concatenate([l[c2] for l in logs]) for c2 in
               ("t", "kind", "src", "dst", "key", "val")}
        ev = EventLog(**cat)
        # events were replicated across shards: dedup identical rows
        rows = np.stack([ev.t, ev.kind.astype(np.int64), ev.src.astype(np.int64),
                         ev.dst.astype(np.int64), ev.key.astype(np.int64),
                         ev.val.astype(np.int64)], 1)
        _, uniq = np.unique(rows, axis=0, return_index=True)
        ev = ev.take(np.sort(uniq))
        return ev.take(np.argsort(ev.t, kind="stable"))

    def _leaf_for(self, si: SpanIndex, t: int) -> int:
        """Nearest derived-hierarchy checkpoint at or before t."""
        return max(
            i for i, ct in enumerate(si.checkpoint_ts) if ct <= t
        ) if any(ct <= t for ct in si.checkpoint_ts) else 0

    def _span_events_until(self, si: SpanIndex, t_ck: int, t_hi: int, c: int,
                           pids: Optional[Sequence[int]],
                           view: Optional[ReadView] = None) -> EventLog:
        """Eventlists of the span covering (t_ck, t_hi], pid-filtered —
        fetched ONCE and re-filtered per timepoint by the batched path."""
        ev_buckets = self._ev_buckets(si, t_ck, t_hi, view)
        if not ev_buckets:
            return EventLog.empty()
        sids = None
        if pids is not None:
            sids = sorted({self._sid_of_pid(int(p)) for p in pids})
        ev = self._fetch_eventlists(si, min(ev_buckets), max(ev_buckets) + 1, c,
                                    sids=sids)
        ev = ev.take(np.nonzero((ev.t > t_ck) & (ev.t <= t_hi))[0])
        if pids is not None and len(ev):
            # keep events with EITHER endpoint in the fetched pids — a
            # deletion whose src lives elsewhere must still clear the
            # mirrored copy, or the edge resurrects
            pid_s, _, found_s = si.smap.lookup(ev.src)
            keep = found_s & np.isin(pid_s, np.asarray(pids))
            has_dst = ev.dst >= 0
            if has_dst.any():
                pid_d, _, found_d = si.smap.lookup(ev.dst)
                keep |= has_dst & found_d & np.isin(pid_d, np.asarray(pids))
            ev = ev.take(np.nonzero(keep)[0])
        return ev

    def _restrict_pids(self, state: Delta, si: SpanIndex,
                       pids: Sequence[int]) -> Delta:
        """Materialize only the fetched partitions: unfetched ones hold
        partial (event-only) state and must not leak into the result."""
        mask = np.zeros(self.cfg.n_parts, bool)
        mask[np.asarray(pids, np.int64)] = True  # stays valid for pids=[]
        state.valid &= mask[:, None]
        psize = si.smap.psize
        e_pid = (state.e_src.astype(np.int64) // psize)
        bad = (state.e_src != SENTINEL) & ~mask[np.clip(e_pid, 0, self.cfg.n_parts - 1)]
        keep = ~bad  # keeps trailing SENTINEL pads -> prefix invariant holds
        state.e_src = state.e_src[keep]
        state.e_dst = state.e_dst[keep]
        state.e_op = state.e_op[keep]
        state.e_val = state.e_val[keep]
        return state

    def _snap_key(self, t: int, pids, projection, c: int):
        # c is part of the key: it cannot change the result, but a
        # caller asking for a c>1 replicated read expects to exercise
        # real storage reads (failover), not a c=1 cache entry
        return (
            int(t),
            None if pids is None else tuple(int(p) for p in pids),
            None if projection is None else tuple(projection),
            int(c),
        )

    def _snap_cache_get(self, key,
                        epoch: Optional[int] = None) -> Optional[GraphState]:
        with self._mvcc:
            if epoch is not None and epoch != self.read_epoch:
                # pinned behind a published epoch: the shared LRU may
                # already hold newer-epoch entries under the same key —
                # bypass it and rebuild from the pinned view instead
                return None
            hit = self._snap_cache.get(key)
            if hit is None:
                return None
            self._snap_cache.move_to_end(key)
            g, cost = hit
        # replay the logical fetch cost: the LRU changes wall time, not
        # the planner's accounting (cost invariants stay deterministic).
        # The replay preserves the fill-time physical-vs-pool split, so
        # bytes the block pool served are never re-counted as decodes
        # (accounting parity with the fill-time read).
        self._record_cost(cost.n_deltas, cost.n_bytes, cost.sum_cardinality,
                          cost.n_bytes_decompressed, cost.n_bytes_pool,
                          cost.n_pool_hits)
        return g.copy()

    def _snap_cache_put(self, key, g: GraphState, cost: FetchCost,
                        epoch: Optional[int] = None) -> None:
        with self._mvcc:
            if epoch is not None and epoch != self.read_epoch:
                return  # built from an older pinned view: never published
            self._snap_cache[key] = (g.copy(), cost.copy())
            self._snap_cache.move_to_end(key)
            while len(self._snap_cache) > self.SNAP_CACHE_MAX:
                self._snap_cache.popitem(last=False)

    def invalidate_caches(self, t_from: Optional[int] = None,
                          t_ranges: Optional[Sequence[Tuple[int, int]]] = None,
                          drop_pool: bool = True) -> None:
        """Cache invalidation, scoped when possible.  With no arguments
        everything is dropped — the snapshot LRU AND the store's
        decoded-block pool (pass ``drop_pool=False`` to keep warm blocks,
        e.g. when benchmarking the pool itself).  ``t_from`` drops LRU
        entries at t >= t_from (append/update: snapshots strictly before
        the new events stay valid); ``t_ranges`` drops entries whose t
        falls inside any inclusive [lo, hi] range (compaction: only the
        rewritten spans' windows are touched).  Scoped invalidation
        leaves the block pool alone: stored blocks are immutable per
        tsid, and the write paths invalidate per key through
        ``DeltaStore.put``/``delete``.  Every call bumps ``read_epoch``
        (the plan-layer fetch cache keys on it).

        The epoch bump, the snapshot-LRU drop, the pool clear, and the
        ``_mean_degree`` cache reset are one atomic step under the MVCC
        lock: no concurrent reader can observe the new epoch paired with
        stale cache contents."""
        with self._mvcc:
            self.read_epoch += 1
            self._mean_degree_cache = None
            if t_from is None and t_ranges is None:
                self._snap_cache.clear()
                if drop_pool:
                    self.store.clear_pool()
                return
            stale = [
                k for k in self._snap_cache
                if (t_from is not None and k[0] >= t_from)
                or (t_ranges is not None
                    and any(lo <= k[0] <= hi for lo, hi in t_ranges))
            ]
            for k in stale:
                del self._snap_cache[k]

    def get_snapshot(self, t: int, c: int = 1, pids: Optional[Sequence[int]] = None,
                     use_kernel: bool = False,
                     projection: Optional[Sequence[str]] = None) -> GraphState:
        """Algorithm 1.  pids restricts to a partition subset (used by the
        k-hop and partition-parallel TAF fetch paths); ``projection``
        (planner hook) lists the optional payload fields to fetch —
        passing one without "attrs" skips the attribute tiles entirely
        (the returned attrs are then -1/unset).  Results go through a
        small LRU keyed on (t, pids, projection); hits skip storage but
        re-record the logical fetch cost.  Reads at t past the sealed
        history (mid-stream ``append``) overlay the ingest buffer's live
        events and bypass the LRU."""
        self.last_cost = FetchCost()
        with self.read_guard() as view:
            p0 = self._pending_floor(view)
            open_read = p0 is not None and t >= p0
            key = self._snap_key(t, pids, projection, c)
            if not open_read:
                hit = self._snap_cache_get(key, epoch=view.epoch)
                if hit is not None:
                    return hit
            with self.cost_scope() as acc:
                si = self._span_index(t, view)
                leaf = self._leaf_for(si, t)
                path = self._hierarchy_path(si, leaf)
                deltas = [self._fetch_delta(si.span.tsid, did, pids, si, c,
                                            projection)
                          for did in path]
                state = overlay_fold(deltas, use_kernel=use_kernel)
                t_ck = si.checkpoint_ts[leaf]
                ev = self._span_events_until(si, t_ck, t, c, pids, view)
                if len(ev):
                    state = overlay_fold(
                        [state, events_to_delta(ev, si.smap, self.cfg.n_attrs)],
                        use_kernel=use_kernel,
                    )
                if pids is not None:
                    state = self._restrict_pids(state, si, pids)
                g = delta_to_graph(state, si.smap)
                if open_read:
                    g = self._overlay_pending(g, t, si, pids, view)
            if not open_read:
                self._snap_cache_put(key, g, acc, epoch=view.epoch)
            return g

    def get_snapshots(self, ts: Sequence[int], c: int = 1,
                      pids: Optional[Sequence[int]] = None,
                      use_kernel: bool = False,
                      projection: Optional[Sequence[str]] = None) -> List[GraphState]:
        """Batched Algorithm 1: snapshots at every t in ``ts``, sharing
        one hierarchy-path fetch and one eventlist fetch per (span, leaf)
        group instead of re-reading them per timepoint.  With
        ``use_kernel`` the node payloads of a whole group fold in one
        time-batched ``delta_overlay`` kernel launch (per-timepoint
        validity masks select each t's eventlist layer).

        ``last_cost`` totals the whole batch.  Bit-identical to
        ``[get_snapshot(t) for t in ts]`` (property-tested)."""
        ts_list = [int(t) for t in np.asarray(ts, np.int64).ravel()]
        out: List[Optional[GraphState]] = [None] * len(ts_list)
        self.last_cost = FetchCost()
        with self.read_guard() as view:
            p0 = self._pending_floor(view)
            groups: Dict[Tuple[int, int], List[int]] = {}
            for j, t in enumerate(ts_list):
                if p0 is None or t < p0:  # open reads bypass the LRU
                    hit = self._snap_cache_get(
                        self._snap_key(t, pids, projection, c),
                        epoch=view.epoch)
                    if hit is not None:
                        out[j] = hit
                        continue
                si = self._span_index(t, view)
                groups.setdefault((si.span.tsid, self._leaf_for(si, t)),
                                  []).append(j)
            for (tsid, leaf), members in groups.items():
                si = view.span_by_tsid[tsid]
                t_ck = si.checkpoint_ts[leaf]
                t_hi = max(ts_list[j] for j in members)
                path = self._hierarchy_path(si, leaf)
                path_deltas = [
                    self._fetch_delta(tsid, did, pids, si, c, projection)
                    for did in path
                ]
                ev = self._span_events_until(si, t_ck, t_hi, c, pids, view)
                ev_deltas = []
                for j in members:
                    ev_j = ev.take(np.nonzero(ev.t <= ts_list[j])[0])
                    ev_deltas.append(
                        events_to_delta(ev_j, si.smap, self.cfg.n_attrs)
                        if len(ev_j) else None
                    )
                states = self._fold_group(path_deltas, ev_deltas, use_kernel)
                for j, state in zip(members, states):
                    if pids is not None:
                        state = self._restrict_pids(state, si, pids)
                    g = delta_to_graph(state, si.smap)
                    if p0 is not None and ts_list[j] >= p0:
                        g = self._overlay_pending(g, ts_list[j], si, pids, view)
                    out[j] = g
                # NOT inserted into the snapshot LRU: the group's fetch cost
                # is shared across members, so a per-t entry would over-
                # report the logical cost on later single-t cache hits
        return out  # type: ignore[return-value]

    def _fold_group(self, path_deltas: List[Delta],
                    ev_deltas: List[Optional[Delta]],
                    use_kernel: bool) -> List[Delta]:
        """Fold one (span, leaf) group's shared hierarchy path with each
        timepoint's eventlist delta."""
        T = len(ev_deltas)
        base = overlay_fold(path_deltas) if len(path_deltas) > 1 else path_deltas[0]
        if use_kernel and T > 1 and any(d is not None for d in ev_deltas):
            from repro.kernels.delta_overlay import ops as ov_ops

            h0 = len(path_deltas)
            layers = path_deltas + [d for d in ev_deltas if d is not None]
            tmask = np.zeros((len(layers), T), np.int8)
            tmask[:h0, :] = 1  # the shared path applies to every timepoint
            li = h0
            for j, d in enumerate(ev_deltas):
                if d is not None:
                    tmask[li, j] = 1  # each eventlist layer to its own t
                    li += 1
            v, p, a = ov_ops.overlay_batch(
                np.stack([d.valid for d in layers]),
                np.stack([d.present for d in layers]),
                np.stack([d.attrs for d in layers]),
                tmask,
            )
            v, p, a = np.asarray(v), np.asarray(p), np.asarray(a)
            states = []
            for j, d in enumerate(ev_deltas):
                st = base.copy()
                st.valid = v[..., j] != 0
                st.present = p[..., j]
                st.attrs = a[..., j]
                if d is not None:
                    st.e_src, st.e_dst, st.e_op, st.e_val = delta_mod._edge_sum(
                        base, d)
                states.append(st)
            return states
        return [
            base.copy() if d is None else delta_sum(base, d)
            for d in ev_deltas
        ]

    def get_node_history(self, nid: int, t0: int, t1: int, c: int = 1):
        """Algorithm 2: (initial state at t0, EventLog of changes (t0,t1]).
        Buffered (unsealed) events in the window ride along from memory —
        they are not yet referenced by the version chains."""
        self.last_cost = FetchCost()
        with self.read_guard() as view:
            si = self._span_index(t0, view)
            pid, slot, found = si.smap.lookup(np.asarray([nid]))
            p0 = self._pending_floor(view)
            pend_has_nid = False
            if p0 is not None and t0 >= p0:
                pend0 = view.pending.up_to(t0)
                pend_has_nid = bool(
                    ((pend0.src == nid) | (pend0.dst == nid)).any())
            init = None
            if found[0] or pend_has_nid:
                # a node only the buffer knows has no sealed partition
                # yet — fall back to the unrestricted overlay read
                snap = self.get_snapshot(
                    t0, c=c, pids=[int(pid[0])] if found[0] else None)
                if nid < len(snap.present) and snap.present[nid]:
                    init = {
                        "present": 1,
                        "attrs": snap.attrs[nid].copy(),
                        "neighbors": self._neighbors_of(snap, nid),
                    }
            ts, tsids, buckets = view.vc.get(nid, t0, t1)
            ev = EventLog.empty()
            for tsid in np.unique(tsids):
                si2 = view.span_by_tsid[int(tsid)]
                bks = np.unique(buckets[tsids == tsid])
                # events touching nid replicate to nid's shard: read it alone
                pid2, _, found2 = si2.smap.lookup(np.asarray([nid]))
                sids = [self._sid_of_pid(int(pid2[0]))] if found2[0] else None
                got = self._fetch_eventlists(si2, int(bks.min()),
                                             int(bks.max()) + 1, c, sids=sids)
                ev = ev.concat(got, sort=False)
            if p0 is not None and t1 >= p0:
                ev = ev.concat(view.pending.slice_time(t0, t1), sort=False)
            ev = ev.take(np.argsort(ev.t, kind="stable"))
            sel = (((ev.src == nid) | (ev.dst == nid))
                   & (ev.t > t0) & (ev.t <= t1))
            return init, ev.take(np.nonzero(sel)[0])

    def _neighbors_of(self, g: GraphState, nid: int) -> np.ndarray:
        src, dst, _ = g.edges()
        return np.unique(np.concatenate([dst[src == nid], src[dst == nid]]))

    def get_k_hop(self, nid: int, t: int, k: int, c: int = 1,
                  method: str = "auto") -> GraphState:
        """Algorithms 3/4.  'snapshot' filters a full snapshot; 'expand'
        fetches partitions on demand.  'auto' is cost-based: it compares
        the physical raw bytes each method would decode — real stored
        sizes discounted by decoded-block-pool residency (see
        ``explain_k_hop``) — instead of the paper's fixed k<=2 rule
        (which remains the tie-break)."""
        with self.read_guard() as view:
            if method == "auto":
                method = self.explain_k_hop(nid, t, k)["method"]
            if method == "snapshot":
                g = self.get_snapshot(t, c=c)
                return self._filter_k_hop(g, nid, k)
            # expand: fetch the node's partition, then neighbors' ones
            self.last_cost = FetchCost()
            si = self._span_index(t, view)
            frontier = np.asarray([nid], np.int32)
            fetched_pids: set = set()
            g_acc: Optional[GraphState] = None
            nodes_seen = set([int(nid)])
            for _ in range(k + 1):
                pid, _, found = si.smap.lookup(frontier)
                need = sorted(set(int(p) for p in pid[found]) - fetched_pids)
                if need:
                    g_new = self.get_snapshot(t, c=c, pids=need)
                    fetched_pids |= set(need)
                    g_acc = (g_new if g_acc is None
                             else _merge_states(g_acc, g_new))
                if g_acc is None:
                    break
                nxt = []
                src, dst, _ = g_acc.edges()
                for n in frontier:
                    nxt.append(dst[src == n])
                    nxt.append(src[dst == n])
                nxt = (np.unique(np.concatenate(nxt)) if nxt
                       else np.empty(0, np.int32))
                frontier = np.asarray(
                    [x for x in nxt if int(x) not in nodes_seen], np.int32)
                nodes_seen |= set(int(x) for x in nxt)
                if not len(frontier):
                    break
            return self._filter_k_hop(
                g_acc if g_acc is not None
                else GraphState.empty(view.n_nodes, self.cfg.n_attrs), nid, k)

    def _filter_k_hop(self, g: GraphState, nid: int, k: int) -> GraphState:
        keep = {int(nid)}
        frontier = {int(nid)}
        src, dst, _ = g.edges()
        for _ in range(k):
            nxt = set()
            for n in frontier:
                nxt |= set(dst[src == n].tolist())
                nxt |= set(src[dst == n].tolist())
            nxt -= keep
            keep |= nxt
            frontier = nxt
        out = GraphState.empty(len(g.present), g.attrs.shape[1])
        ids = np.asarray(sorted(keep), np.int64)
        ids = ids[ids < len(g.present)]
        out.present[ids] = g.present[ids]
        out.attrs[ids] = g.attrs[ids]
        m = np.isin(src, ids) & np.isin(dst, ids)
        key = pack_edge_key(src[m], dst[m])
        order = np.argsort(key)
        out.edge_key = key[order]
        out.edge_val = g.edge_val[m][order] if len(g.edge_val) else np.empty(0, np.int32)
        return out

    def get_node_1hop_history(self, nid: int, t0: int, t1: int, c: int = 1):
        """Algorithm 5: initial 1-hop state + per-neighbor change events.
        The whole multi-call retrieval runs under one read guard, so the
        center history, the hood, and every neighbor history resolve
        against the same pinned epoch."""
        with self.read_guard():
            init, ev = self.get_node_history(nid, t0, t1, c=c)
            hood = self.get_k_hop(nid, t0, 1, c=c)
            neigh_ids = hood.node_ids()
            neigh_events = {}
            for m in neigh_ids:
                if int(m) == int(nid):
                    continue
                _, ev_m = self.get_node_history(int(m), t0, t1, c=c)
                neigh_events[int(m)] = ev_m
            return {"center_init": init, "center_events": ev,
                    "hood": hood, "neighbor_events": neigh_events}

    # ---- stats ----
    def time_range(self) -> Tuple[int, int]:
        """Ingested time range, including still-buffered (pending) events."""
        with self._mvcc:
            if len(self._pending):
                t0 = (self._events.time_range()[0] if len(self._events)
                      else int(self._pending.t[0]))
                return int(t0), int(self._pending.t[-1])
            return self._events.time_range()

    def index_size_bytes(self) -> int:
        """Live encoded bytes on the store (x replication) — shrinks when
        compaction GCs superseded spans."""
        return self.store.report_snapshot()["live_bytes"]

    COMPONENT_NAMES = {"E": "eventlists", "S": "hierarchy", "X": "aux_replicas"}

    def storage_report(self) -> Dict[str, Dict]:
        """Index size broken down by component (the paper's Fig. 10
        storage analysis): raw vs. encoded bytes and blob count for the
        eventlists (``E:*``), the derived snapshot hierarchy (``S:*``),
        the auxiliary 1-hop replicas (``X:*``), and anything else stored
        under this index's DeltaStore.  ``totals`` adds the aggregate and
        the compression ratio (encoded/raw); sizes are per logical key —
        multiply by ``replication`` for on-disk bytes.

        Internally consistent mid-compaction: the component breakdown,
        the totals, and the per-node status all derive from ONE key-size
        snapshot taken under the store lock (``report_snapshot``), so a
        report sampled while the maintenance thread publishes never
        mixes pre- and post-GC views of the store."""
        snap = self.store.report_snapshot()
        by_comp = snap["size_report"]
        components: Dict[str, Dict] = {}
        raw_total = enc_total = count_total = 0
        for comp, row in sorted(by_comp.items()):
            name = self.COMPONENT_NAMES.get(comp, comp)
            components[name] = dict(row)
            raw_total += row["raw"]
            enc_total += row["encoded"]
            count_total += row["count"]
        return {
            "format": self.store.fmt,
            "replication": self.store.r,
            "components": components,
            "totals": {
                "raw": raw_total,
                "encoded": enc_total,
                "count": count_total,
                "ratio": (enc_total / raw_total) if raw_total else 1.0,
            },
            # per-node health and live-data placement — the same shape
            # whether the store is the in-process DeltaStore or a
            # RemoteDeltaStore over storage cells, so chaos tests assert
            # cluster health through one report
            "nodes": snap["node_status"],
            "gc": {"pending_keys": snap["gc_pending_keys"]},
        }


def _merge_states(a: GraphState, b: GraphState) -> GraphState:
    n = max(len(a.present), len(b.present))
    a.grow(n)
    b.grow(n)
    out = GraphState.empty(n, a.attrs.shape[1])
    on_b = b.present == 1
    out.present = np.where(on_b, b.present, a.present)
    out.attrs = np.where(on_b[:, None], b.attrs, a.attrs)
    keys = np.concatenate([a.edge_key, b.edge_key])
    vals = np.concatenate([a.edge_val, b.edge_val])
    order = np.argsort(keys, kind="stable")
    keys, vals = keys[order], vals[order]
    keep = np.ones(len(keys), bool)
    if len(keys) > 1:
        keep[1:] = keys[1:] != keys[:-1]
    out.edge_key, out.edge_val = keys[keep], vals[keep]
    return out
