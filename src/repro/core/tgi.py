"""Temporal Graph Index (paper §4): build + retrieval.

Index anatomy per timespan (all stored in the DeltaStore under
``{tsid, sid, did, pid}`` keys, placement-keyed by ``(tsid, sid)``):

* ``E:<bucket>``            partitioned micro-eventlists (paper §4.3a) —
                            event columns, replicated to both endpoints'
                            shards, carrying a pid column for micro reads;
* ``S:<level>:<idx>``       the derived-partitioned-snapshot hierarchy
                            (§4.3b): leaf idx at level 0 = checkpoint
                            state diffs vs. their parent; one root per
                            span stored fully; parents are intersections
                            and are NOT stored (paper Fig. 3a);
* ``X:<bucket>``            auxiliary 1-hop replication micro-deltas
                            (§4.5, Fig. 5d) when enabled — read only by
                            neighborhood queries;
* version chains + slot maps + span table: index metadata (``META``).

Retrieval implements Algorithms 1-5.  Fetch cost accounting (deltas
fetched, bytes) is recorded per query for the Table-1 benchmarks.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import math
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import partition as part_mod
from repro.core import delta as delta_mod
from repro.core.delta import (
    FIELDS as DELTA_FIELDS,
    SENTINEL,
    Delta,
    delta_difference,
    delta_intersection,
    delta_sum,
)
from repro.core.events import EventLog
from repro.core.slots import SlotMap
from repro.core.snapshot import (
    GraphState,
    delta_to_graph,
    events_to_delta,
    overlay_fold,
    pack_edge_key,
)
from repro.core.timespan import TimeSpan, span_for_time, split_timespans
from repro.core.version_chain import VersionChains
from repro.storage.kvstore import DeltaKey, DeltaStore


@dataclasses.dataclass
class TGIConfig:
    n_shards: int = 4  # horizontal partitions (sid) — placement width
    parts_per_shard: int = 4  # micro-delta partitions per shard (pid)
    events_per_span: int = 4096  # timespan length (in events)
    eventlist_size: int = 256  # micro-eventlist bucket size l
    checkpoints_per_span: int = 4  # leaves of the derived hierarchy (r)
    n_attrs: int = 4  # node-attribute slots K
    partition_strategy: str = "hash"  # hash | locality
    omega: str = "union_max"  # time-collapse for locality partitioning
    replicate_1hop: bool = False  # auxiliary edge-cut replication
    pad_multiple: int = 128

    @property
    def n_parts(self) -> int:
        return self.n_shards * self.parts_per_shard


@dataclasses.dataclass
class SpanIndex:
    span: TimeSpan
    smap: SlotMap
    checkpoint_ts: List[int]  # state times of hierarchy leaves
    bucket_bounds: List[Tuple[int, int]]  # event-index ranges per bucket


@dataclasses.dataclass
class FetchCost:
    n_deltas: int = 0
    n_bytes: int = 0  # encoded bytes read off storage (wire/disk bytes)
    sum_cardinality: int = 0
    n_bytes_decompressed: int = 0  # raw bytes materialized after decode

    def add(self, n=1, b=0, card=0, raw=0):
        self.n_deltas += n
        self.n_bytes += b
        self.sum_cardinality += card
        self.n_bytes_decompressed += raw


class TGI:
    """Build with ``TGI.build(events, cfg, store)``; query with
    get_snapshot / get_node_history / get_k_hop / get_node_1hop_history."""

    SNAP_CACHE_MAX = 16  # LRU entries of (t, pids, projection) snapshots

    def __init__(self, cfg: TGIConfig, store: DeltaStore):
        self.cfg = cfg
        self.store = store
        self.spans: List[SpanIndex] = []
        self.vc: Optional[VersionChains] = None
        self.n_nodes = 0
        self.last_cost = FetchCost()
        self._cost_accum: Optional[FetchCost] = None
        # reconstructed-snapshot LRU: key -> (GraphState, logical FetchCost)
        self._snap_cache: "collections.OrderedDict" = collections.OrderedDict()

    # ------------------------------------------------------------------
    # Query-planner hooks (used by repro.taf.plan / repro.taf.query)
    # ------------------------------------------------------------------

    def _record_cost(self, n=1, b=0, card=0, raw=0):
        self.last_cost.add(n, b, card, raw)
        if self._cost_accum is not None:
            self._cost_accum.add(n, b, card, raw)

    @contextlib.contextmanager
    def cost_scope(self) -> Iterator[FetchCost]:
        """Accumulate fetch cost across every retrieval issued inside the
        scope — one FetchCost per compiled query plan, even when the plan
        runs several get_* calls (each of which resets ``last_cost``)."""
        prev = self._cost_accum
        acc = FetchCost()
        self._cost_accum = acc
        try:
            yield acc
        finally:
            self._cost_accum = prev
            if prev is not None:  # nested scopes roll up
                prev.add(acc.n_deltas, acc.n_bytes, acc.sum_cardinality,
                         acc.n_bytes_decompressed)

    def pids_for_nodes(self, node_ids: np.ndarray, t: int) -> List[int]:
        """Partition-pruning pushdown: the micro-partitions that cover
        ``node_ids`` in the timespan containing t.  A selection over a
        known node set fetches only these pids instead of all n_parts."""
        si = self._span_index(t)
        pid, _, found = si.smap.lookup(np.asarray(node_ids, np.int32))
        return sorted(set(int(p) for p in pid[found]))

    # ------------------------------------------------------------------
    # Construction (paper §4.4 'Construction and Update')
    # ------------------------------------------------------------------

    @classmethod
    def build(cls, events: EventLog, cfg: TGIConfig, store: DeltaStore) -> "TGI":
        tgi = cls(cfg, store)
        tgi._build_from(events, GraphState.empty(events.n_nodes, cfg.n_attrs))
        return tgi

    def _build_from(self, events: EventLog, state: GraphState):
        cfg = self.cfg
        spans = split_timespans(events, cfg.events_per_span)
        self.n_nodes = max(events.n_nodes, len(state.present))
        span_of_event = np.zeros(len(events), np.int32)
        bucket_of_event = np.zeros(len(events), np.int32)

        for sp in spans:
            ev_span = events.take(slice(sp.ev_lo, sp.ev_hi))
            span_of_event[sp.ev_lo : sp.ev_hi] = sp.tsid
            # nodes live in this span = existing state nodes + touched
            touched = np.unique(np.concatenate([
                ev_span.src, ev_span.dst[ev_span.dst >= 0],
                state.node_ids(),
            ])) if len(ev_span) else state.node_ids()
            touched = touched[touched >= 0]
            assignment = None
            if cfg.partition_strategy == "locality" and len(ev_span):
                nids_l, assignment = part_mod.partition_timespan(
                    ev_span, cfg.n_parts, "locality", cfg.omega, seed=sp.tsid
                )
                # locality assigns only touched-by-edges; extend w/ hash
                if len(nids_l) < len(touched):
                    from repro.core.slots import hash32

                    assign_full = (hash32(touched) % np.uint32(cfg.n_parts)).astype(np.int32)
                    pos = np.searchsorted(touched, nids_l)
                    assign_full[pos] = assignment
                    assignment = assign_full
            smap = SlotMap.build(touched, cfg.n_parts, assignment, cfg.pad_multiple)

            # --- buckets + checkpoints ---
            n_ev = sp.ev_hi - sp.ev_lo
            n_buckets = max(math.ceil(n_ev / cfg.eventlist_size), 1)
            ckpt_every = max(math.ceil(n_buckets / cfg.checkpoints_per_span), 1)
            checkpoint_ts: List[int] = []
            bucket_bounds: List[Tuple[int, int]] = []
            leaves: List[Delta] = []
            leaf_graphs: List[GraphState] = []

            # leaf 0: state at span start
            checkpoint_ts.append(sp.t_start - 1)
            leaves.append(state.to_delta(smap, cfg.n_attrs))
            leaf_graphs.append(state.copy())

            for b in range(n_buckets):
                lo = sp.ev_lo + b * cfg.eventlist_size
                hi = min(sp.ev_lo + (b + 1) * cfg.eventlist_size, sp.ev_hi)
                bucket_bounds.append((lo, hi))
                bucket_of_event[lo:hi] = b
                ev_b = events.take(slice(lo, hi))
                self._store_eventlist(sp.tsid, b, ev_b, smap)
                state.apply_bucket(ev_b)
                # checkpoints only at bucket boundaries that don't split a
                # timestamp — otherwise later same-t events would be in
                # neither the checkpoint nor the (t > t_ck) replay filter
                if ((b + 1) % ckpt_every == 0 and b + 1 < n_buckets
                        and events.t[hi - 1] != events.t[hi]):
                    checkpoint_ts.append(int(events.t[hi - 1]))
                    leaves.append(state.to_delta(smap, cfg.n_attrs))
                    leaf_graphs.append(state.copy())

            self._store_hierarchy(sp.tsid, leaves, smap)
            if cfg.replicate_1hop:
                self._store_aux_replication(sp.tsid, leaf_graphs[-1], smap)
            self.spans.append(
                SpanIndex(span=sp, smap=smap, checkpoint_ts=checkpoint_ts,
                          bucket_bounds=bucket_bounds)
            )

        self.vc = VersionChains.build(events, span_of_event, bucket_of_event,
                                      self.n_nodes)
        self._final_state = state  # retained for update()
        self._events = events
        self.invalidate_caches()

    def update(self, new_events: EventLog):
        """Batch update (paper: 'accepts updates in batches of timespan
        length'): builds spans for the new events on the running state and
        merges metadata (an independent-TGI merge specialization)."""
        assert len(new_events)
        t_last = self._events.t[-1] if len(self._events) else -(2**62)
        assert new_events.t[0] >= t_last, "updates must be append-only"
        base = len(self._events)
        all_events = self._events.concat(new_events, sort=False)
        state = self._final_state
        old_spans = self.spans
        self.spans = list(old_spans)
        # rebuild only the new spans
        spans = split_timespans(new_events, self.cfg.events_per_span)
        span_of, bucket_of = [], []
        tsid0 = len(old_spans)
        cfg = self.cfg
        for sp in spans:
            sp2 = TimeSpan(tsid0 + sp.tsid, sp.t_start, sp.t_end,
                           base + sp.ev_lo, base + sp.ev_hi)
            ev_span = new_events.take(slice(sp.ev_lo, sp.ev_hi))
            touched = np.unique(np.concatenate([
                ev_span.src, ev_span.dst[ev_span.dst >= 0], state.node_ids()
            ]))
            touched = touched[touched >= 0]
            smap = SlotMap.build(touched, cfg.n_parts, None, cfg.pad_multiple)
            n_ev = sp.ev_hi - sp.ev_lo
            n_buckets = max(math.ceil(n_ev / cfg.eventlist_size), 1)
            ckpt_every = max(math.ceil(n_buckets / cfg.checkpoints_per_span), 1)
            checkpoint_ts = [sp2.t_start - 1]
            leaves = [state.to_delta(smap, cfg.n_attrs)]
            bucket_bounds = []
            for b in range(n_buckets):
                lo = sp.ev_lo + b * cfg.eventlist_size
                hi = min(sp.ev_lo + (b + 1) * cfg.eventlist_size, sp.ev_hi)
                bucket_bounds.append((base + lo, base + hi))
                ev_b = new_events.take(slice(lo, hi))
                self._store_eventlist(sp2.tsid, b, ev_b, smap)
                state.apply_bucket(ev_b)
                span_of.extend([sp2.tsid] * (hi - lo))
                bucket_of.extend([b] * (hi - lo))
                if ((b + 1) % ckpt_every == 0 and b + 1 < n_buckets
                        and new_events.t[hi - 1] != new_events.t[hi]):
                    checkpoint_ts.append(int(new_events.t[hi - 1]))
                    leaves.append(state.to_delta(smap, cfg.n_attrs))
            self._store_hierarchy(sp2.tsid, leaves, smap)
            self.spans.append(SpanIndex(sp2, smap, checkpoint_ts, bucket_bounds))
        self._events = all_events
        self.n_nodes = max(self.n_nodes, all_events.n_nodes)
        old_span_of = self.vc  # rebuild VC from scratch (append-merge)
        full_span_of = np.concatenate([
            np.repeat(
                [s.span.tsid for s in old_spans],
                [s.span.ev_hi - s.span.ev_lo for s in old_spans],
            ).astype(np.int32) if old_spans else np.empty(0, np.int32),
            np.asarray(span_of, np.int32),
        ])
        full_bucket_of = np.concatenate([
            self._bucket_of_old(old_spans),
            np.asarray(bucket_of, np.int32),
        ])
        self.vc = VersionChains.build(all_events, full_span_of, full_bucket_of,
                                      self.n_nodes)
        self.invalidate_caches()

    def _bucket_of_old(self, old_spans) -> np.ndarray:
        out = []
        for s in old_spans:
            for b, (lo, hi) in enumerate(s.bucket_bounds):
                out.extend([b] * (hi - lo))
        return np.asarray(out, np.int32)

    # ---- storage helpers ----
    def _sid_of_pid(self, pid: int) -> int:
        return pid // self.cfg.parts_per_shard

    def _store_eventlist(self, tsid: int, bucket: int, ev: EventLog, smap: SlotMap):
        """Partitioned eventlists: events replicated to both endpoints'
        shards, pid column included for micro-partition filtering."""
        if not len(ev):
            return
        pid_src, _, _ = smap.lookup(ev.src)
        pid_dst = np.full(len(ev), -1, np.int32)
        has_dst = ev.dst >= 0
        if has_dst.any():
            pid_dst[has_dst] = smap.lookup(ev.dst[has_dst])[0]
        for sid in range(self.cfg.n_shards):
            ppl = self.cfg.parts_per_shard
            in_shard = (pid_src // ppl == sid) | ((pid_dst >= 0) & (pid_dst // ppl == sid))
            idx = np.nonzero(in_shard)[0]
            if not len(idx):
                continue
            sub = ev.take(idx)
            arrays = sub.to_dict()
            arrays["pid"] = pid_src[idx] % ppl
            self.store.put(DeltaKey(tsid, sid, f"E:{bucket}", 0), arrays)

    def _delta_arrays(self, d: Delta, p: int) -> Dict[str, np.ndarray]:
        """Micro-delta = one partition slice of a Delta.  Edge runs are
        keyed by global slot, so partition p's run is a contiguous
        [p*psize, (p+1)*psize) range of the sorted e_src."""
        psize = d.valid.shape[1]
        lo = np.searchsorted(d.e_src, p * psize)
        hi = np.searchsorted(d.e_src, (p + 1) * psize)
        return {
            "valid": d.valid[p],
            "present": d.present[p],
            "attrs": d.attrs[p],
            "e_src": d.e_src[lo:hi],
            "e_dst": d.e_dst[lo:hi],
            "e_op": d.e_op[lo:hi],
            "e_val": d.e_val[lo:hi],
        }

    def _store_delta(self, tsid: int, did: str, d: Delta):
        for p in range(self.cfg.n_parts):
            sid = self._sid_of_pid(p)
            self.store.put(
                DeltaKey(tsid, sid, did, p % self.cfg.parts_per_shard),
                self._delta_arrays(d, p),
            )

    def _store_hierarchy(self, tsid: int, leaves: List[Delta], smap: SlotMap):
        """DeltaGraph-style binary intersection tree; store root + all
        parent->child differences (paper §4.3b)."""
        level = 0
        nodes = leaves
        while len(nodes) > 1:
            parents = []
            for i in range(0, len(nodes), 2):
                if i + 1 < len(nodes):
                    parent = delta_intersection(nodes[i], nodes[i + 1])
                    self._store_delta(tsid, f"S:{level}:{i}",
                                      delta_difference(nodes[i], parent))
                    self._store_delta(tsid, f"S:{level}:{i+1}",
                                      delta_difference(nodes[i + 1], parent))
                else:
                    # odd tail: node is its own parent; store an empty diff
                    # so the root->leaf path naming stays uniform
                    parent = nodes[i]
                    self._store_delta(tsid, f"S:{level}:{i}",
                                      delta_difference(nodes[i], nodes[i]))
                parents.append(parent)
            nodes = parents
            level += 1
        self._store_delta(tsid, f"S:{level}:0", nodes[0])  # root, stored fully
        self._root_level = level

    def _store_aux_replication(self, tsid: int, g: GraphState, smap: SlotMap):
        """Aux micro-deltas with 1-hop external neighbors per partition."""
        src, dst, val = g.edges()
        pid_s, _, _ = smap.lookup(src)
        pid_d, _, _ = smap.lookup(dst)
        cut = pid_s != pid_d
        for p in range(self.cfg.n_parts):
            sel = cut & ((pid_s == p) | (pid_d == p))
            if not sel.any():
                continue
            self.store.put(
                DeltaKey(tsid, self._sid_of_pid(p), "X:0", p % self.cfg.parts_per_shard),
                {"src": src[sel], "dst": dst[sel], "val": val[sel]},
            )

    # ------------------------------------------------------------------
    # Retrieval
    # ------------------------------------------------------------------

    def _span_index(self, t: int) -> SpanIndex:
        for si in reversed(self.spans):
            if t >= si.span.t_start:
                return si
        return self.spans[0]

    def _hierarchy_path(self, si: SpanIndex, leaf: int) -> List[str]:
        """did names root->leaf for a given leaf index."""
        n_leaves = len(si.checkpoint_ts)
        # reconstruct the tree shape
        names = []
        level = 0
        idx = leaf
        width = n_leaves
        while width > 1:
            names.append(f"S:{level}:{idx}")
            idx //= 2
            width = (width + 1) // 2
            level += 1
        names.append(f"S:{level}:0")
        return list(reversed(names))

    def _fetch_delta(self, tsid: int, did: str, pids: Optional[Sequence[int]],
                     si: SpanIndex, c: int = 1,
                     projection: Optional[Sequence[str]] = None) -> Delta:
        cfg = self.cfg
        pids = list(range(cfg.n_parts)) if pids is None else list(pids)
        keys = [
            DeltaKey(tsid, self._sid_of_pid(p), did, p % cfg.parts_per_shard)
            for p in pids
        ]
        fields = None
        if projection is not None and "attrs" not in projection:
            # attribute-projection pushdown: the attrs tile (the widest
            # column) is never read off storage
            fields = tuple(f for f in DELTA_FIELDS if f != "attrs")
        sizes: Dict[DeltaKey, Tuple[int, int]] = {}
        got = self.store.multiget(keys, c=c, fields=fields, sizes=sizes)
        psize = si.smap.psize
        d = Delta.empty(cfg.n_parts, psize, cfg.n_attrs, ecap=1)
        e_parts = []
        for p, k in zip(pids, keys):
            a = got[k]
            d.valid[p] = a["valid"]
            d.present[p] = a["present"]
            if "attrs" in a:
                d.attrs[p] = a["attrs"]
            ne = int((a["e_src"] != SENTINEL).sum())
            e_parts.append((a["e_src"][:ne], a["e_dst"][:ne], a["e_op"][:ne], a["e_val"][:ne]))
            enc, raw = sizes[k]
            self._record_cost(1, enc, int(a["valid"].sum()) + ne, raw)
        if e_parts:
            d.e_src = np.concatenate([e[0] for e in e_parts])
            d.e_dst = np.concatenate([e[1] for e in e_parts])
            d.e_op = np.concatenate([e[2] for e in e_parts])
            d.e_val = np.concatenate([e[3] for e in e_parts])
            if len(d.e_src) == 0:
                d.e_src = np.full(1, SENTINEL, np.int32)
                d.e_dst = np.full(1, SENTINEL, np.int32)
                d.e_op = np.zeros(1, np.int8)
                d.e_val = np.full(1, -1, np.int32)
        return d

    def _fetch_eventlists(self, si: SpanIndex, b_lo: int, b_hi: int,
                          c: int = 1,
                          sids: Optional[Sequence[int]] = None) -> EventLog:
        """Micro-eventlists for buckets [b_lo, b_hi).  Events are
        replicated to both endpoints' shards, so a fetch restricted to
        the shards covering a partition subset still sees every event
        with >=1 endpoint there (planner shard pruning)."""
        keys = []
        for b in range(b_lo, b_hi):
            for sid in (range(self.cfg.n_shards) if sids is None else sids):
                keys.append(DeltaKey(si.span.tsid, sid, f"E:{b}", 0))
        out = EventLog.empty()
        # a bucket may have no events on a given shard -> key absent;
        # the stored pid column is for micro reads only — project it
        # away so it is seeked over, never decoded
        sizes: Dict[DeltaKey, Tuple[int, int]] = {}
        got = self.store.multiget(keys, c=c, missing_ok=True, sizes=sizes,
                                  fields=("t", "kind", "src", "dst", "key", "val"))
        logs = []
        for k in keys:
            if k not in got:
                continue
            a = got[k]
            enc, raw = sizes[k]
            self._record_cost(1, enc, len(a["t"]), raw)
            logs.append(a)
        if not logs:
            return out
        cat = {c2: np.concatenate([l[c2] for l in logs]) for c2 in
               ("t", "kind", "src", "dst", "key", "val")}
        ev = EventLog(**cat)
        # events were replicated across shards: dedup identical rows
        rows = np.stack([ev.t, ev.kind.astype(np.int64), ev.src.astype(np.int64),
                         ev.dst.astype(np.int64), ev.key.astype(np.int64),
                         ev.val.astype(np.int64)], 1)
        _, uniq = np.unique(rows, axis=0, return_index=True)
        ev = ev.take(np.sort(uniq))
        return ev.take(np.argsort(ev.t, kind="stable"))

    def _leaf_for(self, si: SpanIndex, t: int) -> int:
        """Nearest derived-hierarchy checkpoint at or before t."""
        return max(
            i for i, ct in enumerate(si.checkpoint_ts) if ct <= t
        ) if any(ct <= t for ct in si.checkpoint_ts) else 0

    def _span_events_until(self, si: SpanIndex, t_ck: int, t_hi: int, c: int,
                           pids: Optional[Sequence[int]]) -> EventLog:
        """Eventlists of the span covering (t_ck, t_hi], pid-filtered —
        fetched ONCE and re-filtered per timepoint by the batched path."""
        ev_buckets = [
            b for b, (lo, hi) in enumerate(si.bucket_bounds)
            if hi > lo and self._events.t[lo] <= t_hi
            and self._events.t[hi - 1] > t_ck
        ]
        if not ev_buckets:
            return EventLog.empty()
        sids = None
        if pids is not None:
            sids = sorted({self._sid_of_pid(int(p)) for p in pids})
        ev = self._fetch_eventlists(si, min(ev_buckets), max(ev_buckets) + 1, c,
                                    sids=sids)
        ev = ev.take(np.nonzero((ev.t > t_ck) & (ev.t <= t_hi))[0])
        if pids is not None and len(ev):
            # keep events with EITHER endpoint in the fetched pids — a
            # deletion whose src lives elsewhere must still clear the
            # mirrored copy, or the edge resurrects
            pid_s, _, found_s = si.smap.lookup(ev.src)
            keep = found_s & np.isin(pid_s, np.asarray(pids))
            has_dst = ev.dst >= 0
            if has_dst.any():
                pid_d, _, found_d = si.smap.lookup(ev.dst)
                keep |= has_dst & found_d & np.isin(pid_d, np.asarray(pids))
            ev = ev.take(np.nonzero(keep)[0])
        return ev

    def _restrict_pids(self, state: Delta, si: SpanIndex,
                       pids: Sequence[int]) -> Delta:
        """Materialize only the fetched partitions: unfetched ones hold
        partial (event-only) state and must not leak into the result."""
        mask = np.zeros(self.cfg.n_parts, bool)
        mask[np.asarray(pids, np.int64)] = True  # stays valid for pids=[]
        state.valid &= mask[:, None]
        psize = si.smap.psize
        e_pid = (state.e_src.astype(np.int64) // psize)
        bad = (state.e_src != SENTINEL) & ~mask[np.clip(e_pid, 0, self.cfg.n_parts - 1)]
        keep = ~bad  # keeps trailing SENTINEL pads -> prefix invariant holds
        state.e_src = state.e_src[keep]
        state.e_dst = state.e_dst[keep]
        state.e_op = state.e_op[keep]
        state.e_val = state.e_val[keep]
        return state

    def _snap_key(self, t: int, pids, projection, c: int):
        # c is part of the key: it cannot change the result, but a
        # caller asking for a c>1 replicated read expects to exercise
        # real storage reads (failover), not a c=1 cache entry
        return (
            int(t),
            None if pids is None else tuple(int(p) for p in pids),
            None if projection is None else tuple(projection),
            int(c),
        )

    def _snap_cache_get(self, key) -> Optional[GraphState]:
        hit = self._snap_cache.get(key)
        if hit is None:
            return None
        self._snap_cache.move_to_end(key)
        g, cost = hit
        # replay the logical fetch cost: the LRU changes wall time, not
        # the planner's accounting (cost invariants stay deterministic)
        self._record_cost(cost.n_deltas, cost.n_bytes, cost.sum_cardinality,
                          cost.n_bytes_decompressed)
        return g.copy()

    def _snap_cache_put(self, key, g: GraphState, cost: FetchCost) -> None:
        self._snap_cache[key] = (
            g.copy(), FetchCost(cost.n_deltas, cost.n_bytes,
                                cost.sum_cardinality, cost.n_bytes_decompressed)
        )
        self._snap_cache.move_to_end(key)
        while len(self._snap_cache) > self.SNAP_CACHE_MAX:
            self._snap_cache.popitem(last=False)

    def invalidate_caches(self) -> None:
        self._snap_cache.clear()

    def get_snapshot(self, t: int, c: int = 1, pids: Optional[Sequence[int]] = None,
                     use_kernel: bool = False,
                     projection: Optional[Sequence[str]] = None) -> GraphState:
        """Algorithm 1.  pids restricts to a partition subset (used by the
        k-hop and partition-parallel TAF fetch paths); ``projection``
        (planner hook) lists the optional payload fields to fetch —
        passing one without "attrs" skips the attribute tiles entirely
        (the returned attrs are then -1/unset).  Results go through a
        small LRU keyed on (t, pids, projection); hits skip storage but
        re-record the logical fetch cost."""
        self.last_cost = FetchCost()
        key = self._snap_key(t, pids, projection, c)
        hit = self._snap_cache_get(key)
        if hit is not None:
            return hit
        with self.cost_scope() as acc:
            si = self._span_index(t)
            leaf = self._leaf_for(si, t)
            path = self._hierarchy_path(si, leaf)
            deltas = [self._fetch_delta(si.span.tsid, did, pids, si, c, projection)
                      for did in path]
            state = overlay_fold(deltas, use_kernel=use_kernel)
            t_ck = si.checkpoint_ts[leaf]
            ev = self._span_events_until(si, t_ck, t, c, pids)
            if len(ev):
                state = overlay_fold(
                    [state, events_to_delta(ev, si.smap, self.cfg.n_attrs)],
                    use_kernel=use_kernel,
                )
            if pids is not None:
                state = self._restrict_pids(state, si, pids)
            g = delta_to_graph(state, si.smap)
        self._snap_cache_put(key, g, acc)
        return g

    def get_snapshots(self, ts: Sequence[int], c: int = 1,
                      pids: Optional[Sequence[int]] = None,
                      use_kernel: bool = False,
                      projection: Optional[Sequence[str]] = None) -> List[GraphState]:
        """Batched Algorithm 1: snapshots at every t in ``ts``, sharing
        one hierarchy-path fetch and one eventlist fetch per (span, leaf)
        group instead of re-reading them per timepoint.  With
        ``use_kernel`` the node payloads of a whole group fold in one
        time-batched ``delta_overlay`` kernel launch (per-timepoint
        validity masks select each t's eventlist layer).

        ``last_cost`` totals the whole batch.  Bit-identical to
        ``[get_snapshot(t) for t in ts]`` (property-tested)."""
        ts_list = [int(t) for t in np.asarray(ts, np.int64).ravel()]
        out: List[Optional[GraphState]] = [None] * len(ts_list)
        self.last_cost = FetchCost()
        groups: Dict[Tuple[int, int], List[int]] = {}
        for j, t in enumerate(ts_list):
            hit = self._snap_cache_get(self._snap_key(t, pids, projection, c))
            if hit is not None:
                out[j] = hit
                continue
            si = self._span_index(t)
            groups.setdefault((si.span.tsid, self._leaf_for(si, t)), []).append(j)
        for (tsid, leaf), members in groups.items():
            si = self.spans[tsid]
            t_ck = si.checkpoint_ts[leaf]
            t_hi = max(ts_list[j] for j in members)
            path = self._hierarchy_path(si, leaf)
            path_deltas = [
                self._fetch_delta(tsid, did, pids, si, c, projection)
                for did in path
            ]
            ev = self._span_events_until(si, t_ck, t_hi, c, pids)
            ev_deltas = []
            for j in members:
                ev_j = ev.take(np.nonzero(ev.t <= ts_list[j])[0])
                ev_deltas.append(
                    events_to_delta(ev_j, si.smap, self.cfg.n_attrs)
                    if len(ev_j) else None
                )
            states = self._fold_group(path_deltas, ev_deltas, use_kernel)
            for j, state in zip(members, states):
                if pids is not None:
                    state = self._restrict_pids(state, si, pids)
                out[j] = delta_to_graph(state, si.smap)
            # NOT inserted into the snapshot LRU: the group's fetch cost
            # is shared across members, so a per-t entry would over-
            # report the logical cost on later single-t cache hits
        return out  # type: ignore[return-value]

    def _fold_group(self, path_deltas: List[Delta],
                    ev_deltas: List[Optional[Delta]],
                    use_kernel: bool) -> List[Delta]:
        """Fold one (span, leaf) group's shared hierarchy path with each
        timepoint's eventlist delta."""
        T = len(ev_deltas)
        base = overlay_fold(path_deltas) if len(path_deltas) > 1 else path_deltas[0]
        if use_kernel and T > 1 and any(d is not None for d in ev_deltas):
            from repro.kernels.delta_overlay import ops as ov_ops

            h0 = len(path_deltas)
            layers = path_deltas + [d for d in ev_deltas if d is not None]
            tmask = np.zeros((len(layers), T), np.int8)
            tmask[:h0, :] = 1  # the shared path applies to every timepoint
            li = h0
            for j, d in enumerate(ev_deltas):
                if d is not None:
                    tmask[li, j] = 1  # each eventlist layer to its own t
                    li += 1
            v, p, a = ov_ops.overlay_batch(
                np.stack([d.valid for d in layers]),
                np.stack([d.present for d in layers]),
                np.stack([d.attrs for d in layers]),
                tmask,
            )
            v, p, a = np.asarray(v), np.asarray(p), np.asarray(a)
            states = []
            for j, d in enumerate(ev_deltas):
                st = base.copy()
                st.valid = v[..., j] != 0
                st.present = p[..., j]
                st.attrs = a[..., j]
                if d is not None:
                    st.e_src, st.e_dst, st.e_op, st.e_val = delta_mod._edge_sum(
                        base, d)
                states.append(st)
            return states
        return [
            base.copy() if d is None else delta_sum(base, d)
            for d in ev_deltas
        ]

    def get_node_history(self, nid: int, t0: int, t1: int, c: int = 1):
        """Algorithm 2: (initial state at t0, EventLog of changes (t0,t1])."""
        self.last_cost = FetchCost()
        si = self._span_index(t0)
        pid, slot, found = si.smap.lookup(np.asarray([nid]))
        init = None
        if found[0]:
            snap = self.get_snapshot(t0, c=c, pids=[int(pid[0])])
            if nid < len(snap.present) and snap.present[nid]:
                init = {
                    "present": 1,
                    "attrs": snap.attrs[nid].copy(),
                    "neighbors": self._neighbors_of(snap, nid),
                }
        ts, tsids, buckets = self.vc.get(nid, t0, t1)
        ev = EventLog.empty()
        for tsid in np.unique(tsids):
            si2 = self.spans[int(tsid)]
            bks = np.unique(buckets[tsids == tsid])
            # events touching nid are replicated to nid's shard: read it alone
            pid2, _, found2 = si2.smap.lookup(np.asarray([nid]))
            sids = [self._sid_of_pid(int(pid2[0]))] if found2[0] else None
            got = self._fetch_eventlists(si2, int(bks.min()), int(bks.max()) + 1, c,
                                         sids=sids)
            ev = ev.concat(got, sort=False)
        ev = ev.take(np.argsort(ev.t, kind="stable"))
        sel = ((ev.src == nid) | (ev.dst == nid)) & (ev.t > t0) & (ev.t <= t1)
        return init, ev.take(np.nonzero(sel)[0])

    def _neighbors_of(self, g: GraphState, nid: int) -> np.ndarray:
        src, dst, _ = g.edges()
        return np.unique(np.concatenate([dst[src == nid], src[dst == nid]]))

    def get_k_hop(self, nid: int, t: int, k: int, c: int = 1,
                  method: str = "auto") -> GraphState:
        """Algorithms 3/4.  'snapshot' filters a full snapshot; 'expand'
        fetches partitions on demand (wins for k<=2, per the paper)."""
        if method == "auto":
            method = "expand" if k <= 2 else "snapshot"
        if method == "snapshot":
            g = self.get_snapshot(t, c=c)
            return self._filter_k_hop(g, nid, k)
        # expand: fetch the node's partition, then neighbors' partitions
        self.last_cost = FetchCost()
        si = self._span_index(t)
        frontier = np.asarray([nid], np.int32)
        fetched_pids: set = set()
        g_acc: Optional[GraphState] = None
        nodes_seen = set([int(nid)])
        for _ in range(k + 1):
            pid, _, found = si.smap.lookup(frontier)
            need = sorted(set(int(p) for p in pid[found]) - fetched_pids)
            if need:
                g_new = self.get_snapshot(t, c=c, pids=need)
                fetched_pids |= set(need)
                g_acc = g_new if g_acc is None else _merge_states(g_acc, g_new)
            if g_acc is None:
                break
            nxt = []
            src, dst, _ = g_acc.edges()
            for n in frontier:
                nxt.append(dst[src == n])
                nxt.append(src[dst == n])
            nxt = np.unique(np.concatenate(nxt)) if nxt else np.empty(0, np.int32)
            frontier = np.asarray([x for x in nxt if int(x) not in nodes_seen], np.int32)
            nodes_seen |= set(int(x) for x in nxt)
            if not len(frontier):
                break
        return self._filter_k_hop(g_acc if g_acc is not None else
                                  GraphState.empty(self.n_nodes, self.cfg.n_attrs), nid, k)

    def _filter_k_hop(self, g: GraphState, nid: int, k: int) -> GraphState:
        keep = {int(nid)}
        frontier = {int(nid)}
        src, dst, _ = g.edges()
        for _ in range(k):
            nxt = set()
            for n in frontier:
                nxt |= set(dst[src == n].tolist())
                nxt |= set(src[dst == n].tolist())
            nxt -= keep
            keep |= nxt
            frontier = nxt
        out = GraphState.empty(len(g.present), g.attrs.shape[1])
        ids = np.asarray(sorted(keep), np.int64)
        ids = ids[ids < len(g.present)]
        out.present[ids] = g.present[ids]
        out.attrs[ids] = g.attrs[ids]
        m = np.isin(src, ids) & np.isin(dst, ids)
        key = pack_edge_key(src[m], dst[m])
        order = np.argsort(key)
        out.edge_key = key[order]
        out.edge_val = g.edge_val[m][order] if len(g.edge_val) else np.empty(0, np.int32)
        return out

    def get_node_1hop_history(self, nid: int, t0: int, t1: int, c: int = 1):
        """Algorithm 5: initial 1-hop state + per-neighbor change events."""
        init, ev = self.get_node_history(nid, t0, t1, c=c)
        hood = self.get_k_hop(nid, t0, 1, c=c)
        neigh_ids = hood.node_ids()
        neigh_events = {}
        for m in neigh_ids:
            if int(m) == int(nid):
                continue
            _, ev_m = self.get_node_history(int(m), t0, t1, c=c)
            neigh_events[int(m)] = ev_m
        return {"center_init": init, "center_events": ev,
                "hood": hood, "neighbor_events": neigh_events}

    # ---- stats ----
    def index_size_bytes(self) -> int:
        return self.store.stats.bytes_written

    COMPONENT_NAMES = {"E": "eventlists", "S": "hierarchy", "X": "aux_replicas"}

    def storage_report(self) -> Dict[str, Dict]:
        """Index size broken down by component (the paper's Fig. 10
        storage analysis): raw vs. encoded bytes and blob count for the
        eventlists (``E:*``), the derived snapshot hierarchy (``S:*``),
        the auxiliary 1-hop replicas (``X:*``), and anything else stored
        under this index's DeltaStore.  ``totals`` adds the aggregate and
        the compression ratio (encoded/raw); sizes are per logical key —
        multiply by ``replication`` for on-disk bytes."""
        by_comp = self.store.size_report()
        components: Dict[str, Dict] = {}
        raw_total = enc_total = count_total = 0
        for comp, row in sorted(by_comp.items()):
            name = self.COMPONENT_NAMES.get(comp, comp)
            components[name] = dict(row)
            raw_total += row["raw"]
            enc_total += row["encoded"]
            count_total += row["count"]
        return {
            "format": self.store.fmt,
            "replication": self.store.r,
            "components": components,
            "totals": {
                "raw": raw_total,
                "encoded": enc_total,
                "count": count_total,
                "ratio": (enc_total / raw_total) if raw_total else 1.0,
            },
        }


def _merge_states(a: GraphState, b: GraphState) -> GraphState:
    n = max(len(a.present), len(b.present))
    a.grow(n)
    b.grow(n)
    out = GraphState.empty(n, a.attrs.shape[1])
    on_b = b.present == 1
    out.present = np.where(on_b, b.present, a.present)
    out.attrs = np.where(on_b[:, None], b.attrs, a.attrs)
    keys = np.concatenate([a.edge_key, b.edge_key])
    vals = np.concatenate([a.edge_val, b.edge_val])
    order = np.argsort(keys, kind="stable")
    keys, vals = keys[order], vals[order]
    keep = np.ones(len(keys), bool)
    if len(keys) > 1:
        keep[1:] = keys[1:] != keys[:-1]
    out.edge_key, out.edge_val = keys[keep], vals[keep]
    return out
