"""Temporal Graph Index (paper §4): build + retrieval.

Index anatomy per timespan (all stored in the DeltaStore under
``{tsid, sid, did, pid}`` keys, placement-keyed by ``(tsid, sid)``):

* ``E:<bucket>``            partitioned micro-eventlists (paper §4.3a) —
                            event columns, replicated to both endpoints'
                            shards, carrying a pid column for micro reads;
* ``S:<level>:<idx>``       the derived-partitioned-snapshot hierarchy
                            (§4.3b): leaf idx at level 0 = checkpoint
                            state diffs vs. their parent; one root per
                            span stored fully; parents are intersections
                            and are NOT stored (paper Fig. 3a);
* ``X:<bucket>``            auxiliary 1-hop replication micro-deltas
                            (§4.5, Fig. 5d) when enabled — read only by
                            neighborhood queries;
* version chains + slot maps + span table: index metadata (``META``).

Retrieval implements Algorithms 1-5.  Fetch cost accounting (deltas
fetched, bytes) is recorded per query for the Table-1 benchmarks.

The write path lives in ``repro.core.ingest``: one ``SpanBuilder``
serves batch ``build``, incremental ``update``, the streaming
``append``/``flush`` front-end (open-span reads overlay the not-yet-
sealed buffer), and ``compact`` (micro-span merging + store GC).

The read path layers caches with truthful accounting: the snapshot LRU
(whole states; hits replay logical FetchCost), the store's decoded-
block pool (columns; pool bytes reported separately from physical
decodes), and byte-grounded cost estimators (``estimate_fetch_cost``,
``explain_k_hop``) that the query planner uses for snapshot-vs-expand
and pruning decisions.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import math
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import delta as delta_mod
from repro.core import ingest as ingest_mod
from repro.core.delta import (
    FIELDS as DELTA_FIELDS,
    SENTINEL,
    Delta,
    delta_sum,
)
from repro.core.events import ChunkedEventLog, EventLog
from repro.core.slots import SlotMap
from repro.core.snapshot import (
    GraphState,
    delta_to_graph,
    events_to_delta,
    overlay_fold,
    pack_edge_key,
)
from repro.core.timespan import TimeSpan, split_timespans
from repro.core.version_chain import VersionChains
from repro.storage.kvstore import DeltaKey, DeltaStore, ReadSizes


@dataclasses.dataclass
class TGIConfig:
    n_shards: int = 4  # horizontal partitions (sid) — placement width
    parts_per_shard: int = 4  # micro-delta partitions per shard (pid)
    events_per_span: int = 4096  # timespan length (in events)
    eventlist_size: int = 256  # micro-eventlist bucket size l
    checkpoints_per_span: int = 4  # leaves of the derived hierarchy (r)
    n_attrs: int = 4  # node-attribute slots K
    partition_strategy: str = "hash"  # hash | locality
    omega: str = "union_max"  # time-collapse for locality partitioning
    replicate_1hop: bool = False  # auxiliary edge-cut replication
    pad_multiple: int = 128
    # streaming ingest: also seal a span once the buffered events cover
    # this many time units (None = cut on events_per_span alone)
    span_seal_time: Optional[int] = None

    @property
    def n_parts(self) -> int:
        return self.n_shards * self.parts_per_shard


@dataclasses.dataclass
class SpanIndex:
    span: TimeSpan
    smap: SlotMap
    checkpoint_ts: List[int]  # state times of hierarchy leaves
    bucket_bounds: List[Tuple[int, int]]  # event-index ranges per bucket


@dataclasses.dataclass
class FetchCost:
    n_deltas: int = 0
    n_bytes: int = 0  # encoded bytes physically read off storage
    sum_cardinality: int = 0
    n_bytes_decompressed: int = 0  # raw bytes physically decoded
    n_bytes_pool: int = 0  # raw bytes served from the decoded-block pool
    n_pool_hits: int = 0  # pooled columns served (never physical decodes)

    def add(self, n=1, b=0, card=0, raw=0, pool=0, pool_hits=0):
        self.n_deltas += n
        self.n_bytes += b
        self.sum_cardinality += card
        self.n_bytes_decompressed += raw
        self.n_bytes_pool += pool
        self.n_pool_hits += pool_hits

    def copy(self) -> "FetchCost":
        return dataclasses.replace(self)

    @property
    def n_bytes_raw_total(self) -> int:
        """Logical raw bytes the query touched, however they were served
        (physical decode + pool).  Invariant: identical with the pool on
        or off — the pool moves bytes between the two buckets, it never
        changes what a query logically reads."""
        return self.n_bytes_decompressed + self.n_bytes_pool


class TGI:
    """Build with ``TGI.build(events, cfg, store)``; query with
    get_snapshot / get_node_history / get_k_hop / get_node_1hop_history."""

    SNAP_CACHE_MAX = 16  # LRU entries of (t, pids, projection) snapshots

    def __init__(self, cfg: TGIConfig, store: DeltaStore):
        self.cfg = cfg
        self.store = store
        self.spans: List[SpanIndex] = []  # chronological
        self._span_by_tsid: Dict[int, SpanIndex] = {}
        self._next_tsid = 0  # monotonic — compaction rewrites under fresh ids
        self.vc: Optional[VersionChains] = None
        self.n_nodes = 0
        # chunked: ingest appends O(1) segments, reads concat lazily
        self._events = ChunkedEventLog()
        self._pending = EventLog.empty()  # streaming ingest buffer
        self._final_state = GraphState.empty(0, cfg.n_attrs)
        self.last_cost = FetchCost()
        self._cost_accum: Optional[FetchCost] = None
        # reconstructed-snapshot LRU: key -> (GraphState, logical FetchCost)
        self._snap_cache: "collections.OrderedDict" = collections.OrderedDict()
        # bumped by every cache invalidation (ingest, compaction, manual):
        # the plan layer's cross-plan fetch cache keys on it, so a shared
        # operand can never outlive the index state it was fetched from
        self.read_epoch = 0
        self._mean_degree_cache: Optional[Tuple[int, float]] = None

    # ------------------------------------------------------------------
    # Query-planner hooks (used by repro.taf.plan / repro.taf.query)
    # ------------------------------------------------------------------

    def _record_cost(self, n=1, b=0, card=0, raw=0, pool=0, pool_hits=0):
        self.last_cost.add(n, b, card, raw, pool, pool_hits)
        if self._cost_accum is not None:
            self._cost_accum.add(n, b, card, raw, pool, pool_hits)

    @contextlib.contextmanager
    def cost_scope(self) -> Iterator[FetchCost]:
        """Accumulate fetch cost across every retrieval issued inside the
        scope — one FetchCost per compiled query plan, even when the plan
        runs several get_* calls (each of which resets ``last_cost``)."""
        prev = self._cost_accum
        acc = FetchCost()
        self._cost_accum = acc
        try:
            yield acc
        finally:
            self._cost_accum = prev
            if prev is not None:  # nested scopes roll up
                prev.add(acc.n_deltas, acc.n_bytes, acc.sum_cardinality,
                         acc.n_bytes_decompressed, acc.n_bytes_pool,
                         acc.n_pool_hits)

    def pids_for_nodes(self, node_ids: np.ndarray, t: int) -> List[int]:
        """Partition-pruning pushdown: the micro-partitions that cover
        ``node_ids`` in the timespan containing t.  A selection over a
        known node set fetches only these pids instead of all n_parts."""
        si = self._span_index(t)
        pid, _, found = si.smap.lookup(np.asarray(node_ids, np.int32))
        return sorted(set(int(p) for p in pid[found]))

    def has_cached_snapshot(self, t: int, projection=None, c: int = 1) -> bool:
        """Non-destructive snapshot-LRU probe (planner hook): a warm
        *full* snapshot at t makes an unpruned fetch cheaper than a cold
        pruned one — the executor asks before committing to pruning."""
        return self._snap_key(int(t), None, projection, c) in self._snap_cache

    def _span_fetch_keys(self, t: int, pids: Optional[Sequence[int]] = None,
                         ) -> Tuple[List[DeltaKey], List[DeltaKey]]:
        """The store keys Algorithm 1 would touch for a snapshot at ``t``:
        ``(hierarchy path keys, eventlist keys)`` for the covering span,
        leaf, and partition subset — the cost model's key enumeration
        (shares the exact logic of ``get_snapshot``'s fetch)."""
        if not self.spans:
            return [], []
        si = self._span_index(t)
        leaf = self._leaf_for(si, t)
        plist = list(range(self.cfg.n_parts)) if pids is None else list(pids)
        hier = [
            k for did in self._hierarchy_path(si, leaf)
            for k in self._delta_keys(si.span.tsid, did, plist)
        ]
        t_ck = si.checkpoint_ts[leaf]
        sids = sorted({self._sid_of_pid(int(p)) for p in plist})
        ev_keys = []
        bs = self._ev_buckets(si, t_ck, t)
        if bs:  # the real fetch reads the contiguous [min, max] range
            for b in range(min(bs), max(bs) + 1):
                for sid in sids:
                    ev_keys.append(DeltaKey(si.span.tsid, sid, f"E:{b}", 0))
        return hier, ev_keys

    def estimate_fetch_cost(self, t: int,
                            pids: Optional[Sequence[int]] = None,
                            ) -> Dict[str, float]:
        """Planner estimate of one snapshot fetch at ``t``: encoded and
        raw bytes of every key the fetch would touch — real write-time
        sizes from ``store.key_sizes``, not guesses — split by component
        and discounted by the decoded-block pool's residency.  The
        ``physical_raw_bytes`` dimension is what cost-based plan
        selection compares: it is the ``FetchCost.n_bytes_decompressed``
        the fetch would actually pay, given what the pool already holds."""
        hier, ev_keys = self._span_fetch_keys(t, pids)
        out = {"enc_bytes": 0.0, "raw_bytes": 0.0, "physical_raw_bytes": 0.0,
               "hier_raw_bytes": 0.0, "ev_raw_bytes": 0.0,
               "hier_physical_bytes": 0.0, "ev_physical_bytes": 0.0}
        for comp, keys in (("hier", hier), ("ev", ev_keys)):
            for k in keys:
                raw, enc = self.store.key_sizes.get(k, (0, 0))
                phys = raw * (1.0 - self.store.pool_residency(k))
                out["enc_bytes"] += enc
                out["raw_bytes"] += raw
                out["physical_raw_bytes"] += phys
                out[f"{comp}_raw_bytes"] += raw
                out[f"{comp}_physical_bytes"] += phys
        return out

    def _mean_degree(self) -> float:
        """Mean degree of the final state (cached per read_epoch) — the
        k-hop cost model's frontier-growth rate."""
        cached = self._mean_degree_cache
        if cached is not None and cached[0] == self.read_epoch:
            return cached[1]
        g = self._final_state
        n_alive = int((g.present == 1).sum())
        dbar = (2.0 * len(g.edge_key)) / max(n_alive, 1)
        self._mean_degree_cache = (self.read_epoch, dbar)
        return dbar

    def explain_k_hop(self, nid: int, t: int, k: int) -> Dict[str, float]:
        """The cost model behind ``get_k_hop(method="auto")``.

        * ``snapshot_bytes`` — physical raw bytes of a full-span fetch
          (pool-discounted ``estimate_fetch_cost``).
        * ``expand_bytes`` — hierarchy bytes scaled by the expected
          fraction of partitions a k-hop frontier touches (balls-into-
          bins over the expected frontier size under the mean degree),
          plus eventlist bytes for the covering shards (fetched once
          physically: the pool absorbs the per-hop re-reads).

        Grounded in ``FetchCost.n_bytes_decompressed`` units: both
        estimates are the raw bytes the method would physically decode,
        given current pool residency.  Ties fall back to the paper's
        ``k <= 2 -> expand`` heuristic."""
        full = self.estimate_fetch_cost(t)
        n_parts, n_shards = self.cfg.n_parts, self.cfg.n_shards
        dbar = self._mean_degree()
        m = 1.0
        fr = 1.0
        for _ in range(k):
            fr *= max(dbar, 1e-9)
            m += fr
        m = min(m, float(max(self.n_nodes, 1)))
        # expected distinct partitions/shards hit by m uniform nodes
        part_frac = 1.0 - (1.0 - 1.0 / max(n_parts, 1)) ** m
        shard_frac = 1.0 - (1.0 - 1.0 / max(n_shards, 1)) ** m
        snapshot_bytes = full["physical_raw_bytes"]
        expand_bytes = (full["hier_physical_bytes"] * part_frac
                        + full["ev_physical_bytes"] * shard_frac)
        if expand_bytes < snapshot_bytes:
            method = "expand"
        elif expand_bytes > snapshot_bytes:
            method = "snapshot"
        else:
            method = "expand" if k <= 2 else "snapshot"
        return {
            "snapshot_bytes": snapshot_bytes,
            "expand_bytes": expand_bytes,
            "mean_degree": dbar,
            "expected_frontier": m,
            "partition_fraction": part_frac,
            "shard_fraction": shard_frac,
            "method": method,
        }

    # ------------------------------------------------------------------
    # Construction (paper §4.4 'Construction and Update')
    # ------------------------------------------------------------------

    @classmethod
    def build(cls, events: EventLog, cfg: TGIConfig, store: DeltaStore) -> "TGI":
        tgi = cls(cfg, store)
        tgi._build_from(events, GraphState.empty(events.n_nodes, cfg.n_attrs))
        return tgi

    def _build_from(self, events: EventLog, state: GraphState):
        self.spans = []
        self._span_by_tsid = {}
        self._next_tsid = 0
        self._events = ChunkedEventLog()
        self._pending = EventLog.empty()
        self._final_state = state
        self.n_nodes = max(events.n_nodes, len(state.present))
        z = np.empty(0, np.int32)
        self.vc = VersionChains.build(EventLog.empty(), z, z, 0)
        self._ingest_spans(events)
        self.vc.consolidate()  # a bulk build lands as one base CSR
        self.invalidate_caches()

    def _ingest_spans(self, new_events: EventLog) -> None:
        """Seal append-only events into spans via the shared SpanBuilder
        (one write path for build/update/flush) and extend the version
        chains incrementally — O(batch), not O(total history)."""
        base = len(self._events)
        state = self._final_state
        builder = ingest_mod.SpanBuilder(self.cfg, self.store)
        spans = split_timespans(new_events, self.cfg.events_per_span)
        span_of = np.empty(len(new_events), np.int32)
        bucket_of = np.empty(len(new_events), np.int32)
        for sp in spans:
            sp2 = TimeSpan(self._next_tsid, sp.t_start, sp.t_end,
                           base + sp.ev_lo, base + sp.ev_hi)
            self._next_tsid += 1
            ev_span = new_events.take(slice(sp.ev_lo, sp.ev_hi))
            si, b_of = builder.build_span(sp2, ev_span, state)
            span_of[sp.ev_lo:sp.ev_hi] = sp2.tsid
            bucket_of[sp.ev_lo:sp.ev_hi] = b_of
            self.spans.append(si)
            self._span_by_tsid[sp2.tsid] = si
        # O(1) segment append — the flat view folds lazily on next read
        self._events.append(new_events)
        self.n_nodes = max(self.n_nodes, new_events.n_nodes, len(state.present))
        if len(new_events):
            self.vc.append(new_events, span_of, bucket_of, self.n_nodes)
            # snapshots strictly before the new events are untouched
            self.invalidate_caches(t_from=int(new_events.t[0]))

    def update(self, new_events: EventLog):
        """Batch update (paper: 'accepts updates in batches of timespan
        length').  Spans for the new events are cut by the shared
        SpanBuilder on the running state — the same layout policy as
        ``build`` (locality partitioning and 1-hop replication included)
        — and the version chains extend incrementally instead of being
        re-derived from the full log."""
        assert len(new_events)
        self.flush()  # seal any streaming buffer first: global order
        # time_range() reads segment bounds only — no fold on the ingest path
        t_last = self._events.time_range()[1] if len(self._events) else -(2**62)
        assert new_events.t[0] >= t_last, "updates must be append-only"
        self._ingest_spans(new_events)

    # ------------------------------------------------------------------
    # Streaming ingest (buffered append + span sealing + flush)
    # ------------------------------------------------------------------

    def append(self, new_events: EventLog) -> None:
        """Streaming front-end: buffer events, cutting spans whenever the
        buffer holds ``events_per_span`` events (and/or covers
        ``cfg.span_seal_time`` time units).  Queries remain correct while
        ingest is mid-flight: reads at t past the sealed history overlay
        the buffer's live events (open-span reads); ``flush()`` seals the
        remainder into a final (possibly short) span."""
        if not len(new_events):
            return
        t_tail = self._pending.t[-1] if len(self._pending) else (
            self._events.time_range()[1] if len(self._events) else None)
        assert t_tail is None or new_events.t[0] >= t_tail, \
            "appends must be append-only"
        self._pending = self._pending.concat(new_events, sort=False)
        # buffered events shadow cached snapshots at t >= their start
        self.invalidate_caches(t_from=int(new_events.t[0]))
        self._seal_ready(force=False)

    def flush(self) -> None:
        """Seal every buffered event into spans."""
        self._seal_ready(force=True)

    def _seal_ready(self, force: bool) -> None:
        epb = self.cfg.events_per_span
        window = self.cfg.span_seal_time
        while True:
            n = len(self._pending)
            if n == 0:
                return
            timed_out = (window is not None and
                         int(self._pending.t[-1]) - int(self._pending.t[0])
                         >= window)
            if not force and n < epb and not timed_out:
                return
            if force and n <= epb:
                hi = n
            elif n < epb:  # timed_out: close the window [t0, t0 + window)
                hi = max(int(np.searchsorted(
                    self._pending.t,
                    int(self._pending.t[0]) + window, side="left")), 1)
            else:
                hi = epb
            if hi < n:  # span boundaries never split a timestamp
                t_edge = int(self._pending.t[hi - 1])
                hi = int(np.searchsorted(self._pending.t, t_edge, side="right"))
            self._ingest_spans(self._pending.take(slice(0, hi)))
            self._pending = self._pending.take(slice(hi, n))

    def _pending_floor(self) -> Optional[int]:
        """First buffered (unsealed) timestamp, or None when fully sealed.
        Reads at t >= this floor are open-span reads."""
        return int(self._pending.t[0]) if len(self._pending) else None

    def _overlay_pending(self, g: GraphState, t: int, si: SpanIndex,
                         pids: Optional[Sequence[int]]) -> GraphState:
        """Open-span read: apply the buffered events with t' <= t on top
        of the sealed-index state.  With a pid subset, only events with an
        endpoint in the subset are applied (mirroring the sealed eventlist
        filter); events touching nodes the sealed SlotMap has never seen
        (brand-new nodes, not yet in any partition) are kept
        conservatively so histories and k-hop expansion stay complete."""
        pend = self._pending.up_to(t)
        if not len(pend):
            return g
        if pids is not None:
            sel = np.asarray(pids)
            pid_s, _, found_s = si.smap.lookup(pend.src)
            keep = (found_s & np.isin(pid_s, sel)) | ~found_s
            has_dst = pend.dst >= 0
            if has_dst.any():
                pid_d, _, found_d = si.smap.lookup(pend.dst)
                keep |= has_dst & ((found_d & np.isin(pid_d, sel)) | ~found_d)
            pend = pend.take(np.nonzero(keep)[0])
        g.apply_bucket(pend)
        return g

    # ------------------------------------------------------------------
    # Compaction (micro-span merging + store GC)
    # ------------------------------------------------------------------

    def compact(self, min_run: int = 2) -> "ingest_mod.CompactionStats":
        """Merge runs of adjacent micro-spans (spans shorter than
        ``events_per_span``, as accreted by small update/append batches)
        into full-size spans: re-derives the merged spans' SlotMaps,
        eventlist buckets, and hierarchy through the shared SpanBuilder,
        rewrites them under fresh tsids, deletes the superseded store
        keys (GC — ``storage_report`` shrinks), and re-derives the
        version chains against the new layout (which also consolidates
        any appended segments).  Snapshot-cache invalidation is scoped to
        the affected spans' time ranges; cached snapshots outside them
        survive.  A run is only rewritten when it actually reduces the
        span count (``min_run`` adjacent micro-spans merging into fewer
        full spans)."""
        self.flush()
        self._events.fold()  # chunked log: segments collapse at compaction
        cfg = self.cfg
        stats = ingest_mod.CompactionStats(spans_before=len(self.spans))
        sizes = [s.span.ev_hi - s.span.ev_lo for s in self.spans]
        runs: List[Tuple[int, int]] = []
        i = 0
        while i < len(self.spans):
            if sizes[i] >= cfg.events_per_span:
                i += 1
                continue
            j = i
            while j < len(self.spans) and sizes[j] < cfg.events_per_span:
                j += 1
            total = sum(sizes[i:j])
            if (j - i >= min_run
                    and j - i > math.ceil(total / cfg.events_per_span)):
                runs.append((i, j))
            i = j
        if not runs:
            stats.spans_after = len(self.spans)
            stats.cost = FetchCost()
            return stats
        bytes_w0 = self.store.stats.bytes_written
        bytes_d0 = self.store.stats.bytes_deleted
        builder = ingest_mod.SpanBuilder(cfg, self.store)
        with self.cost_scope() as acc:
            new_layout = list(self.spans)
            affected: List[Tuple[int, int]] = []
            # reversed: splice positions of earlier runs stay valid
            for (i, j) in reversed(runs):
                first, last = self.spans[i], self.spans[j - 1]
                ev_lo, ev_hi = first.span.ev_lo, last.span.ev_hi
                affected.append((first.span.t_start, last.span.t_end))
                ev_run = self._events.take(slice(ev_lo, ev_hi))
                # starting state = reconstructed state just before the run
                # (spans before it are untouched by this pass)
                if i == 0:
                    state = GraphState.empty(0, cfg.n_attrs)
                else:
                    state = self.get_snapshot(self.spans[i - 1].span.t_end)
                replacement = []
                for sp in split_timespans(ev_run, cfg.events_per_span):
                    sp2 = TimeSpan(self._next_tsid, sp.t_start, sp.t_end,
                                   ev_lo + sp.ev_lo, ev_lo + sp.ev_hi)
                    self._next_tsid += 1
                    si, _ = builder.build_span(
                        sp2, ev_run.take(slice(sp.ev_lo, sp.ev_hi)), state)
                    replacement.append(si)
                for old in self.spans[i:j]:  # GC superseded store keys
                    for sid in range(cfg.n_shards):
                        for k in self.store.keys_for_placement(
                                old.span.tsid, sid):
                            if self.store.delete(k):
                                stats.keys_deleted += 1
                stats.events_rewritten += ev_hi - ev_lo
                stats.runs_merged += 1
                new_layout[i:j] = replacement
            self.spans = new_layout
            self._span_by_tsid = {s.span.tsid: s for s in self.spans}
            # re-derive version chains against the new layout (vectorized
            # bounds arithmetic; the log itself is unchanged)
            span_of, bucket_of = ingest_mod.span_bucket_arrays(self.spans)
            self.vc = VersionChains.build(self._events.fold(), span_of,
                                          bucket_of, self.n_nodes)
            self.invalidate_caches(t_ranges=affected)
        stats.spans_after = len(self.spans)
        stats.bytes_deleted = self.store.stats.bytes_deleted - bytes_d0
        stats.bytes_written = self.store.stats.bytes_written - bytes_w0
        stats.cost = acc
        return stats

    def _bucket_of_old(self, old_spans) -> np.ndarray:
        # shim over the vectorized helper (was a per-event Python loop)
        return ingest_mod.span_bucket_arrays(old_spans)[1]

    # ---- storage helpers ----
    def _sid_of_pid(self, pid: int) -> int:
        return pid // self.cfg.parts_per_shard

    def _delta_keys(self, tsid: int, did: str,
                    pids: Sequence[int]) -> List[DeltaKey]:
        """Store keys of one delta restricted to a partition subset —
        THE key layout of the fetch path; the cost model enumerates
        through this same helper so estimates can't drift from reads."""
        return [
            DeltaKey(tsid, self._sid_of_pid(p), did,
                     p % self.cfg.parts_per_shard)
            for p in pids
        ]

    def _ev_buckets(self, si: SpanIndex, t_ck: int, t_hi: int) -> List[int]:
        """Micro-eventlist buckets of ``si`` whose events intersect
        (t_ck, t_hi] — shared by the real fetch (``_span_events_until``)
        and the cost model (``_span_fetch_keys``)."""
        return [
            b for b, (lo, hi) in enumerate(si.bucket_bounds)
            if hi > lo and self._events.t[lo] <= t_hi
            and self._events.t[hi - 1] > t_ck
        ]

    # ------------------------------------------------------------------
    # Retrieval
    # ------------------------------------------------------------------

    def _span_index(self, t: int) -> SpanIndex:
        for si in reversed(self.spans):
            if t >= si.span.t_start:
                return si
        return self.spans[0]

    def _hierarchy_path(self, si: SpanIndex, leaf: int) -> List[str]:
        """did names root->leaf for a given leaf index."""
        n_leaves = len(si.checkpoint_ts)
        # reconstruct the tree shape
        names = []
        level = 0
        idx = leaf
        width = n_leaves
        while width > 1:
            names.append(f"S:{level}:{idx}")
            idx //= 2
            width = (width + 1) // 2
            level += 1
        names.append(f"S:{level}:0")
        return list(reversed(names))

    def _fetch_delta(self, tsid: int, did: str, pids: Optional[Sequence[int]],
                     si: SpanIndex, c: int = 1,
                     projection: Optional[Sequence[str]] = None) -> Delta:
        cfg = self.cfg
        pids = list(range(cfg.n_parts)) if pids is None else list(pids)
        keys = self._delta_keys(tsid, did, pids)
        fields = None
        if projection is not None and "attrs" not in projection:
            # attribute-projection pushdown: the attrs tile (the widest
            # column) is never read off storage
            fields = tuple(f for f in DELTA_FIELDS if f != "attrs")
        sizes: Dict[DeltaKey, ReadSizes] = {}
        got = self.store.multiget(keys, c=c, fields=fields, sizes=sizes)
        psize = si.smap.psize
        d = Delta.empty(cfg.n_parts, psize, cfg.n_attrs, ecap=1)
        e_parts = []
        for p, k in zip(pids, keys):
            a = got[k]
            d.valid[p] = a["valid"]
            d.present[p] = a["present"]
            if "attrs" in a:
                d.attrs[p] = a["attrs"]
            ne = int((a["e_src"] != SENTINEL).sum())
            e_parts.append((a["e_src"][:ne], a["e_dst"][:ne], a["e_op"][:ne], a["e_val"][:ne]))
            s = sizes[k]
            self._record_cost(1, s.enc, int(a["valid"].sum()) + ne, s.raw,
                              s.pool, s.pool_cols)
        if e_parts:
            d.e_src = np.concatenate([e[0] for e in e_parts])
            d.e_dst = np.concatenate([e[1] for e in e_parts])
            d.e_op = np.concatenate([e[2] for e in e_parts])
            d.e_val = np.concatenate([e[3] for e in e_parts])
            if len(d.e_src) == 0:
                d.e_src = np.full(1, SENTINEL, np.int32)
                d.e_dst = np.full(1, SENTINEL, np.int32)
                d.e_op = np.zeros(1, np.int8)
                d.e_val = np.full(1, -1, np.int32)
        return d

    def _fetch_eventlists(self, si: SpanIndex, b_lo: int, b_hi: int,
                          c: int = 1,
                          sids: Optional[Sequence[int]] = None) -> EventLog:
        """Micro-eventlists for buckets [b_lo, b_hi).  Events are
        replicated to both endpoints' shards, so a fetch restricted to
        the shards covering a partition subset still sees every event
        with >=1 endpoint there (planner shard pruning)."""
        keys = []
        for b in range(b_lo, b_hi):
            for sid in (range(self.cfg.n_shards) if sids is None else sids):
                keys.append(DeltaKey(si.span.tsid, sid, f"E:{b}", 0))
        out = EventLog.empty()
        # a bucket may have no events on a given shard -> key absent;
        # the stored pid column is for micro reads only — project it
        # away so it is seeked over, never decoded
        sizes: Dict[DeltaKey, ReadSizes] = {}
        got = self.store.multiget(keys, c=c, missing_ok=True, sizes=sizes,
                                  fields=("t", "kind", "src", "dst", "key", "val"))
        logs = []
        for k in keys:
            if k not in got:
                continue
            a = got[k]
            s = sizes[k]
            self._record_cost(1, s.enc, len(a["t"]), s.raw, s.pool, s.pool_cols)
            logs.append(a)
        if not logs:
            return out
        cat = {c2: np.concatenate([l[c2] for l in logs]) for c2 in
               ("t", "kind", "src", "dst", "key", "val")}
        ev = EventLog(**cat)
        # events were replicated across shards: dedup identical rows
        rows = np.stack([ev.t, ev.kind.astype(np.int64), ev.src.astype(np.int64),
                         ev.dst.astype(np.int64), ev.key.astype(np.int64),
                         ev.val.astype(np.int64)], 1)
        _, uniq = np.unique(rows, axis=0, return_index=True)
        ev = ev.take(np.sort(uniq))
        return ev.take(np.argsort(ev.t, kind="stable"))

    def _leaf_for(self, si: SpanIndex, t: int) -> int:
        """Nearest derived-hierarchy checkpoint at or before t."""
        return max(
            i for i, ct in enumerate(si.checkpoint_ts) if ct <= t
        ) if any(ct <= t for ct in si.checkpoint_ts) else 0

    def _span_events_until(self, si: SpanIndex, t_ck: int, t_hi: int, c: int,
                           pids: Optional[Sequence[int]]) -> EventLog:
        """Eventlists of the span covering (t_ck, t_hi], pid-filtered —
        fetched ONCE and re-filtered per timepoint by the batched path."""
        ev_buckets = self._ev_buckets(si, t_ck, t_hi)
        if not ev_buckets:
            return EventLog.empty()
        sids = None
        if pids is not None:
            sids = sorted({self._sid_of_pid(int(p)) for p in pids})
        ev = self._fetch_eventlists(si, min(ev_buckets), max(ev_buckets) + 1, c,
                                    sids=sids)
        ev = ev.take(np.nonzero((ev.t > t_ck) & (ev.t <= t_hi))[0])
        if pids is not None and len(ev):
            # keep events with EITHER endpoint in the fetched pids — a
            # deletion whose src lives elsewhere must still clear the
            # mirrored copy, or the edge resurrects
            pid_s, _, found_s = si.smap.lookup(ev.src)
            keep = found_s & np.isin(pid_s, np.asarray(pids))
            has_dst = ev.dst >= 0
            if has_dst.any():
                pid_d, _, found_d = si.smap.lookup(ev.dst)
                keep |= has_dst & found_d & np.isin(pid_d, np.asarray(pids))
            ev = ev.take(np.nonzero(keep)[0])
        return ev

    def _restrict_pids(self, state: Delta, si: SpanIndex,
                       pids: Sequence[int]) -> Delta:
        """Materialize only the fetched partitions: unfetched ones hold
        partial (event-only) state and must not leak into the result."""
        mask = np.zeros(self.cfg.n_parts, bool)
        mask[np.asarray(pids, np.int64)] = True  # stays valid for pids=[]
        state.valid &= mask[:, None]
        psize = si.smap.psize
        e_pid = (state.e_src.astype(np.int64) // psize)
        bad = (state.e_src != SENTINEL) & ~mask[np.clip(e_pid, 0, self.cfg.n_parts - 1)]
        keep = ~bad  # keeps trailing SENTINEL pads -> prefix invariant holds
        state.e_src = state.e_src[keep]
        state.e_dst = state.e_dst[keep]
        state.e_op = state.e_op[keep]
        state.e_val = state.e_val[keep]
        return state

    def _snap_key(self, t: int, pids, projection, c: int):
        # c is part of the key: it cannot change the result, but a
        # caller asking for a c>1 replicated read expects to exercise
        # real storage reads (failover), not a c=1 cache entry
        return (
            int(t),
            None if pids is None else tuple(int(p) for p in pids),
            None if projection is None else tuple(projection),
            int(c),
        )

    def _snap_cache_get(self, key) -> Optional[GraphState]:
        hit = self._snap_cache.get(key)
        if hit is None:
            return None
        self._snap_cache.move_to_end(key)
        g, cost = hit
        # replay the logical fetch cost: the LRU changes wall time, not
        # the planner's accounting (cost invariants stay deterministic).
        # The replay preserves the fill-time physical-vs-pool split, so
        # bytes the block pool served are never re-counted as decodes
        # (accounting parity with the fill-time read).
        self._record_cost(cost.n_deltas, cost.n_bytes, cost.sum_cardinality,
                          cost.n_bytes_decompressed, cost.n_bytes_pool,
                          cost.n_pool_hits)
        return g.copy()

    def _snap_cache_put(self, key, g: GraphState, cost: FetchCost) -> None:
        self._snap_cache[key] = (g.copy(), cost.copy())
        self._snap_cache.move_to_end(key)
        while len(self._snap_cache) > self.SNAP_CACHE_MAX:
            self._snap_cache.popitem(last=False)

    def invalidate_caches(self, t_from: Optional[int] = None,
                          t_ranges: Optional[Sequence[Tuple[int, int]]] = None,
                          drop_pool: bool = True) -> None:
        """Cache invalidation, scoped when possible.  With no arguments
        everything is dropped — the snapshot LRU AND the store's
        decoded-block pool (pass ``drop_pool=False`` to keep warm blocks,
        e.g. when benchmarking the pool itself).  ``t_from`` drops LRU
        entries at t >= t_from (append/update: snapshots strictly before
        the new events stay valid); ``t_ranges`` drops entries whose t
        falls inside any inclusive [lo, hi] range (compaction: only the
        rewritten spans' windows are touched).  Scoped invalidation
        leaves the block pool alone: stored blocks are immutable per
        tsid, and the write paths invalidate per key through
        ``DeltaStore.put``/``delete``.  Every call bumps ``read_epoch``
        (the plan-layer fetch cache keys on it)."""
        self.read_epoch += 1
        if t_from is None and t_ranges is None:
            self._snap_cache.clear()
            if drop_pool:
                self.store.clear_pool()
            return
        stale = [
            k for k in self._snap_cache
            if (t_from is not None and k[0] >= t_from)
            or (t_ranges is not None
                and any(lo <= k[0] <= hi for lo, hi in t_ranges))
        ]
        for k in stale:
            del self._snap_cache[k]

    def get_snapshot(self, t: int, c: int = 1, pids: Optional[Sequence[int]] = None,
                     use_kernel: bool = False,
                     projection: Optional[Sequence[str]] = None) -> GraphState:
        """Algorithm 1.  pids restricts to a partition subset (used by the
        k-hop and partition-parallel TAF fetch paths); ``projection``
        (planner hook) lists the optional payload fields to fetch —
        passing one without "attrs" skips the attribute tiles entirely
        (the returned attrs are then -1/unset).  Results go through a
        small LRU keyed on (t, pids, projection); hits skip storage but
        re-record the logical fetch cost.  Reads at t past the sealed
        history (mid-stream ``append``) overlay the ingest buffer's live
        events and bypass the LRU."""
        self.last_cost = FetchCost()
        p0 = self._pending_floor()
        open_read = p0 is not None and t >= p0
        key = self._snap_key(t, pids, projection, c)
        if not open_read:
            hit = self._snap_cache_get(key)
            if hit is not None:
                return hit
        with self.cost_scope() as acc:
            si = self._span_index(t)
            leaf = self._leaf_for(si, t)
            path = self._hierarchy_path(si, leaf)
            deltas = [self._fetch_delta(si.span.tsid, did, pids, si, c, projection)
                      for did in path]
            state = overlay_fold(deltas, use_kernel=use_kernel)
            t_ck = si.checkpoint_ts[leaf]
            ev = self._span_events_until(si, t_ck, t, c, pids)
            if len(ev):
                state = overlay_fold(
                    [state, events_to_delta(ev, si.smap, self.cfg.n_attrs)],
                    use_kernel=use_kernel,
                )
            if pids is not None:
                state = self._restrict_pids(state, si, pids)
            g = delta_to_graph(state, si.smap)
            if open_read:
                g = self._overlay_pending(g, t, si, pids)
        if not open_read:
            self._snap_cache_put(key, g, acc)
        return g

    def get_snapshots(self, ts: Sequence[int], c: int = 1,
                      pids: Optional[Sequence[int]] = None,
                      use_kernel: bool = False,
                      projection: Optional[Sequence[str]] = None) -> List[GraphState]:
        """Batched Algorithm 1: snapshots at every t in ``ts``, sharing
        one hierarchy-path fetch and one eventlist fetch per (span, leaf)
        group instead of re-reading them per timepoint.  With
        ``use_kernel`` the node payloads of a whole group fold in one
        time-batched ``delta_overlay`` kernel launch (per-timepoint
        validity masks select each t's eventlist layer).

        ``last_cost`` totals the whole batch.  Bit-identical to
        ``[get_snapshot(t) for t in ts]`` (property-tested)."""
        ts_list = [int(t) for t in np.asarray(ts, np.int64).ravel()]
        out: List[Optional[GraphState]] = [None] * len(ts_list)
        self.last_cost = FetchCost()
        p0 = self._pending_floor()
        groups: Dict[Tuple[int, int], List[int]] = {}
        for j, t in enumerate(ts_list):
            if p0 is None or t < p0:  # open reads bypass the LRU
                hit = self._snap_cache_get(self._snap_key(t, pids, projection, c))
                if hit is not None:
                    out[j] = hit
                    continue
            si = self._span_index(t)
            groups.setdefault((si.span.tsid, self._leaf_for(si, t)), []).append(j)
        for (tsid, leaf), members in groups.items():
            si = self._span_by_tsid[tsid]
            t_ck = si.checkpoint_ts[leaf]
            t_hi = max(ts_list[j] for j in members)
            path = self._hierarchy_path(si, leaf)
            path_deltas = [
                self._fetch_delta(tsid, did, pids, si, c, projection)
                for did in path
            ]
            ev = self._span_events_until(si, t_ck, t_hi, c, pids)
            ev_deltas = []
            for j in members:
                ev_j = ev.take(np.nonzero(ev.t <= ts_list[j])[0])
                ev_deltas.append(
                    events_to_delta(ev_j, si.smap, self.cfg.n_attrs)
                    if len(ev_j) else None
                )
            states = self._fold_group(path_deltas, ev_deltas, use_kernel)
            for j, state in zip(members, states):
                if pids is not None:
                    state = self._restrict_pids(state, si, pids)
                g = delta_to_graph(state, si.smap)
                if p0 is not None and ts_list[j] >= p0:
                    g = self._overlay_pending(g, ts_list[j], si, pids)
                out[j] = g
            # NOT inserted into the snapshot LRU: the group's fetch cost
            # is shared across members, so a per-t entry would over-
            # report the logical cost on later single-t cache hits
        return out  # type: ignore[return-value]

    def _fold_group(self, path_deltas: List[Delta],
                    ev_deltas: List[Optional[Delta]],
                    use_kernel: bool) -> List[Delta]:
        """Fold one (span, leaf) group's shared hierarchy path with each
        timepoint's eventlist delta."""
        T = len(ev_deltas)
        base = overlay_fold(path_deltas) if len(path_deltas) > 1 else path_deltas[0]
        if use_kernel and T > 1 and any(d is not None for d in ev_deltas):
            from repro.kernels.delta_overlay import ops as ov_ops

            h0 = len(path_deltas)
            layers = path_deltas + [d for d in ev_deltas if d is not None]
            tmask = np.zeros((len(layers), T), np.int8)
            tmask[:h0, :] = 1  # the shared path applies to every timepoint
            li = h0
            for j, d in enumerate(ev_deltas):
                if d is not None:
                    tmask[li, j] = 1  # each eventlist layer to its own t
                    li += 1
            v, p, a = ov_ops.overlay_batch(
                np.stack([d.valid for d in layers]),
                np.stack([d.present for d in layers]),
                np.stack([d.attrs for d in layers]),
                tmask,
            )
            v, p, a = np.asarray(v), np.asarray(p), np.asarray(a)
            states = []
            for j, d in enumerate(ev_deltas):
                st = base.copy()
                st.valid = v[..., j] != 0
                st.present = p[..., j]
                st.attrs = a[..., j]
                if d is not None:
                    st.e_src, st.e_dst, st.e_op, st.e_val = delta_mod._edge_sum(
                        base, d)
                states.append(st)
            return states
        return [
            base.copy() if d is None else delta_sum(base, d)
            for d in ev_deltas
        ]

    def get_node_history(self, nid: int, t0: int, t1: int, c: int = 1):
        """Algorithm 2: (initial state at t0, EventLog of changes (t0,t1]).
        Buffered (unsealed) events in the window ride along from memory —
        they are not yet referenced by the version chains."""
        self.last_cost = FetchCost()
        si = self._span_index(t0)
        pid, slot, found = si.smap.lookup(np.asarray([nid]))
        p0 = self._pending_floor()
        pend_has_nid = False
        if p0 is not None and t0 >= p0:
            pend0 = self._pending.up_to(t0)
            pend_has_nid = bool(((pend0.src == nid) | (pend0.dst == nid)).any())
        init = None
        if found[0] or pend_has_nid:
            # a node only the buffer knows has no sealed partition yet —
            # fall back to the unrestricted overlay read
            snap = self.get_snapshot(
                t0, c=c, pids=[int(pid[0])] if found[0] else None)
            if nid < len(snap.present) and snap.present[nid]:
                init = {
                    "present": 1,
                    "attrs": snap.attrs[nid].copy(),
                    "neighbors": self._neighbors_of(snap, nid),
                }
        ts, tsids, buckets = self.vc.get(nid, t0, t1)
        ev = EventLog.empty()
        for tsid in np.unique(tsids):
            si2 = self._span_by_tsid[int(tsid)]
            bks = np.unique(buckets[tsids == tsid])
            # events touching nid are replicated to nid's shard: read it alone
            pid2, _, found2 = si2.smap.lookup(np.asarray([nid]))
            sids = [self._sid_of_pid(int(pid2[0]))] if found2[0] else None
            got = self._fetch_eventlists(si2, int(bks.min()), int(bks.max()) + 1, c,
                                         sids=sids)
            ev = ev.concat(got, sort=False)
        if p0 is not None and t1 >= p0:
            ev = ev.concat(self._pending.slice_time(t0, t1), sort=False)
        ev = ev.take(np.argsort(ev.t, kind="stable"))
        sel = ((ev.src == nid) | (ev.dst == nid)) & (ev.t > t0) & (ev.t <= t1)
        return init, ev.take(np.nonzero(sel)[0])

    def _neighbors_of(self, g: GraphState, nid: int) -> np.ndarray:
        src, dst, _ = g.edges()
        return np.unique(np.concatenate([dst[src == nid], src[dst == nid]]))

    def get_k_hop(self, nid: int, t: int, k: int, c: int = 1,
                  method: str = "auto") -> GraphState:
        """Algorithms 3/4.  'snapshot' filters a full snapshot; 'expand'
        fetches partitions on demand.  'auto' is cost-based: it compares
        the physical raw bytes each method would decode — real stored
        sizes discounted by decoded-block-pool residency (see
        ``explain_k_hop``) — instead of the paper's fixed k<=2 rule
        (which remains the tie-break)."""
        if method == "auto":
            method = self.explain_k_hop(nid, t, k)["method"]
        if method == "snapshot":
            g = self.get_snapshot(t, c=c)
            return self._filter_k_hop(g, nid, k)
        # expand: fetch the node's partition, then neighbors' partitions
        self.last_cost = FetchCost()
        si = self._span_index(t)
        frontier = np.asarray([nid], np.int32)
        fetched_pids: set = set()
        g_acc: Optional[GraphState] = None
        nodes_seen = set([int(nid)])
        for _ in range(k + 1):
            pid, _, found = si.smap.lookup(frontier)
            need = sorted(set(int(p) for p in pid[found]) - fetched_pids)
            if need:
                g_new = self.get_snapshot(t, c=c, pids=need)
                fetched_pids |= set(need)
                g_acc = g_new if g_acc is None else _merge_states(g_acc, g_new)
            if g_acc is None:
                break
            nxt = []
            src, dst, _ = g_acc.edges()
            for n in frontier:
                nxt.append(dst[src == n])
                nxt.append(src[dst == n])
            nxt = np.unique(np.concatenate(nxt)) if nxt else np.empty(0, np.int32)
            frontier = np.asarray([x for x in nxt if int(x) not in nodes_seen], np.int32)
            nodes_seen |= set(int(x) for x in nxt)
            if not len(frontier):
                break
        return self._filter_k_hop(g_acc if g_acc is not None else
                                  GraphState.empty(self.n_nodes, self.cfg.n_attrs), nid, k)

    def _filter_k_hop(self, g: GraphState, nid: int, k: int) -> GraphState:
        keep = {int(nid)}
        frontier = {int(nid)}
        src, dst, _ = g.edges()
        for _ in range(k):
            nxt = set()
            for n in frontier:
                nxt |= set(dst[src == n].tolist())
                nxt |= set(src[dst == n].tolist())
            nxt -= keep
            keep |= nxt
            frontier = nxt
        out = GraphState.empty(len(g.present), g.attrs.shape[1])
        ids = np.asarray(sorted(keep), np.int64)
        ids = ids[ids < len(g.present)]
        out.present[ids] = g.present[ids]
        out.attrs[ids] = g.attrs[ids]
        m = np.isin(src, ids) & np.isin(dst, ids)
        key = pack_edge_key(src[m], dst[m])
        order = np.argsort(key)
        out.edge_key = key[order]
        out.edge_val = g.edge_val[m][order] if len(g.edge_val) else np.empty(0, np.int32)
        return out

    def get_node_1hop_history(self, nid: int, t0: int, t1: int, c: int = 1):
        """Algorithm 5: initial 1-hop state + per-neighbor change events."""
        init, ev = self.get_node_history(nid, t0, t1, c=c)
        hood = self.get_k_hop(nid, t0, 1, c=c)
        neigh_ids = hood.node_ids()
        neigh_events = {}
        for m in neigh_ids:
            if int(m) == int(nid):
                continue
            _, ev_m = self.get_node_history(int(m), t0, t1, c=c)
            neigh_events[int(m)] = ev_m
        return {"center_init": init, "center_events": ev,
                "hood": hood, "neighbor_events": neigh_events}

    # ---- stats ----
    def time_range(self) -> Tuple[int, int]:
        """Ingested time range, including still-buffered (pending) events."""
        if len(self._pending):
            t0 = (self._events.time_range()[0] if len(self._events)
                  else int(self._pending.t[0]))
            return int(t0), int(self._pending.t[-1])
        return self._events.time_range()

    def index_size_bytes(self) -> int:
        """Live encoded bytes on the store (x replication) — shrinks when
        compaction GCs superseded spans."""
        return self.store.live_bytes()

    COMPONENT_NAMES = {"E": "eventlists", "S": "hierarchy", "X": "aux_replicas"}

    def storage_report(self) -> Dict[str, Dict]:
        """Index size broken down by component (the paper's Fig. 10
        storage analysis): raw vs. encoded bytes and blob count for the
        eventlists (``E:*``), the derived snapshot hierarchy (``S:*``),
        the auxiliary 1-hop replicas (``X:*``), and anything else stored
        under this index's DeltaStore.  ``totals`` adds the aggregate and
        the compression ratio (encoded/raw); sizes are per logical key —
        multiply by ``replication`` for on-disk bytes."""
        by_comp = self.store.size_report()
        components: Dict[str, Dict] = {}
        raw_total = enc_total = count_total = 0
        for comp, row in sorted(by_comp.items()):
            name = self.COMPONENT_NAMES.get(comp, comp)
            components[name] = dict(row)
            raw_total += row["raw"]
            enc_total += row["encoded"]
            count_total += row["count"]
        return {
            "format": self.store.fmt,
            "replication": self.store.r,
            "components": components,
            "totals": {
                "raw": raw_total,
                "encoded": enc_total,
                "count": count_total,
                "ratio": (enc_total / raw_total) if raw_total else 1.0,
            },
            # per-node health and live-data placement — the same shape
            # whether the store is the in-process DeltaStore or a
            # RemoteDeltaStore over storage cells, so chaos tests assert
            # cluster health through one report
            "nodes": self.store.node_status(),
        }


def _merge_states(a: GraphState, b: GraphState) -> GraphState:
    n = max(len(a.present), len(b.present))
    a.grow(n)
    b.grow(n)
    out = GraphState.empty(n, a.attrs.shape[1])
    on_b = b.present == 1
    out.present = np.where(on_b, b.present, a.present)
    out.attrs = np.where(on_b[:, None], b.attrs, a.attrs)
    keys = np.concatenate([a.edge_key, b.edge_key])
    vals = np.concatenate([a.edge_val, b.edge_val])
    order = np.argsort(keys, kind="stable")
    keys, vals = keys[order], vals[order]
    keep = np.ones(len(keys), bool)
    if len(keys) > 1:
        keep[1:] = keys[1:] != keys[:-1]
    out.edge_key, out.edge_val = keys[keep], vals[keep]
    return out
