"""Deterministic fault injection for the MVCC maintenance path.

Named crash points (``compact.shadow_build``, ``compact.pre_swap``,
``compact.post_swap``, ``compact.mid_gc``, ``cell.apply``,
``cell.lease_expire`` — a cell's sweeper just detected an expired
writer lease, before reconciliation starts — and ``cell.reconcile`` —
mid orphan-seq reconciliation, after anti-entropy but before the lane
seal persists, ...) are
compiled into the maintenance and service code as ``fire(name)`` calls —
free when disarmed (one dict probe).  Tests arm a point with a hit
countdown and an action:

* ``raise`` — the Nth ``fire`` raises :class:`FaultError` in whatever
  thread hit it (a "clean" crash: the maintenance pass dies mid-flight
  but the process survives, so the test can assert the store is still
  readable and a retried pass converges);
* ``kill``  — the Nth ``fire`` SIGKILLs the *process* (used inside
  subprocess storage cells to prove a hard crash during a compaction
  write storm leaves the cluster serving).

Arming surfaces, in precedence order at ``fire`` time:

1. a :class:`contextvars.ContextVar` overlay (``local()``) — visible to
   the arming thread/task only; use it to scope a fault to one code path
   without races against unrelated threads;
2. the process-global registry (``arm()`` / ``scoped()``) — visible to
   every thread, which is what you want when the *maintenance thread*
   must crash while the test's main thread arms and observes;
3. the ``REPRO_FAULTPOINTS`` environment variable, parsed at import (and
   re-parsed by ``reset()``): ``name=hits[:action],name2=hits`` — e.g.
   ``REPRO_FAULTPOINTS="cell.apply=3:kill"`` makes a spawned storage
   cell SIGKILL itself on its 3rd apply.  Names therefore must not
   contain ``=``, ``:`` or ``,`` (use dots).

Countdown semantics: ``hits=N`` means fires N-1 times silently, then
acts on the Nth.  A fired entry disarms itself, so a retried maintenance
pass runs clean — exactly the "killed pass converges on retry" shape the
concurrency suite asserts.
"""
from __future__ import annotations

import contextlib
import contextvars
import os
import signal
import threading
from typing import Dict, Optional, Tuple

__all__ = ["FaultError", "fire", "arm", "disarm", "reset", "scoped",
           "local", "armed_points", "fired_counts"]

ENV_VAR = "REPRO_FAULTPOINTS"
ACTIONS = ("raise", "kill")


class FaultError(RuntimeError):
    """Raised by an armed fault point with action='raise'."""


# name -> [hits_remaining, action]; mutated under _lock
_registry: Dict[str, list] = {}
_fired: Dict[str, int] = {}  # total fires per name (armed or not)
_lock = threading.Lock()

# same-thread overlay: {name: [hits_remaining, action]} — list cells are
# shared with whatever context copied them, which is fine: the overlay is
# explicitly same-thread scoping
_ctx: contextvars.ContextVar = contextvars.ContextVar(
    "repro_faultpoints", default=None)


def _parse_env(val: str) -> Dict[str, list]:
    out: Dict[str, list] = {}
    for item in val.split(","):
        item = item.strip()
        if not item or "=" not in item:
            continue
        name, spec = item.split("=", 1)
        action = "raise"
        if ":" in spec:
            spec, action = spec.split(":", 1)
        if action not in ACTIONS:
            raise ValueError(f"unknown fault action {action!r} in {ENV_VAR}")
        out[name.strip()] = [max(int(spec), 1), action]
    return out


def reset() -> None:
    """Drop every armed point and re-parse ``REPRO_FAULTPOINTS``."""
    with _lock:
        _registry.clear()
        _fired.clear()
        _registry.update(_parse_env(os.environ.get(ENV_VAR, "")))
    ctx = _ctx.get()
    if ctx:
        ctx.clear()


def arm(name: str, hits: int = 1, action: str = "raise") -> None:
    """Arm ``name`` globally: the ``hits``-th fire acts, then disarms."""
    assert action in ACTIONS, action
    with _lock:
        _registry[name] = [max(int(hits), 1), action]


def disarm(name: str) -> None:
    with _lock:
        _registry.pop(name, None)
    ctx = _ctx.get()
    if ctx:
        ctx.pop(name, None)


@contextlib.contextmanager
def scoped(name: str, hits: int = 1, action: str = "raise"):
    """Globally arm ``name`` for the duration of the block (any thread —
    including a background maintenance thread — can trip it)."""
    arm(name, hits, action)
    try:
        yield
    finally:
        disarm(name)


@contextlib.contextmanager
def local(name: str, hits: int = 1, action: str = "raise"):
    """Arm ``name`` in the current context only (same thread/task);
    threads spawned inside the block do NOT inherit it."""
    assert action in ACTIONS, action
    ctx = _ctx.get()
    if ctx is None:
        ctx = {}
        _ctx.set(ctx)
    ctx[name] = [max(int(hits), 1), action]
    try:
        yield
    finally:
        ctx.pop(name, None)


def _act(name: str, action: str) -> None:
    if action == "kill":
        os.kill(os.getpid(), signal.SIGKILL)
    raise FaultError(f"fault point {name!r} fired")


def fire(name: str) -> None:
    """Trip ``name``: no-op unless armed; countdown then act + disarm."""
    ctx = _ctx.get()
    if ctx is not None:
        cell = ctx.get(name)
        if cell is not None:
            cell[0] -= 1
            if cell[0] <= 0:
                ctx.pop(name, None)
                _act(name, cell[1])
            return
    action: Optional[str] = None
    with _lock:
        _fired[name] = _fired.get(name, 0) + 1
        cell = _registry.get(name)
        if cell is not None:
            cell[0] -= 1
            if cell[0] <= 0:
                _registry.pop(name, None)
                action = cell[1]
    if action is not None:
        _act(name, action)


def armed_points() -> Dict[str, Tuple[int, str]]:
    with _lock:
        return {k: (v[0], v[1]) for k, v in _registry.items()}


def fired_counts() -> Dict[str, int]:
    with _lock:
        return dict(_fired)


reset()  # pick up REPRO_FAULTPOINTS at import (subprocess cells rely on it)
