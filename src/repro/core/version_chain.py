"""Version chains (paper §4.3c): per-node chronological pointers into the
delta sets — CSR arrays over the node-id space, keyed by (t, tsid,
eventlist bucket).  This is the entity-centric index leg that gives TGI
its |V|+1-fetch node-history cost (Table 1)."""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.events import EventLog


@dataclasses.dataclass
class VersionChains:
    indptr: np.ndarray  # (N+1,) int64
    t: np.ndarray  # (R,) int64 — event time
    tsid: np.ndarray  # (R,) int32 — timespan of the reference
    bucket: np.ndarray  # (R,) int32 — micro-eventlist bucket within span

    @classmethod
    def build(cls, events: EventLog, span_of_event: np.ndarray,
              bucket_of_event: np.ndarray, n_nodes: int) -> "VersionChains":
        """span_of_event / bucket_of_event: per-event placement, aligned
        with the (chronologically sorted) global log."""
        src = events.src
        dst = events.dst
        # each event references its src node, and its dst node for edges
        has_dst = dst >= 0
        nid = np.concatenate([src, dst[has_dst]])
        t = np.concatenate([events.t, events.t[has_dst]])
        ts = np.concatenate([span_of_event, span_of_event[has_dst]])
        bk = np.concatenate([bucket_of_event, bucket_of_event[has_dst]])
        order = np.lexsort((t, nid))
        nid, t, ts, bk = nid[order], t[order], ts[order], bk[order]
        indptr = np.searchsorted(nid, np.arange(n_nodes + 1))
        return cls(indptr=indptr.astype(np.int64), t=t.astype(np.int64),
                   tsid=ts.astype(np.int32), bucket=bk.astype(np.int32))

    def get(self, nid: int, t0=None, t1=None):
        """References for node nid with t in (t0, t1] (paper Alg. 2 l.2-3)."""
        lo, hi = int(self.indptr[nid]), int(self.indptr[nid + 1])
        t = self.t[lo:hi]
        sel = np.ones(hi - lo, bool)
        if t0 is not None:
            sel &= t > t0
        if t1 is not None:
            sel &= t <= t1
        idx = np.nonzero(sel)[0] + lo
        return self.t[idx], self.tsid[idx], self.bucket[idx]

    def n_versions(self, nid: int) -> int:
        return int(self.indptr[nid + 1] - self.indptr[nid])

    def to_arrays(self):
        return {"indptr": self.indptr, "t": self.t, "tsid": self.tsid,
                "bucket": self.bucket}

    @classmethod
    def from_arrays(cls, d):
        return cls(d["indptr"], d["t"], d["tsid"], d["bucket"])
