"""Version chains (paper §4.3c): per-node chronological pointers into the
delta sets — CSR arrays over the node-id space, keyed by (t, tsid,
eventlist bucket).  This is the entity-centric index leg that gives TGI
its |V|+1-fetch node-history cost (Table 1).

Updates are append-only in time, so ``append`` does NOT re-derive the
chains from the full log (the old path lexsorted every reference on
every batch — O(total history) per update).  Each appended batch becomes
one small CSR *segment* (O(batch log batch) to build); ``get`` drains the
base CSR plus every segment's per-node slice, which stays chronological
because segments are time-ordered.  ``consolidate`` folds the segments
back into the base in one vectorized pass — compaction calls it, and it
auto-runs once the segment list grows past ``AUTO_CONSOLIDATE`` so read
fan-out stays bounded.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.events import EventLog

_CSR = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


def _refs_csr(events: EventLog, span_of_event: np.ndarray,
              bucket_of_event: np.ndarray, n_nodes: int) -> _CSR:
    """(indptr, t, tsid, bucket) CSR of one batch's references: each event
    references its src node, and its dst node for edge events."""
    src = events.src
    dst = events.dst
    has_dst = dst >= 0
    nid = np.concatenate([src, dst[has_dst]])
    t = np.concatenate([events.t, events.t[has_dst]])
    ts = np.concatenate([span_of_event, span_of_event[has_dst]])
    bk = np.concatenate([bucket_of_event, bucket_of_event[has_dst]])
    order = np.lexsort((t, nid))
    nid, t, ts, bk = nid[order], t[order], ts[order], bk[order]
    indptr = np.searchsorted(nid, np.arange(n_nodes + 1))
    return (indptr.astype(np.int64), t.astype(np.int64),
            ts.astype(np.int32), bk.astype(np.int32))


def _csr_slice(csr: _CSR, nid: int, t0, t1):
    indptr, t_arr, ts_arr, bk_arr = csr
    if nid < 0 or nid + 1 >= len(indptr):
        z = np.empty(0, np.int64)
        return z, z.astype(np.int32), z.astype(np.int32)
    lo, hi = int(indptr[nid]), int(indptr[nid + 1])
    t = t_arr[lo:hi]
    sel = np.ones(hi - lo, bool)
    if t0 is not None:
        sel &= t > t0
    if t1 is not None:
        sel &= t <= t1
    idx = np.nonzero(sel)[0] + lo
    return t_arr[idx], ts_arr[idx], bk_arr[idx]


@dataclasses.dataclass
class VersionChains:
    indptr: np.ndarray  # (N+1,) int64
    t: np.ndarray  # (R,) int64 — event time
    tsid: np.ndarray  # (R,) int32 — timespan of the reference
    bucket: np.ndarray  # (R,) int32 — micro-eventlist bucket within span
    # appended-batch CSR segments, chronological (see module docstring)
    segments: List[_CSR] = dataclasses.field(default_factory=list)

    AUTO_CONSOLIDATE = 64  # max segments before reads force a merge

    @classmethod
    def build(cls, events: EventLog, span_of_event: np.ndarray,
              bucket_of_event: np.ndarray, n_nodes: int) -> "VersionChains":
        """span_of_event / bucket_of_event: per-event placement, aligned
        with the (chronologically sorted) global log."""
        indptr, t, ts, bk = _refs_csr(events, span_of_event, bucket_of_event,
                                      n_nodes)
        return cls(indptr=indptr, t=t, tsid=ts, bucket=bk)

    def append(self, events: EventLog, span_of_event: np.ndarray,
               bucket_of_event: np.ndarray, n_nodes: int) -> None:
        """Extend the chains with one append-only batch — O(batch) work,
        independent of total history size."""
        if not len(events):
            return
        self.segments.append(
            _refs_csr(events, span_of_event, bucket_of_event, n_nodes))
        if len(self.segments) > self.AUTO_CONSOLIDATE:
            self.consolidate()

    def consolidate(self) -> None:
        """Fold the appended segments into the base CSR (one vectorized
        interleave over all references)."""
        if not self.segments:
            return
        csrs = [(self.indptr, self.t, self.tsid, self.bucket)] + self.segments
        n_nodes = max(len(c[0]) - 1 for c in csrs)
        nid = np.concatenate([
            np.repeat(np.arange(len(c[0]) - 1, dtype=np.int64), np.diff(c[0]))
            for c in csrs
        ])
        t = np.concatenate([c[1] for c in csrs])
        ts = np.concatenate([c[2] for c in csrs])
        bk = np.concatenate([c[3] for c in csrs])
        rank = np.concatenate([
            np.full(len(c[1]), i, np.int32) for i, c in enumerate(csrs)
        ])
        # per-node chronological order; segment rank breaks same-t ties in
        # ingest order (base first), preserving the chains' stable order
        order = np.lexsort((rank, t, nid))
        nid, t, ts, bk = nid[order], t[order], ts[order], bk[order]
        self.indptr = np.searchsorted(nid, np.arange(n_nodes + 1)).astype(np.int64)
        self.t, self.tsid, self.bucket = t, ts, bk
        self.segments = []

    def snapshot(self) -> "VersionChains":
        """O(1) structural snapshot for MVCC read views: shares the base
        arrays (rebound — never mutated in place — by ``consolidate``)
        and copies the segment *list*, so a reader holding the snapshot
        keeps a stable chain while the live object consolidates or grows
        under the index's MVCC lock."""
        return VersionChains(self.indptr, self.t, self.tsid, self.bucket,
                             list(self.segments))

    def get(self, nid: int, t0=None, t1=None):
        """References for node nid with t in (t0, t1] (paper Alg. 2 l.2-3)."""
        parts = [_csr_slice((self.indptr, self.t, self.tsid, self.bucket),
                            nid, t0, t1)]
        parts.extend(_csr_slice(seg, nid, t0, t1) for seg in self.segments)
        if len(parts) == 1:
            return parts[0]
        return (np.concatenate([p[0] for p in parts]),
                np.concatenate([p[1] for p in parts]),
                np.concatenate([p[2] for p in parts]))

    def n_versions(self, nid: int) -> int:
        n = 0
        for indptr, *_ in [(self.indptr,)] + [(s[0],) for s in self.segments]:
            if 0 <= nid < len(indptr) - 1:
                n += int(indptr[nid + 1] - indptr[nid])
        return n

    def to_arrays(self):
        self.consolidate()
        return {"indptr": self.indptr, "t": self.t, "tsid": self.tsid,
                "bucket": self.bucket}

    @classmethod
    def from_arrays(cls, d):
        return cls(d["indptr"], d["t"], d["tsid"], d["bucket"])
