"""Timespan management (paper §4.5, Fig. 4).

History is divided into non-overlapping timespans holding a roughly equal
number of events (uniform-in-events is the paper's practical choice);
partitioning and slot maps are frozen within a span and rebuilt at
boundaries.  ``tune_timespan_length`` implements the paper's g(T) - f(T)
maxima argument as an explicit cost model the benchmarks sweep.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.events import EventLog


@dataclasses.dataclass
class TimeSpan:
    tsid: int
    t_start: int  # inclusive
    t_end: int  # inclusive
    ev_lo: int  # event index range [lo, hi) in the global log
    ev_hi: int


def split_timespans(events: EventLog, events_per_span: int) -> List[TimeSpan]:
    """Equal-event-count spans; boundaries never split a timestamp (all
    events of one t land in one span, keeping snapshots well-defined)."""
    n = len(events)
    if n == 0:
        return [TimeSpan(0, 0, 0, 0, 0)]
    spans: List[TimeSpan] = []
    lo = 0
    tsid = 0
    while lo < n:
        hi = min(lo + events_per_span, n)
        # extend to include all events with the same timestamp
        if hi < n:
            t_edge = events.t[hi - 1]
            while hi < n and events.t[hi] == t_edge:
                hi += 1
        spans.append(
            TimeSpan(tsid, int(events.t[lo]), int(events.t[hi - 1]), lo, hi)
        )
        tsid += 1
        lo = hi
    return spans


def span_for_time(spans: List[TimeSpan], t: int) -> TimeSpan:
    """The span whose range contains t (or the last one before it)."""
    for s in reversed(spans):
        if t >= s.t_start:
            return s
    return spans[0]


# ---------------------------------------------------------------------------
# f(T) / g(T) cost model (paper §4.5 closing discussion)
# ---------------------------------------------------------------------------


def partition_quality_penalty(span_events: int, events_per_span: int,
                              drift_rate: float = 1e-6) -> float:
    """f(T): expected extra micro-delta seeks on k-hop queries due to a
    stale partitioning — grows with span length as the graph drifts away
    from the layout computed at span start."""
    return drift_rate * span_events * (span_events / max(events_per_span, 1))


def version_query_gain(events_per_span: int, mean_query_interval_events: float) -> float:
    """g(T): version queries spanning fewer timespans touch fewer slot
    maps / partition generations; gain saturates once a span covers the
    average query interval."""
    return min(events_per_span / max(mean_query_interval_events, 1.0), 1.0)


def tune_timespan_length(candidates, mean_query_interval_events: float,
                         drift_rate: float = 1e-6) -> int:
    """argmax over candidates of g(T) - f(T) (the paper's maxima)."""
    best, best_v = candidates[0], -np.inf
    for c in candidates:
        v = version_query_gain(c, mean_query_interval_events) - partition_quality_penalty(
            c, c, drift_rate
        )
        if v > best_v:
            best, best_v = c, v
    return int(best)
