"""The delta framework (paper §4.1, Definitions 1-5) in tensor form.

A partitioned delta over a timespan's slot assignment has two parts:

* **node payload** — dense slot-aligned tiles.  Because the paper freezes
  the node->partition map within a timespan (§4.5), every node also gets a
  frozen *slot*, so Δ-sum over node state degenerates from a sorted merge
  into an elementwise last-writer-wins overlay (the TPU adaptation —
  DESIGN.md §2; Pallas kernel in repro.kernels.delta_overlay):

      valid  (P, psize)      bool  — this delta touches the slot
      present(P, psize)      int8  — 0/1 node existence (post-state)
      attrs  (P, psize, K)   int32 — attribute values, -1 = unset

* **edge payload** — slot-keyed sorted adjacency runs; Δ-sum is a sorted
  last-wins merge (edges are too skewed for dense rows):

      e_src  (E,) int32 — slot-of-src within partition  (sorted major)
      e_dst  (E,) int32 — global dst node id            (sorted minor)
      e_op   (E,) int8  — 1 = present after this delta, 0 = deleted
      e_val  (E,) int32 — edge attribute value (-1 unset)
      (padded with e_src = INT32_MAX sentinels to fixed capacity)

All Δ-algebra identities of the paper hold and are property-tested:
Δ+∅=Δ, (Δ1+Δ2)+Δ3 = Δ1+(Δ2+Δ3), Δ−Δ=∅, and non-commutativity of +.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

SENTINEL = np.int32(2**31 - 1)

# the stored micro-delta schema (one source of truth for serialization,
# size accounting, and the planner's projection pushdown)
FIELDS = ("valid", "present", "attrs", "e_src", "e_dst", "e_op", "e_val")


@dataclasses.dataclass
class Delta:
    """One partitioned delta (all partitions of one horizontal shard)."""

    valid: np.ndarray  # (P, psize) bool
    present: np.ndarray  # (P, psize) int8
    attrs: np.ndarray  # (P, psize, K) int32
    e_src: np.ndarray  # (E,) int32 (slot ids, SENTINEL-padded, sorted)
    e_dst: np.ndarray  # (E,) int32
    e_op: np.ndarray  # (E,) int8
    e_val: np.ndarray  # (E,) int32

    # ---- constructors ----
    @classmethod
    def empty(cls, P: int, psize: int, K: int, ecap: int = 0) -> "Delta":
        return cls(
            valid=np.zeros((P, psize), bool),
            present=np.zeros((P, psize), np.int8),
            attrs=np.full((P, psize, K), -1, np.int32),
            e_src=np.full(ecap, SENTINEL, np.int32),
            e_dst=np.full(ecap, SENTINEL, np.int32),
            e_op=np.zeros(ecap, np.int8),
            e_val=np.full(ecap, -1, np.int32),
        )

    @property
    def shape(self):
        return self.valid.shape + (self.attrs.shape[-1], len(self.e_src))

    def n_edges(self) -> int:
        return int((self.e_src != SENTINEL).sum())

    def cardinality(self) -> int:
        """Paper Def. 3: unique node/edge descriptions in the delta."""
        return int(self.valid.sum()) + self.n_edges()

    def nbytes(self) -> int:
        return sum(getattr(self, f).nbytes for f in FIELDS)

    def copy(self) -> "Delta":
        return Delta(**{f: getattr(self, f).copy() for f in
                        ("valid", "present", "attrs", "e_src", "e_dst", "e_op", "e_val")})


# ---------------------------------------------------------------------------
# Node-payload algebra (elementwise, slot-aligned)
# ---------------------------------------------------------------------------


def _node_sum(a: Delta, b: Delta):
    """last-writer-wins overlay: b over a.  Attributes merge per-key: a
    delta that touches a node but leaves a key at -1 inherits a's value
    (matches event semantics: NATTR_SET writes one key)."""
    valid = a.valid | b.valid
    present = np.where(b.valid, b.present, a.present)
    attrs = np.where(b.valid[..., None] & (b.attrs != -1), b.attrs, a.attrs)
    # deletion clears attributes
    attrs = np.where((present == 0)[..., None], -1, attrs)
    return valid, present, attrs


def _edge_key(src, dst):
    # shift-pack (slot, dst) into one sortable int64.  This is a delta-
    # internal keyspace (src is a global SLOT id, not a node id) and is
    # never compared against GraphState.edge_key / snapshot.pack_edge_key
    # (which shifts by 32); both halves stay below 2^31 here (slots are
    # n_parts*psize-bounded, dst ids are bounded by events.py int32)
    return (src.astype(np.int64) << 31) | dst.astype(np.int64)


def _edge_sum(a: Delta, b: Delta, cap: Optional[int] = None):
    """Sorted last-wins merge of edge runs (b wins)."""
    na = int((a.e_src != SENTINEL).sum())
    nb = int((b.e_src != SENTINEL).sum())
    src = np.concatenate([a.e_src[:na], b.e_src[:nb]])
    dst = np.concatenate([a.e_dst[:na], b.e_dst[:nb]])
    op = np.concatenate([a.e_op[:na], b.e_op[:nb]])
    val = np.concatenate([a.e_val[:na], b.e_val[:nb]])
    prio = np.concatenate([np.zeros(na, np.int8), np.ones(nb, np.int8)])
    key = _edge_key(src, dst)
    order = np.lexsort((prio, key))
    key, src, dst, op, val = key[order], src[order], dst[order], op[order], val[order]
    # keep last of each key; inherit attr from the earlier run when the
    # later one leaves it unset and keeps the edge present
    last = np.ones(len(key), bool)
    if len(key) > 1:
        last[:-1] = key[1:] != key[:-1]
    # attribute inheritance within equal-key runs (at most 2 entries)
    if len(key) > 1:
        same_prev = key[1:] == key[:-1]
        inherit = same_prev & (val[1:] == -1) & (op[1:] == 1)
        val[1:][inherit] = val[:-1][inherit]
    src, dst, op, val = src[last], dst[last], op[last], val[last]
    n = len(src)
    cap = cap if cap is not None else max(n, 1)
    cap = max(cap, n)
    out = (
        np.full(cap, SENTINEL, np.int32),
        np.full(cap, SENTINEL, np.int32),
        np.zeros(cap, np.int8),
        np.full(cap, -1, np.int32),
    )
    out[0][:n], out[1][:n], out[2][:n], out[3][:n] = src, dst, op, val
    return out


def delta_sum(a: Delta, b: Delta, ecap: Optional[int] = None) -> Delta:
    """Paper Def. 4: Δs = a + b (b's components win on id collision)."""
    valid, present, attrs = _node_sum(a, b)
    e_src, e_dst, e_op, e_val = _edge_sum(a, b, ecap)
    return Delta(valid, present, attrs, e_src, e_dst, e_op, e_val)


def delta_intersection(a: Delta, b: Delta) -> Delta:
    """Paper Def. 5: components equal in both (used to build parents in
    the derived-snapshot hierarchy)."""
    same = (
        a.valid
        & b.valid
        & (a.present == b.present)
        & (a.attrs == b.attrs).all(-1)
    )
    valid = same
    present = np.where(same, a.present, 0).astype(np.int8)
    attrs = np.where(same[..., None], a.attrs, -1)
    # edges: sorted set intersection on (key, op, val)
    na = int((a.e_src != SENTINEL).sum())
    nb = int((b.e_src != SENTINEL).sum())
    ka = _edge_key(a.e_src[:na], a.e_dst[:na])
    kb = _edge_key(b.e_src[:nb], b.e_dst[:nb])
    common, ia, ib = np.intersect1d(ka, kb, return_indices=True)
    eq = (a.e_op[ia] == b.e_op[ib]) & (a.e_val[ia] == b.e_val[ib])
    ia = ia[eq]
    n = len(ia)
    cap = max(n, 1)
    e_src = np.full(cap, SENTINEL, np.int32)
    e_dst = np.full(cap, SENTINEL, np.int32)
    e_op = np.zeros(cap, np.int8)
    e_val = np.full(cap, -1, np.int32)
    e_src[:n], e_dst[:n] = a.e_src[ia], a.e_dst[ia]
    e_op[:n], e_val[:n] = a.e_op[ia], a.e_val[ia]
    return Delta(valid, present, attrs, e_src, e_dst, e_op, e_val)


def delta_difference(a: Delta, b: Delta) -> Delta:
    """a - b: components of a not present (identically) in b.  Satisfies
    (a ∩ b) + (a - (a ∩ b)) == a — the hierarchy reconstruction identity."""
    same = (
        a.valid
        & b.valid
        & (a.present == b.present)
        & (a.attrs == b.attrs).all(-1)
    )
    keep = a.valid & ~same
    valid = keep
    present = np.where(keep, a.present, 0).astype(np.int8)
    attrs = np.where(keep[..., None], a.attrs, -1)
    na = int((a.e_src != SENTINEL).sum())
    nb = int((b.e_src != SENTINEL).sum())
    ka = _edge_key(a.e_src[:na], a.e_dst[:na])
    kb = _edge_key(b.e_src[:nb], b.e_dst[:nb])
    # positions of a-edges identically present in b
    pos = np.searchsorted(kb, ka)
    pos_c = np.clip(pos, 0, max(nb - 1, 0))
    same_e = np.zeros(na, bool)
    if nb:
        same_e = (
            (kb[pos_c] == ka)
            & (b.e_op[pos_c] == a.e_op[:na])
            & (b.e_val[pos_c] == a.e_val[:na])
        )
    ia = np.nonzero(~same_e)[0]
    n = len(ia)
    cap = max(n, 1)
    e_src = np.full(cap, SENTINEL, np.int32)
    e_dst = np.full(cap, SENTINEL, np.int32)
    e_op = np.zeros(cap, np.int8)
    e_val = np.full(cap, -1, np.int32)
    e_src[:n], e_dst[:n] = a.e_src[ia], a.e_dst[ia]
    e_op[:n], e_val[:n] = a.e_op[ia], a.e_val[ia]
    return Delta(valid, present, attrs, e_src, e_dst, e_op, e_val)


def deltas_equal(a: Delta, b: Delta) -> bool:
    if not (
        (a.valid == b.valid).all()
        and (np.where(a.valid, a.present, 0) == np.where(b.valid, b.present, 0)).all()
        and (np.where(a.valid[..., None], a.attrs, -1)
             == np.where(b.valid[..., None], b.attrs, -1)).all()
    ):
        return False
    na = int((a.e_src != SENTINEL).sum())
    nb = int((b.e_src != SENTINEL).sum())
    if na != nb:
        return False
    return (
        (a.e_src[:na] == b.e_src[:nb]).all()
        and (a.e_dst[:na] == b.e_dst[:nb]).all()
        and (a.e_op[:na] == b.e_op[:nb]).all()
        and (a.e_val[:na] == b.e_val[:nb]).all()
    )
