"""Dynamic graph partitioning (paper §4.5).

A timespan's event stream is projected to a single weighted static graph
with a time-collapse function Ω ∈ {median, union-max, union-mean}, then
statically partitioned.  The paper's default — Union-Max edge weights +
uniform node weights — is ours too.

The static partitioner is a streaming LDG-style greedy (BFS order,
capacity-penalized neighbor affinity) followed by bounded
Kernighan-Lin-style refinement sweeps; pure numpy, runs at timespan
boundaries on the host (control plane — the TPU only consumes the
resulting layout).  1-hop edge-cut replication (paper Fig. 5d) is
computed here and stored as auxiliary micro-deltas by the TGI builder.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.events import EDGE_ADD, EDGE_DEL, EventLog


# ---------------------------------------------------------------------------
# Ω time-collapse (paper §4.5: Median / Union-Max / Union-Mean)
# ---------------------------------------------------------------------------


def collapse(events: EventLog, omega: str = "union_max",
             t0: Optional[int] = None, t1: Optional[int] = None):
    """Project a timespan's edge events to a static weighted edge list.

    Returns (src, dst, weight) numpy arrays (canonical src<dst, unique).
    Weight semantics: presence duration/max as per Ω; an edge deleted and
    never re-added ends with weight 0 under 'median' at a t where absent.
    """
    t0 = events.t[0] if (t0 is None and len(events)) else (t0 or 0)
    t1 = events.t[-1] if (t1 is None and len(events)) else (t1 or 0)
    is_edge = (events.kind == EDGE_ADD) | (events.kind == EDGE_DEL)
    ev = events.take(np.nonzero(is_edge)[0])
    if not len(ev):
        z = np.empty(0, np.int32)
        return z, z, np.empty(0, np.float32)
    key = ev.src.astype(np.int64) * (2**31) + ev.dst.astype(np.int64)
    if omega == "median":
        tm = (int(t0) + int(t1)) // 2
        upto = ev.up_to(tm)
        key_m = upto.src.astype(np.int64) * (2**31) + upto.dst.astype(np.int64)
        # last op per edge decides presence at median time
        order = np.arange(len(upto))
        last = {}
        for i in order:  # small per-timespan streams; clarity over speed
            last[key_m[i]] = i
        idx = np.array([i for k, i in last.items() if upto.kind[i] == EDGE_ADD], int)
        if not len(idx):
            z = np.empty(0, np.int32)
            return z, z, np.empty(0, np.float32)
        w = np.where(upto.val[idx] >= 0, upto.val[idx], 1).astype(np.float32)
        return upto.src[idx], upto.dst[idx], w
    # union variants: any edge that ever existed in the span
    uniq, inv = np.unique(key, return_inverse=True)
    w_ev = np.where(ev.val >= 0, ev.val, 1).astype(np.float32)
    if omega == "union_max":
        w = np.zeros(len(uniq), np.float32)
        np.maximum.at(w, inv, np.where(ev.kind == EDGE_ADD, w_ev, 0.0))
    elif omega == "union_mean":
        # time-fraction weighted mean presence; approximate with fraction
        # of span the edge is present times its (last) weight
        span = max(int(t1) - int(t0), 1)
        present_time = np.zeros(len(uniq), np.float64)
        last_on = np.full(len(uniq), -1, np.int64)
        for i in range(len(ev)):  # chronological
            e = inv[i]
            if ev.kind[i] == EDGE_ADD and last_on[e] < 0:
                last_on[e] = ev.t[i]
            elif ev.kind[i] == EDGE_DEL and last_on[e] >= 0:
                present_time[e] += ev.t[i] - last_on[e]
                last_on[e] = -1
        still = last_on >= 0
        present_time[still] += int(t1) - last_on[still]
        w = (present_time / span).astype(np.float32)
    else:
        raise ValueError(omega)
    src = (uniq // (2**31)).astype(np.int32)
    dst = (uniq % (2**31)).astype(np.int32)
    keep = w > 0
    return src[keep], dst[keep], w[keep]


# ---------------------------------------------------------------------------
# Static partitioning
# ---------------------------------------------------------------------------


def edge_cut(src, dst, assign) -> int:
    return int((assign[src] != assign[dst]).sum())


def partition_graph(node_ids: np.ndarray, src: np.ndarray, dst: np.ndarray,
                    weights: Optional[np.ndarray], k: int,
                    refine_sweeps: int = 2, seed: int = 0) -> np.ndarray:
    """Returns assignment (len(node_ids),) in [0,k) — balanced (ceil/floor)
    min-cut heuristic.  node_ids sorted unique; src/dst are node *ids*."""
    n = len(node_ids)
    if n == 0:
        return np.empty(0, np.int32)
    idx_of = {int(v): i for i, v in enumerate(node_ids)}
    s = np.array([idx_of[int(x)] for x in src], np.int64) if len(src) else np.empty(0, np.int64)
    d = np.array([idx_of[int(x)] for x in dst], np.int64) if len(src) else np.empty(0, np.int64)
    w = (weights if weights is not None else np.ones(len(s), np.float32))
    cap = int(np.ceil(n / k))

    # adjacency (CSR over both directions)
    deg_src = np.concatenate([s, d])
    deg_dst = np.concatenate([d, s])
    deg_w = np.concatenate([w, w])
    order = np.argsort(deg_src, kind="stable")
    adj_src = deg_src[order]
    adj_dst = deg_dst[order]
    adj_w = deg_w[order]
    indptr = np.searchsorted(adj_src, np.arange(n + 1))

    assign = np.full(n, -1, np.int32)
    sizes = np.zeros(k, np.int64)
    rng = np.random.RandomState(seed)

    # BFS order from highest-degree seeds (locality streaming)
    degs = np.diff(indptr)
    visit_order = []
    visited = np.zeros(n, bool)
    for root in np.argsort(-degs):
        if visited[root]:
            continue
        stack = [int(root)]
        visited[root] = True
        while stack:
            u = stack.pop()
            visit_order.append(u)
            for j in range(indptr[u], indptr[u + 1]):
                v = int(adj_dst[j])
                if not visited[v]:
                    visited[v] = True
                    stack.append(v)

    for u in visit_order:
        aff = np.zeros(k, np.float64)
        for j in range(indptr[u], indptr[u + 1]):
            v = int(adj_dst[j])
            if assign[v] >= 0:
                aff[assign[v]] += adj_w[j]
        penalty = 1.0 - sizes / cap  # LDG balance term
        score = aff * np.maximum(penalty, 0.0) + 1e-9 * penalty
        full = sizes >= cap
        score[full] = -np.inf
        p = int(np.argmax(score))
        if np.isinf(score[p]):
            p = int(np.argmin(sizes))
        assign[u] = p
        sizes[p] += 1

    # bounded KL-style refinement: move nodes whose gain > 0, respecting caps
    for _ in range(refine_sweeps):
        moved = 0
        for u in rng.permutation(n):
            cur = assign[u]
            aff = np.zeros(k, np.float64)
            for j in range(indptr[u], indptr[u + 1]):
                v = int(adj_dst[j])
                aff[assign[v]] += adj_w[j]
            best = int(np.argmax(aff))
            if best != cur and aff[best] > aff[cur] and sizes[best] < cap:
                assign[u] = best
                sizes[cur] -= 1
                sizes[best] += 1
                moved += 1
        if not moved:
            break
    return assign


def partition_timespan(events: EventLog, n_parts: int, strategy: str = "hash",
                       omega: str = "union_max", seed: int = 0):
    """Returns (node_ids, assignment or None).  strategy 'hash' returns
    None (SlotMap hashes); 'locality' runs Ω-collapse + min-cut."""
    nids = np.unique(np.concatenate([
        events.src, events.dst[events.dst >= 0]
    ])) if len(events) else np.empty(0, np.int32)
    nids = nids[nids >= 0].astype(np.int32)
    if strategy == "hash":
        return nids, None
    src, dst, w = collapse(events, omega)
    assign = partition_graph(nids, src, dst, w, n_parts, seed=seed)
    return nids, assign


def replication_lists(src, dst, assign_of) -> Dict[int, np.ndarray]:
    """1-hop edge-cut replication: for each partition p, the set of
    *external* neighbor node-ids that its nodes connect to (stored as
    auxiliary micro-deltas so snapshot/node reads are unaffected)."""
    out: Dict[int, list] = {}
    ps, pd = assign_of(src), assign_of(dst)
    cut = ps != pd
    for p in np.unique(np.concatenate([ps, pd])):
        ext = np.concatenate([dst[cut & (ps == p)], src[cut & (pd == p)]])
        out[int(p)] = np.unique(ext)
    return {p: v for p, v in out.items()}
