"""Ingest subsystem (paper §4.4 'Construction and Update').

One write path for everything between the event log and the read path:

* ``SpanBuilder`` — cuts one timespan into micro-eventlist buckets and
  derived-hierarchy checkpoints, owns the SlotMap / locality
  partitioning, and emits every store key (``E:*`` eventlists, ``S:*``
  hierarchy deltas, ``X:*`` aux replicas).  ``TGI.build``, ``TGI.update``,
  the streaming ``TGI.append`` front-end, and ``TGI.compact`` all go
  through it, so batch construction, incremental update, and compaction
  can never diverge (the old ``update`` was a hand-copied ``_build_from``
  that silently dropped locality partitioning and 1-hop replication).
* ``span_bucket_arrays`` — vectorized per-event (tsid, bucket) placement
  for a span list (replaces the per-event Python loop the old
  ``_bucket_of_old`` ran on every update).
* ``CompactionStats`` — the result record of ``TGI.compact()``: span
  counts, deleted/rewritten store bytes, and the fetch cost of the reads
  compaction issued (surfaced as ``HistoricalGraphStore.last_cost``).

Read-cache coherence: every write this subsystem emits goes through
``DeltaStore.put`` and every GC through ``DeltaStore.delete``, both of
which invalidate the store's decoded-block buffer pool per key — so
build/update/append/compact can never leave stale decoded columns
behind, and scoped snapshot-LRU invalidation (``t_from``/``t_ranges``)
never needs to touch the pool.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import numpy as np

from repro.core import partition as part_mod
from repro.core.delta import Delta
from repro.core.events import EventLog
from repro.core.slots import SlotMap, hash32
from repro.core.snapshot import GraphState
from repro.core.timespan import TimeSpan
from repro.storage.kvstore import DeltaKey, DeltaStore


@dataclasses.dataclass
class CompactionStats:
    """What one ``TGI.compact()`` pass did.  ``cost`` is the fetch cost of
    the snapshot reads compaction issued to seed each merged run's
    starting state (its write/delete I/O is in the byte counters)."""

    spans_before: int = 0
    spans_after: int = 0
    runs_merged: int = 0
    events_rewritten: int = 0
    keys_deleted: int = 0
    bytes_deleted: int = 0  # encoded bytes GC'd off the store (x r)
    bytes_written: int = 0  # encoded bytes of the rewritten spans (x r)
    cost: object = None  # FetchCost of compaction's own reads

    @property
    def span_reduction(self) -> float:
        return self.spans_before / max(self.spans_after, 1)


def span_bucket_arrays(spans) -> Tuple[np.ndarray, np.ndarray]:
    """Per-event ``(span_of_event, bucket_of_event)`` for a list of
    ``SpanIndex`` — pure bounds arithmetic (``np.repeat`` over the bucket
    ranges), no per-event Python loop."""
    tsids, buckets = [], []
    for s in spans:
        bounds = np.asarray(s.bucket_bounds, np.int64).reshape(-1, 2)
        sizes = bounds[:, 1] - bounds[:, 0]
        n_ev = int(sizes.sum())
        tsids.append(np.full(n_ev, s.span.tsid, np.int32))
        buckets.append(np.repeat(np.arange(len(bounds), dtype=np.int32), sizes))
    if not tsids:
        z = np.empty(0, np.int32)
        return z, z.copy()
    return np.concatenate(tsids), np.concatenate(buckets)


class SpanBuilder:
    """Shared span write path.  ``build_span`` consumes one timespan's
    events, mutates the running ``GraphState`` forward, and writes the
    span's eventlists, hierarchy, and aux replicas to the store."""

    def __init__(self, cfg, store: DeltaStore):
        self.cfg = cfg
        self.store = store

    def _sid_of_pid(self, pid: int) -> int:
        return pid // self.cfg.parts_per_shard

    # ------------------------------------------------------------------
    # Partitioning (hash | locality), frozen per span
    # ------------------------------------------------------------------

    def partition_span(self, tsid: int, ev_span: EventLog,
                       state: GraphState) -> SlotMap:
        """SlotMap for one span: nodes alive at span start plus nodes the
        span's events touch; ``cfg.partition_strategy`` decides layout
        (the locality path applies to update/append spans too — the old
        ``TGI.update`` silently fell back to hash)."""
        cfg = self.cfg
        if len(ev_span):
            touched = np.unique(np.concatenate([
                ev_span.src, ev_span.dst[ev_span.dst >= 0], state.node_ids(),
            ]))
        else:
            touched = state.node_ids()
        touched = touched[touched >= 0]
        assignment = None
        if cfg.partition_strategy == "locality" and len(ev_span):
            nids_l, assignment = part_mod.partition_timespan(
                ev_span, cfg.n_parts, "locality", cfg.omega, seed=tsid
            )
            # locality assigns only nodes touched by edges; extend to the
            # full touched set with hash placement
            if len(nids_l) < len(touched):
                assign_full = (hash32(touched) % np.uint32(cfg.n_parts)).astype(np.int32)
                pos = np.searchsorted(touched, nids_l)
                assign_full[pos] = assignment
                assignment = assign_full
        return SlotMap.build(touched, cfg.n_parts, assignment, cfg.pad_multiple)

    # ------------------------------------------------------------------
    # Span construction
    # ------------------------------------------------------------------

    def build_span(self, sp: TimeSpan, ev_span: EventLog,
                   state: GraphState):
        """Build one span.  ``sp.ev_lo/ev_hi`` are *global* event-log
        offsets; ``ev_span`` is the span-local slice (``ev_hi - ev_lo``
        events).  Returns ``(SpanIndex, bucket_of_event)`` with
        ``bucket_of_event`` aligned to ``ev_span``; ``state`` is advanced
        to the span end in place."""
        from repro.core.tgi import SpanIndex  # cycle: tgi imports ingest

        cfg = self.cfg
        n_ev = sp.ev_hi - sp.ev_lo
        assert n_ev == len(ev_span)
        smap = self.partition_span(sp.tsid, ev_span, state)
        n_buckets = max(math.ceil(n_ev / cfg.eventlist_size), 1)
        ckpt_every = max(math.ceil(n_buckets / cfg.checkpoints_per_span), 1)
        checkpoint_ts: List[int] = [sp.t_start - 1]
        leaves: List[Delta] = [state.to_delta(smap, cfg.n_attrs)]
        # aux replicas are derived from the state at the LAST checkpoint
        aux_state = state.copy() if cfg.replicate_1hop else None
        bucket_bounds: List[Tuple[int, int]] = []
        bucket_of = np.zeros(n_ev, np.int32)
        for b in range(n_buckets):
            lo = b * cfg.eventlist_size
            hi = min((b + 1) * cfg.eventlist_size, n_ev)
            bucket_bounds.append((sp.ev_lo + lo, sp.ev_lo + hi))
            bucket_of[lo:hi] = b
            ev_b = ev_span.take(slice(lo, hi))
            self._store_eventlist(sp.tsid, b, ev_b, smap)
            state.apply_bucket(ev_b)
            # checkpoints only at bucket boundaries that don't split a
            # timestamp — otherwise later same-t events would be in
            # neither the checkpoint nor the (t > t_ck) replay filter
            if ((b + 1) % ckpt_every == 0 and b + 1 < n_buckets
                    and ev_span.t[hi - 1] != ev_span.t[hi]):
                checkpoint_ts.append(int(ev_span.t[hi - 1]))
                leaves.append(state.to_delta(smap, cfg.n_attrs))
                if aux_state is not None:
                    aux_state = state.copy()
        self._store_hierarchy(sp.tsid, leaves, smap)
        if aux_state is not None:
            self._store_aux_replication(sp.tsid, aux_state, smap)
        return (
            SpanIndex(span=sp, smap=smap, checkpoint_ts=checkpoint_ts,
                      bucket_bounds=bucket_bounds),
            bucket_of,
        )

    # ------------------------------------------------------------------
    # Store emission (moved verbatim from the old TGI write path)
    # ------------------------------------------------------------------

    def _store_eventlist(self, tsid: int, bucket: int, ev: EventLog,
                         smap: SlotMap) -> None:
        """Partitioned eventlists: events replicated to both endpoints'
        shards, pid column included for micro-partition filtering."""
        if not len(ev):
            return
        pid_src, _, _ = smap.lookup(ev.src)
        pid_dst = np.full(len(ev), -1, np.int32)
        has_dst = ev.dst >= 0
        if has_dst.any():
            pid_dst[has_dst] = smap.lookup(ev.dst[has_dst])[0]
        ppl = self.cfg.parts_per_shard
        for sid in range(self.cfg.n_shards):
            in_shard = (pid_src // ppl == sid) | ((pid_dst >= 0) & (pid_dst // ppl == sid))
            idx = np.nonzero(in_shard)[0]
            if not len(idx):
                continue
            sub = ev.take(idx)
            arrays = sub.to_dict()
            arrays["pid"] = pid_src[idx] % ppl
            self.store.put(DeltaKey(tsid, sid, f"E:{bucket}", 0), arrays)

    def _delta_arrays(self, d: Delta, p: int):
        """Micro-delta = one partition slice of a Delta.  Edge runs are
        keyed by global slot, so partition p's run is a contiguous
        [p*psize, (p+1)*psize) range of the sorted e_src."""
        psize = d.valid.shape[1]
        lo = np.searchsorted(d.e_src, p * psize)
        hi = np.searchsorted(d.e_src, (p + 1) * psize)
        return {
            "valid": d.valid[p],
            "present": d.present[p],
            "attrs": d.attrs[p],
            "e_src": d.e_src[lo:hi],
            "e_dst": d.e_dst[lo:hi],
            "e_op": d.e_op[lo:hi],
            "e_val": d.e_val[lo:hi],
        }

    def _store_delta(self, tsid: int, did: str, d: Delta) -> None:
        for p in range(self.cfg.n_parts):
            sid = self._sid_of_pid(p)
            self.store.put(
                DeltaKey(tsid, sid, did, p % self.cfg.parts_per_shard),
                self._delta_arrays(d, p),
            )

    def _store_hierarchy(self, tsid: int, leaves: List[Delta],
                         smap: SlotMap) -> None:
        """DeltaGraph-style binary intersection tree; store root + all
        parent->child differences (paper §4.3b)."""
        from repro.core.delta import delta_difference, delta_intersection

        level = 0
        nodes = leaves
        while len(nodes) > 1:
            parents = []
            for i in range(0, len(nodes), 2):
                if i + 1 < len(nodes):
                    parent = delta_intersection(nodes[i], nodes[i + 1])
                    self._store_delta(tsid, f"S:{level}:{i}",
                                      delta_difference(nodes[i], parent))
                    self._store_delta(tsid, f"S:{level}:{i+1}",
                                      delta_difference(nodes[i + 1], parent))
                else:
                    # odd tail: node is its own parent; store an empty diff
                    # so the root->leaf path naming stays uniform
                    parent = nodes[i]
                    self._store_delta(tsid, f"S:{level}:{i}",
                                      delta_difference(nodes[i], nodes[i]))
                parents.append(parent)
            nodes = parents
            level += 1
        self._store_delta(tsid, f"S:{level}:0", nodes[0])  # root, stored fully

    def _store_aux_replication(self, tsid: int, g: GraphState,
                               smap: SlotMap) -> None:
        """Aux micro-deltas with 1-hop external neighbors per partition."""
        src, dst, val = g.edges()
        pid_s, _, _ = smap.lookup(src)
        pid_d, _, _ = smap.lookup(dst)
        cut = pid_s != pid_d
        for p in range(self.cfg.n_parts):
            sel = cut & ((pid_s == p) | (pid_d == p))
            if not sel.any():
                continue
            self.store.put(
                DeltaKey(tsid, self._sid_of_pid(p), "X:0",
                         p % self.cfg.parts_per_shard),
                {"src": src[sel], "dst": dst[sel], "val": val[sel]},
            )
