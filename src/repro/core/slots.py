"""Per-timespan node -> (partition, slot) assignment.

The paper freezes the node->partition function f_i within a timespan
(§4.5); we additionally freeze a *slot* index inside the partition, which
is what makes dense slot-aligned deltas (and the elementwise Δ-sum
overlay) possible.  Slot maps are rebuilt at timespan boundaries exactly
where the paper re-partitions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


def hash32(x: np.ndarray) -> np.ndarray:
    """Deterministic avalanche hash (splitmix-style) for balanced
    node->shard placement (the paper's 'random function of the node-id')."""
    x = x.astype(np.uint32)
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * np.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


@dataclasses.dataclass
class SlotMap:
    """node-id -> (pid, slot) for one timespan.

    node_ids is sorted; (pid, slot) parallel arrays.  psize is uniform
    across partitions (padded) — BlockSpec-friendly.
    """

    node_ids: np.ndarray  # (N,) int32 sorted
    pid: np.ndarray  # (N,) int32
    slot: np.ndarray  # (N,) int32
    n_parts: int
    psize: int

    @classmethod
    def build(cls, node_ids: np.ndarray, n_parts: int,
              assignment: Optional[np.ndarray] = None,
              pad_multiple: int = 128) -> "SlotMap":
        """assignment: optional node->partition (locality partitioner);
        default = hash partitioning."""
        node_ids = np.unique(np.asarray(node_ids, np.int32))
        if assignment is None:
            pid = (hash32(node_ids) % np.uint32(n_parts)).astype(np.int32)
        else:
            pid = np.asarray(assignment, np.int32)
            assert len(pid) == len(node_ids)
        # slot = rank within partition (stable by node id)
        order = np.lexsort((node_ids, pid))
        slot = np.empty(len(node_ids), np.int32)
        ranks = np.arange(len(node_ids), dtype=np.int32)
        # rank within each pid group
        pid_sorted = pid[order]
        group_start = np.zeros(len(node_ids), np.int64)
        if len(node_ids):
            starts = np.r_[0, np.nonzero(np.diff(pid_sorted))[0] + 1]
            sizes = np.diff(np.r_[starts, len(node_ids)])
            within = ranks - np.repeat(starts, sizes)
            slot[order] = within.astype(np.int32)
        counts = np.bincount(pid, minlength=n_parts) if len(node_ids) else np.zeros(n_parts, int)
        psize = int(counts.max()) if len(node_ids) else pad_multiple
        psize = max(((psize + pad_multiple - 1) // pad_multiple) * pad_multiple, pad_multiple)
        return cls(node_ids=node_ids, pid=pid, slot=slot, n_parts=n_parts, psize=psize)

    def lookup(self, nids: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (pid, slot, found_mask) for query node ids."""
        nids = np.asarray(nids, np.int32)
        pos = np.searchsorted(self.node_ids, nids)
        pos_c = np.clip(pos, 0, max(len(self.node_ids) - 1, 0))
        found = np.zeros(len(nids), bool)
        if len(self.node_ids):
            found = self.node_ids[pos_c] == nids
        pid = np.where(found, self.pid[pos_c], -1).astype(np.int32)
        slot = np.where(found, self.slot[pos_c], -1).astype(np.int32)
        return pid, slot, found

    def reverse(self) -> np.ndarray:
        """(n_parts, psize) int32 table: slot -> node id (-1 = empty)."""
        table = np.full((self.n_parts, self.psize), -1, np.int32)
        table[self.pid, self.slot] = self.node_ids
        return table

    def n_nodes(self) -> int:
        return len(self.node_ids)
