"""ShapeDtypeStruct stand-ins for every model input / state tree.

``input_specs(cfg, shape)`` produces exactly what each lowered step
consumes — weak-type-correct, shardable, and never allocated.  The same
functions back the dry-run, the benchmarks, and the elastic launcher's
restore-time shape checks.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.models.common import Init
from repro.models.sharding import Sharder, split_tree
from repro.optim import adamw


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training / prefill batch: tokens + labels (+ stub modality inputs)."""
    B, S = shape.global_batch, shape.seq_len
    n_img = cfg.n_img_tokens or 0
    n_txt = S - n_img
    specs = {"tokens": jax.ShapeDtypeStruct((B, n_txt), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, n_txt), jnp.int32)
    if n_img:
        specs["img_embeds"] = jax.ShapeDtypeStruct((B, n_img, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        specs["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, model_axis: int):
    """(cache_specs ParamLeaf tree, tokens, pos) for one decode step with a
    cache of shape.seq_len entries."""
    B, S = shape.global_batch, shape.seq_len
    ini = Init(rng=jax.random.PRNGKey(0), abstract=True)
    cache = lm.init_cache(ini, cfg, B, S, model_axis)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    return cache, tokens, pos


def abstract_params(cfg: ModelConfig, max_seq: int):
    """ParamLeaf tree of ShapeDtypeStructs (values, axes)."""
    return lm.init(jax.random.PRNGKey(0), cfg, max_seq=max_seq, abstract=True)


def abstract_opt_state(params_sds):
    return jax.eval_shape(adamw.init, params_sds)


def opt_state_shardings(param_shardings, mesh):
    from jax.sharding import NamedSharding, PartitionSpec

    return {
        "m": param_shardings,
        "v": param_shardings,
        "count": NamedSharding(mesh, PartitionSpec()),
    }


def batch_shardings(specs: Dict[str, jax.ShapeDtypeStruct], shd: Sharder):
    out = {}
    for k, v in specs.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = shd.param_sharding(v, axes)
    return out


def n_params(params_sds) -> int:
    import math

    # python ints (jnp.prod overflows int32 on stacked-layer leaves)
    return sum(math.prod(l.shape) for l in jax.tree.leaves(params_sds))


def n_active_params(cfg: ModelConfig, params_sds) -> int:
    """Active params per token (MoE: top_k of n_experts expert params)."""
    total = n_params(params_sds)
    if not cfg.is_moe:
        return total
    # expert weights are the (..., E, D, F) tensors under 'ffn'
    flat = jax.tree_util.tree_flatten_with_path(params_sds)[0]
    expert_total = 0
    import math

    for path, leaf in flat:
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if any(n in ("w_gate", "w_up", "w_down") for n in names) and len(leaf.shape) >= 3:
            expert_total += math.prod(leaf.shape)
    dense = total - expert_total
    return dense + expert_total * cfg.top_k // cfg.n_experts
