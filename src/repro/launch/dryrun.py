import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init, and the production meshes need 512 placeholder CPU devices.  Do NOT
export this flag globally: smoke tests and benches see 1 device.

Per cell this script:
  1. builds the production mesh (16x16 or 2x16x16),
  2. constructs abstract params / optimizer / batch / cache specs
     (ShapeDtypeStruct — nothing is allocated),
  3. jits the train_step / prefill_step / serve_step with explicit
     in/out shardings + donation,
  4. .lower().compile()s, printing memory_analysis() and cost_analysis(),
  5. parses collective traffic from the compiled HLO and writes one JSON
     artifact under experiments/dryrun/ for the roofline table.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as specs_mod
from repro.models.sharding import (
    LONG_CONTEXT_OVERRIDES,
    Sharder,
    make_rules,
    split_tree,
)
from repro.optim import adamw
from repro.roofline import compute_roofline, model_flops, summarize_collectives
from repro.roofline import analytic
from repro.train import make_prefill_step, make_serve_step, make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# XLA's cost analysis counts while-loop bodies ONCE, so the scanned
# compile under-reports flops/bytes/collectives by ~n_units.  The cost
# PROBE compiles 1-unit and 2-unit UNROLLED variants (direct attention, no
# inner scans) and extrapolates linearly: total = c1 + (n_units-1)*(c2-c1).
# Exact for per-layer-linear costs; the sLSTM per-timestep scan is added
# analytically (see repro.roofline.analytic).


def cell_supported(cfg, shape) -> (bool, str):
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "pure full-attention arch: 500k-token decode has no sub-quadratic "
            "path (unbounded KV); skipped per DESIGN.md §Arch-applicability"
        )
    return True, ""


def lower_cell(arch: str, shape_name: str, multi_pod: bool, extra_rules=None,
               cfg_overrides=None, skip_masked_blocks: bool = False):
    """Lower+compile one cell; returns the result record dict."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "SKIP", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    model_axis = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    overrides = dict(LONG_CONTEXT_OVERRIDES) if shape_name == "long_500k" else {}
    if extra_rules:
        overrides.update(extra_rules)
    rules = make_rules(**overrides)
    shd = Sharder(mesh=mesh, rules=rules)

    t0 = time.time()
    if shape.kind == "train":
        params_pl = specs_mod.abstract_params(cfg, max_seq=shape.seq_len)
        params_sds, axes = split_tree(params_pl)
        p_sh = shd.tree_shardings(params_sds, axes)
        opt_sds = specs_mod.abstract_opt_state(params_sds)
        o_sh = specs_mod.opt_state_shardings(p_sh, mesh)
        bspecs = specs_mod.batch_specs(cfg, shape)
        b_sh = specs_mod.batch_shardings(bspecs, shd)
        step = make_train_step(cfg, shd, skip_masked_blocks=skip_masked_blocks)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_sds, opt_sds, bspecs)
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        scfg = cfg.replace(param_dtype=cfg.dtype)  # serve in bf16
        params_pl = specs_mod.abstract_params(scfg, max_seq=shape.seq_len)
        params_sds, axes = split_tree(params_pl)
        p_sh = shd.tree_shardings(params_sds, axes)
        bspecs = specs_mod.batch_specs(scfg, shape)
        b_sh = specs_mod.batch_shardings(bspecs, shd)
        step = make_prefill_step(scfg, shd, model_axis, cache_len=shape.seq_len)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(params_sds, bspecs)
        cfg = scfg
        tokens = shape.global_batch * shape.seq_len
    else:  # decode
        scfg = cfg.replace(param_dtype=cfg.dtype)
        params_pl = specs_mod.abstract_params(scfg, max_seq=shape.seq_len)
        params_sds, axes = split_tree(params_pl)
        p_sh = shd.tree_shardings(params_sds, axes)
        cache_pl, tok_sds, pos_sds = specs_mod.decode_specs(scfg, shape, model_axis)
        cache_sds, cache_axes = split_tree(cache_pl)
        c_sh = shd.tree_shardings(cache_sds, cache_axes)
        tok_sh = shd.param_sharding(tok_sds, ("batch", None))
        pos_sh = shd.param_sharding(pos_sds, ("batch",))
        step = make_serve_step(scfg, shd)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
            out_shardings=(None, None, c_sh),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_sds, cache_sds, tok_sds, pos_sds)
        cfg = scfg
        tokens = shape.global_batch  # one token per sequence
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = summarize_collectives(hlo)

    n_par = specs_mod.n_params(params_sds)
    n_act = specs_mod.n_active_params(cfg, params_sds)
    mf = model_flops(shape.kind, n_act, tokens)
    roof = compute_roofline(cost, coll["wire_bytes"], mf, n_chips)

    rec = {
        "cfg_overrides": {k: str(v) for k, v in (cfg_overrides or {}).items()},
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "status": "OK",
        "n_chips": n_chips,
        "n_params": n_par,
        "n_active_params": n_act,
        "tokens_per_step": tokens,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_est": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost": {k: v for k, v in cost.items() if k in ("flops", "bytes accessed", "transcendentals")},
        "collectives": coll,
        "roofline": roof.to_dict(),
    }
    return rec


def probe_costs(arch, shape_name, multi_pod, extra_rules=None, base_overrides=None,
                skip_masked_blocks=False):
    """Linear-extrapolated per-device costs from unrolled 1/2-unit probes."""
    cfg = get_config(arch)
    if base_overrides:
        cfg = cfg.replace(**base_overrides)
    shape = SHAPES[shape_name]
    unit, rem = cfg.unit_len, cfg.n_rem_layers
    n_units = cfg.n_units
    probe_base = {"scan_layers": False, "attn_impl": "direct"}
    if base_overrides:
        probe_base.update(base_overrides)

    def one(k_dec: int, k_enc: int):
        ov = dict(probe_base, n_layers=k_dec * unit + rem)
        if cfg.is_encdec:
            ov["n_enc_layers"] = k_enc
        rec = lower_cell(arch, shape_name, multi_pod, extra_rules=extra_rules,
                         cfg_overrides=ov, skip_masked_blocks=skip_masked_blocks)
        return rec

    r1 = one(1, 1)
    r2 = one(2, 1)
    r3 = one(1, 2) if (cfg.is_encdec and cfg.n_enc_layers > 1) else None

    def metric(rec, path):
        d = rec
        for p in path:
            d = d[p]
        return float(d or 0.0)

    paths = {
        "flops": ("cost", "flops"),
        "bytes": ("cost", "bytes accessed"),
        "wire_bytes": ("collectives", "wire_bytes"),
        "operand_bytes": ("collectives", "operand_bytes"),
        "cross_pod_wire_bytes": ("collectives", "cross_pod_wire_bytes"),
    }
    out = {}
    for name, path in paths.items():
        c1, c2 = metric(r1, path), metric(r2, path)
        total = c1 + (n_units - 1) * (c2 - c1)
        if r3 is not None:
            c3 = metric(r3, path)
            total += (cfg.n_enc_layers - 1) * (c3 - c1)
        out[name] = max(total, 0.0)
        out[f"probe_{name}_1u"] = c1
        out[f"probe_{name}_2u"] = c2
    # analytic correction for per-timestep scans the probe cannot see
    n_chips = 512 if multi_pod else 256
    corr = analytic.slstm_scan_correction(
        cfg, shape.global_batch, shape.seq_len if shape.kind != "decode" else 1
    )
    out["flops"] += corr / n_chips
    out["slstm_corr_flops_per_dev"] = corr / n_chips
    return out


def _fix_encdec_probe(cfg):  # placeholder for clarity
    return cfg


def run_cell(arch, shape_name, multi_pod, skip_existing=False, verbose=True, tag="",
             with_probe=True):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    mesh_tag = "multipod" if multi_pod else "singlepod"
    suffix = f"_{tag}" if tag else ""
    fname = OUT_DIR / f"{arch}__{shape_name}__{mesh_tag}{suffix}.json"
    if skip_existing and fname.exists():
        print(f"[skip-existing] {fname.name}")
        return json.loads(fname.read_text())
    try:
        rec = lower_cell(arch, shape_name, multi_pod)
        shape_kind = SHAPES[shape_name].kind
        # Cells where the unrolled probe is unnecessary or pathological:
        #  - decode: per-layer math is simple;
        #  - mlstm/slstm archs at >4k seq: unrolling the chunk loop (128
        #    chunks at 32k) explodes compile time — the chunkwise math is
        #    exactly what the analytic model counts.
        pattern = get_config(arch).resolved_pattern
        analytic_only = shape_kind == "decode" or (
            any(k in ("mlstm", "slstm") for k in pattern)
            and SHAPES[shape_name].seq_len > 4096
        )
        if rec["status"] == "OK" and not multi_pod and analytic_only:
            # analytic flops/bytes + trip-weighted HLO collectives
            cfg = get_config(arch)
            shape = SHAPES[shape_name]
            an_flops = analytic.step_flops(cfg, shape.kind, shape.global_batch, shape.seq_len)
            an_bytes = analytic.step_bytes(cfg, shape.kind, shape.global_batch,
                                           shape.seq_len, chips=rec["n_chips"])
            rec["analytic"] = {
                "flops_global": an_flops,
                "flops_per_dev": an_flops / rec["n_chips"],
                "bytes_per_dev": an_bytes,
            }
            mf = model_flops(shape.kind, rec["n_active_params"], rec["tokens_per_step"])
            roof = compute_roofline(
                {"flops": an_flops / rec["n_chips"], "bytes accessed": an_bytes["total"]},
                rec["collectives"]["wire_bytes"], mf, rec["n_chips"],
            )
            rec["roofline"] = roof.to_dict()
            rec["roofline"]["source"] = "flops=analytic bytes=analytic collectives=weighted-hlo"
        elif rec["status"] == "OK" and with_probe and not multi_pod:
            # roofline table is single-pod only; probe there.
            # Three-source accounting (see EXPERIMENTS.md §Roofline):
            #   compute   <- unrolled 1u/2u probe extrapolation (exact matmul flops)
            #   collective<- trip-count-weighted parse of the REAL scanned HLO
            #   memory    <- itemized analytic HBM model (XLA 'bytes accessed'
            #                is not TPU-fusion-aware; kept as diagnostic)
            probe = probe_costs(arch, shape_name, multi_pod)
            rec["cost_probe"] = probe
            shape = SHAPES[shape_name]
            cfg = get_config(arch)
            an_flops = analytic.step_flops(
                cfg, shape.kind, shape.global_batch, shape.seq_len
            )
            an_bytes = analytic.step_bytes(
                cfg, shape.kind, shape.global_batch, shape.seq_len,
                chips=rec["n_chips"],
            )
            rec["analytic"] = {
                "flops_global": an_flops,
                "flops_per_dev": an_flops / rec["n_chips"],
                "probe_vs_analytic": (
                    probe["flops"] / (an_flops / rec["n_chips"])
                    if an_flops
                    else 0.0
                ),
                "bytes_per_dev": an_bytes,
            }
            mf = model_flops(shape.kind, rec["n_active_params"], rec["tokens_per_step"])
            roof = compute_roofline(
                {"flops": probe["flops"], "bytes accessed": an_bytes["total"]},
                rec["collectives"]["wire_bytes"],  # weighted real-HLO parse
                mf,
                rec["n_chips"],
            )
            rec["roofline"] = roof.to_dict()
            rec["roofline"]["source"] = (
                "flops=probe bytes=analytic collectives=weighted-hlo"
            )
    except Exception as e:  # a failure here is a sharding bug — record it
        rec = {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "status": "FAIL", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    fname.write_text(json.dumps(rec, indent=2, default=float))
    if verbose:
        s = rec["status"]
        if s == "OK":
            r = rec["roofline"]
            print(
                f"[{s}] {arch} x {shape_name} ({mesh_tag}): "
                f"compile={rec['compile_s']}s "
                f"mem/dev={rec['memory']['peak_bytes_est']/2**30:.2f}GiB "
                f"compute={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms "
                f"coll={r['collective_s']*1e3:.2f}ms dom={r['dominant']} "
                f"useful={r['useful_ratio']:.2f} mfu={r['mfu']:.3f}"
            )
        elif s == "SKIP":
            print(f"[{s}] {arch} x {shape_name} ({mesh_tag}): {rec['reason'][:90]}")
        else:
            print(f"[{s}] {arch} x {shape_name} ({mesh_tag}): {rec['error'][:200]}")
    sys.stdout.flush()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-probe", action="store_true",
                    help="compile-check only (skip the unrolled cost probe)")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    archs = list(ARCH_IDS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]

    n_fail = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, mp, skip_existing=args.skip_existing,
                               with_probe=not args.no_probe)
                n_fail += rec["status"] == "FAIL"
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
