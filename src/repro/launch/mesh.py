"""Production meshes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS *before* calling it.

Single pod: 16 x 16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the pod
axis carries data parallelism only (replicated params, gradient
all-reduce over the slow inter-pod links; see optim.compression).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(shape=None, axes=None):
    """Mesh over whatever devices actually exist (tests / local runs)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )
