"""End-to-end training driver.

CPU-runnable on reduced configs (the smoke/e2e path and example driver);
on a real pod the same loop runs with the production mesh and full
configs.  Integrates: model zoo, AdamW, deterministic pipeline, TGI
checkpoint store (periodic async saves), elastic restore on start.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --reduced --steps 30 --batch 8 --seq 64 --checkpoint-every 10
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, SyntheticLM
from repro.models import lm
from repro.models.sharding import Sharder, split_tree
from repro.optim import adamw
from repro.storage.checkpoint import CheckpointConfig, CheckpointStore
from repro.storage.kvstore import DeltaStore
from repro.train import make_train_step


def run(arch: str = "qwen3-1.7b", steps: int = 30, batch: int = 8, seq: int = 64,
        reduced: bool = True, checkpoint_every: int = 0, resume: bool = False,
        store: Optional[CheckpointStore] = None, seed: int = 0, log_every: int = 5,
        lr: float = 1e-3, stop_after: Optional[int] = None):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    shd = Sharder(mesh=None)
    ocfg = adamw.AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                             decay_steps=steps)

    rng = jax.random.PRNGKey(seed)
    params, _ = split_tree(lm.init(rng, cfg, max_seq=4 * seq))
    opt_state = adamw.init(params)
    start_step = 0
    if resume and store is not None and store.saves:
        (params, opt_state), start_step = store.restore(
            example_tree=(params, opt_state)
        )
        start_step += 1
        print(f"[resume] restored step {start_step - 1}")

    pipe_cfg = PipelineConfig(global_batch=batch, seq_len=seq,
                              vocab_size=cfg.vocab_size, n_shards=1)
    pipe = SyntheticLM(pipe_cfg, seed=seed)
    step_fn = jax.jit(make_train_step(cfg, shd, ocfg))

    losses = []
    pending = None
    end = min(steps, stop_after) if stop_after is not None else steps
    for step in range(start_step, end):
        batch_np = pipe.batch(step)
        if cfg.n_img_tokens:
            batch_np["img_embeds"] = np.zeros(
                (batch, cfg.n_img_tokens, cfg.d_model), np.float32
            )
        if cfg.is_encdec:
            batch_np["frames"] = (
                np.random.RandomState(step).randn(batch, cfg.enc_seq, cfg.d_model)
                .astype(np.float32) * 0.02
            )
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state,
                                             {k: jnp.asarray(v) for k, v in batch_np.items()})
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0:
            print(f"step {step:4d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} dt {time.time()-t0:.2f}s")
        if checkpoint_every and store is not None and (step + 1) % checkpoint_every == 0:
            if pending is not None:
                pending.result()  # backpressure: at most one in flight
            pending = store.save_async(step, (params, opt_state))
    if pending is not None:
        pending.result()
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()
    store = None
    if args.checkpoint_every:
        backend = "file" if args.checkpoint_dir else "mem"
        store = CheckpointStore(
            DeltaStore(m=4, r=2, backend=backend, root=args.checkpoint_dir)
        )
    _, _, losses = run(args.arch, args.steps, args.batch, args.seq,
                       args.reduced, args.checkpoint_every, store=store)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
