"""Batched serving driver: prefill a batch of prompts, then step-decode.

CPU-runnable on reduced configs; the same serve_step lowers on the
production meshes in the dry-run (decode_32k / long_500k cells).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.models.sharding import Sharder, split_tree
from repro.train import make_prefill_step, make_serve_step


def serve(arch: str = "qwen3-1.7b", batch: int = 4, prompt_len: int = 32,
          gen_tokens: int = 16, reduced: bool = True, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(param_dtype=cfg.dtype)  # serving precision
    shd = Sharder(mesh=None)
    max_seq = prompt_len + gen_tokens + 8
    params, _ = split_tree(lm.init(jax.random.PRNGKey(seed), cfg, max_seq=max_seq))

    rng = np.random.RandomState(seed)
    batch_in = {"tokens": rng.randint(0, cfg.vocab_size,
                                      size=(batch, prompt_len)).astype(np.int32)}
    if cfg.n_img_tokens:
        batch_in["img_embeds"] = np.zeros((batch, cfg.n_img_tokens, cfg.d_model), np.float32)
    if cfg.is_encdec:
        batch_in["frames"] = rng.randn(batch, cfg.enc_seq, cfg.d_model).astype(np.float32) * 0.02

    prefill = jax.jit(make_prefill_step(cfg, shd, model_axis=1, cache_len=max_seq))
    step = jax.jit(make_serve_step(cfg, shd))

    t0 = time.time()
    tok, cache = prefill(params, {k: jnp.asarray(v) for k, v in batch_in.items()})
    tok = np.asarray(tok)
    t_prefill = time.time() - t0

    pos0 = prompt_len + (cfg.n_img_tokens or 0)
    out = [tok]
    t0 = time.time()
    for i in range(gen_tokens - 1):
        pos = jnp.full((batch,), pos0 + i, jnp.int32)
        tok_j, _, cache = step(params, cache, jnp.asarray(out[-1])[:, None], pos)
        out.append(np.asarray(tok_j))
    t_decode = time.time() - t0
    gen = np.stack(out, 1)
    return gen, {"prefill_s": t_prefill, "decode_s": t_decode,
                 "tok_per_s": batch * (gen_tokens - 1) / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    gen, stats = serve(args.arch, args.batch, args.prompt_len, args.tokens)
    print(f"generated {gen.shape} tokens; prefill {stats['prefill_s']:.2f}s, "
          f"decode {stats['decode_s']:.2f}s ({stats['tok_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()
