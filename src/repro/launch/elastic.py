"""Elastic coordinator: failure detection, straggler mitigation, re-mesh.

Controller-side logic for a 1000+-node deployment, exercised here against
simulated workers (tests/test_fault_tolerance.py).  The data-plane pieces
it drives — TGI checkpoint restore-with-reshard, deterministic data
pipeline seek — are the real implementations.

Policies:
* failure: no heartbeat for ``heartbeat_timeout`` -> host dead; pick the
  largest (data_axis') <= data_axis with dead hosts removed, restore the
  latest checkpoint onto the shrunk mesh, seek the pipeline to the
  restored step (no sample loss/duplication — the pipeline is seeded by
  (step, shard)).
* stragglers: a host whose rolling median step time exceeds
  ``straggler_factor`` x the cluster median is quarantined at the next
  re-mesh boundary (TPU SPMD steps are synchronous — one slow host IS a
  slow step, so quarantine, don't re-balance).
* elastic growth: joined hosts are folded in at the next boundary the
  same way (restore onto the larger mesh).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Set, Tuple


@dataclasses.dataclass
class WorkerState:
    last_heartbeat: float
    step_times: deque  # rolling window
    quarantined: bool = False


class Coordinator:
    def __init__(self, n_hosts: int, chips_per_host: int = 4,
                 heartbeat_timeout: float = 60.0, straggler_factor: float = 2.0,
                 window: int = 16, clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_factor = straggler_factor
        self.chips_per_host = chips_per_host
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState(self.clock(), deque(maxlen=window)) for i in range(n_hosts)
        }
        self.generation = 0  # bumped on every re-mesh
        self.log: List[Dict] = []

    # ---- data plane callbacks ----
    def heartbeat(self, host: int, step_time: Optional[float] = None):
        w = self.workers[host]
        w.last_heartbeat = self.clock()
        if step_time is not None:
            w.step_times.append(step_time)

    def join(self, host: int):
        self.workers[host] = WorkerState(self.clock(), deque(maxlen=16))
        self.log.append({"event": "join", "host": host, "gen": self.generation})

    # ---- policies ----
    def dead_hosts(self) -> Set[int]:
        now = self.clock()
        return {
            h for h, w in self.workers.items()
            if now - w.last_heartbeat > self.heartbeat_timeout
        }

    def stragglers(self) -> Set[int]:
        med = self._cluster_median()
        if med is None:
            return set()
        out = set()
        for h, w in self.workers.items():
            if len(w.step_times) >= w.step_times.maxlen // 2:
                wm = sorted(w.step_times)[len(w.step_times) // 2]
                if wm > self.straggler_factor * med:
                    out.add(h)
        return out

    def _cluster_median(self) -> Optional[float]:
        all_t = [t for w in self.workers.values() for t in w.step_times]
        if not all_t:
            return None
        return sorted(all_t)[len(all_t) // 2]

    def healthy_hosts(self) -> List[int]:
        dead = self.dead_hosts()
        return sorted(
            h for h, w in self.workers.items()
            if h not in dead and not w.quarantined
        )

    def plan(self, data_axis: int, model_axis: int) -> Optional[Dict]:
        """Returns a re-mesh plan if the healthy set changed, else None.

        The model axis is preserved (weights shard over it); the data axis
        shrinks/grows to the largest power-of-two host count available —
        checkpoint restore re-shards, the pipeline re-seeks.
        """
        dead = self.dead_hosts()
        strag = self.stragglers()
        for h in strag:
            self.workers[h].quarantined = True
        healthy = self.healthy_hosts()
        chips = len(healthy) * self.chips_per_host
        need = data_axis * model_axis
        if not dead and not strag and chips >= need:
            return None
        # largest data' (power of two) fitting the healthy chips
        data2 = data_axis
        while data2 > 1 and data2 * model_axis > chips:
            data2 //= 2
        self.generation += 1
        plan = {
            "gen": self.generation,
            "dead": sorted(dead),
            "quarantined": sorted(strag),
            "hosts": healthy[: (data2 * model_axis) // self.chips_per_host],
            "mesh": (data2, model_axis),
            "action": "restore_from_checkpoint_and_reseek",
        }
        self.log.append(plan)
        return plan


def pipeline_seek(step: int, global_batch: int, n_shards: int):
    """Deterministic pipeline position after restore: each shard's RNG is
    seeded by (step, shard), so resuming at `step` replays no sample and
    skips none (see repro.data.pipeline)."""
    return {"step": step, "shard_seeds": [(step, s) for s in range(n_shards)]}
