"""TGI-backed training checkpoint store — the paper's technique as a
first-class LM feature (DESIGN.md §4).

Training-state history *is* a temporal graph: parameter blocks are nodes,
steps are timepoints.  The store keeps:

* **snapshot checkpoints** (the paper's Copy leg / hierarchy roots):
  full blocks, every ``snapshot_every``-th save;
* **delta checkpoints** (the Log leg / eventlists): per-block XOR of the
  raw bits vs. the previous save, zlib-compressed — bit-exact to invert,
  and low-entropy because adjacent optimizer states share exponent/
  high-mantissa bits.  (A float "intersection tree" is vacuous — XOR
  chains are the TGI hierarchy's correct adaptation to parameter data;
  recorded in DESIGN.md §2 assumption changes.)

Restore at step t = nearest snapshot + forward delta replay (Algorithm 1
verbatim).  Blocks are placement-keyed ``(tsid=save_idx, sid=block_hash)``
so restores are partition-parallel and **re-shardable**: the launcher maps
restored leaves onto any mesh (elastic scaling, repro.launch.elastic).
Every blob carries a crc32 verified on read; replication/failover come
from the underlying DeltaStore.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import json
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.storage.kvstore import DeltaKey, DeltaStore

BLOCK = 1 << 20  # 1 MiB per node-block


@dataclasses.dataclass
class CheckpointConfig:
    snapshot_every: int = 4  # full checkpoint cadence (Copy vs Log knob)
    compress_level: int = 1
    n_shards: int = 4  # placement width


def _leaf_blocks(arr: np.ndarray):
    raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
    return [raw[i : i + BLOCK] for i in range(0, len(raw), BLOCK)] or [raw]


class CheckpointStore:
    def __init__(self, store: DeltaStore, cfg: CheckpointConfig = CheckpointConfig()):
        self.store = store
        self.cfg = cfg
        self.saves: List[Dict] = []  # manifest per save: step, kind, leaf meta
        self._prev_raw: Optional[List[np.ndarray]] = None
        self._pool = cf.ThreadPoolExecutor(max_workers=2)

    # ------------------------------------------------------------------
    # Save
    # ------------------------------------------------------------------

    def save(self, step: int, tree) -> Dict:
        """Synchronous save; returns the manifest entry."""
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(l) for l in leaves]
        raws = [np.ascontiguousarray(h).view(np.uint8).reshape(-1) for h in host]
        sidx = len(self.saves)
        is_snap = (sidx % self.cfg.snapshot_every == 0) or self._prev_raw is None
        kind = "snap" if is_snap else "delta"
        leaf_meta = []
        for li, (h, raw) in enumerate(zip(host, raws)):
            payload = raw if is_snap else np.bitwise_xor(raw, self._prev_raw[li])
            blocks = _leaf_blocks(payload)
            blk_meta = []
            for bi, blk in enumerate(blocks):
                comp = zlib.compress(blk.tobytes(), self.cfg.compress_level)
                crc = zlib.crc32(blk.tobytes())
                key = DeltaKey(
                    tsid=sidx,
                    sid=(li * 131 + bi) % self.cfg.n_shards,
                    did=f"P:{li}",
                    pid=bi,
                )
                self.store.put(key, {
                    "z": np.frombuffer(comp, np.uint8),
                    "crc": np.asarray([crc], np.uint32),
                    "n": np.asarray([len(blk)], np.int64),
                })
                blk_meta.append({"key": list(key), "crc": int(crc), "n": len(blk)})
            leaf_meta.append({
                "shape": list(h.shape), "dtype": str(h.dtype), "blocks": blk_meta,
            })
        entry = {"step": int(step), "save_idx": sidx, "kind": kind,
                 "leaves": leaf_meta, "treedef": str(treedef)}
        self.saves.append(entry)
        self._prev_raw = raws
        self._treedef = treedef
        # manifest blob (replicated like any chunk)
        self.store.put(
            DeltaKey(sidx, 0, "MANIFEST", 0),
            {"json": np.frombuffer(json.dumps(entry).encode(), np.uint8)},
        )
        return entry

    def save_async(self, step: int, tree):
        """Async save: snapshots the host copy synchronously (cheap vs.
        device->host it already implies) and writes in a worker thread so
        the train loop is not blocked on storage."""
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(l).copy() for l in leaves]
        rebuilt = jax.tree.unflatten(treedef, host)
        return self._pool.submit(self.save, step, rebuilt)

    # ------------------------------------------------------------------
    # Restore (Algorithm 1 on parameter history)
    # ------------------------------------------------------------------

    def _fetch_payload(self, entry: Dict, c: int) -> List[np.ndarray]:
        keys, sizes = [], []
        for li, lm in enumerate(entry["leaves"]):
            for bm in lm["blocks"]:
                keys.append(DeltaKey(*bm["key"]))
        got = self.store.multiget(keys, c=c)
        out = []
        ki = 0
        for lm in entry["leaves"]:
            parts = []
            for bm in lm["blocks"]:
                rec = got[keys[ki]]
                blk = np.frombuffer(zlib.decompress(rec["z"].tobytes()), np.uint8)
                assert zlib.crc32(blk.tobytes()) == bm["crc"], "checkpoint corrupt"
                assert len(blk) == bm["n"]
                parts.append(blk)
                ki += 1
            out.append(np.concatenate(parts))
        return out

    def restore(self, step: Optional[int] = None, c: int = 4,
                example_tree=None):
        """Reconstruct the tree at `step` (default: latest).  Nearest
        snapshot + XOR-delta replay forward."""
        assert self.saves, "nothing saved"
        target = max(
            (e for e in self.saves if step is None or e["step"] <= step),
            key=lambda e: e["step"],
        )
        sidx = target["save_idx"]
        snap_idx = max(i for i in range(sidx + 1)
                       if self.saves[i]["kind"] == "snap")
        raws = self._fetch_payload(self.saves[snap_idx], c)
        for i in range(snap_idx + 1, sidx + 1):
            deltas = self._fetch_payload(self.saves[i], c)
            raws = [np.bitwise_xor(r, d) for r, d in zip(raws, deltas)]
        leaves = []
        for raw, lm in zip(raws, target["leaves"]):
            arr = raw.view(np.dtype(lm["dtype"])).reshape(lm["shape"])
            leaves.append(arr)
        if example_tree is not None:
            treedef = jax.tree.structure(example_tree)
        else:
            treedef = self._treedef
        return jax.tree.unflatten(treedef, leaves), target["step"]

    def restore_sharded(self, mesh, shardings_tree, step: Optional[int] = None,
                        c: int = 4, example_tree=None):
        """Elastic restore: place restored leaves on an arbitrary mesh
        (different chip count than the writer — re-sharding is free
        because retrieval is block-partitioned, the TGI property)."""
        tree, got_step = self.restore(step, c=c, example_tree=example_tree)
        flat_s, _ = jax.tree.flatten(shardings_tree)
        flat_v, treedef = jax.tree.flatten(tree)
        placed = [jax.device_put(v, s) for v, s in zip(flat_v, flat_s)]
        return jax.tree.unflatten(treedef, placed), got_step

    def storage_cost(self) -> Dict[str, int]:
        return {
            "bytes_written": self.store.stats.bytes_written,
            "n_saves": len(self.saves),
        }
