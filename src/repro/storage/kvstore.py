"""Delta KV store (the paper's Cassandra role, §4.4).

Keys are ``DeltaKey(tsid, sid, did, pid)``; the **placement key**
``(tsid, sid)`` maps a chunk to a storage node, so any large fetch
(snapshot = all sids of one tsid; node version = one sid across tsids)
spreads over the whole cluster — the paper's equitable-distribution
property.  Within a chunk, micro-deltas are clustered by the full delta
key, i.e. all ``pid`` of one ``did`` stored contiguously (paper layout
point 5): the FileBackend writes one blob per placement key.

Replication factor r places a chunk on r consecutive storage nodes;
``fail_node``/``heal_node`` inject failures — reads fall over to live
replicas, writes raise only if *all* replicas are down.  A thread-pooled
``multiget`` models the paper's parallel fetch factor ``c``.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import os
import threading
from collections import defaultdict
from pathlib import Path
from typing import Dict, Iterable, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.storage import serialize


class DeltaKey(NamedTuple):
    tsid: int
    sid: int
    did: str  # e.g. 'E:<bucket>' eventlist, 'S:<level>:<idx>' derived snapshot
    pid: int  # micro-delta partition id (== sid-local partition index)

    @property
    def placement(self) -> Tuple[int, int]:
        return (self.tsid, self.sid)


class StorageNodeDown(RuntimeError):
    pass


# file-backend deletion marker: a record whose length field holds this
# sentinel carries no blob and tombstones every earlier write of its key
# (reads are last-record-wins, so append-only chunk files stay valid)
_TOMBSTONE = (1 << 64) - 1


class KeyMissing(KeyError):
    pass


@dataclasses.dataclass
class StoreStats:
    reads: int = 0
    writes: int = 0
    n_deletes: int = 0  # keys GC'd (span compaction)
    bytes_read: int = 0  # encoded bytes touched off storage
    bytes_written: int = 0  # encoded bytes on disk (x replication)
    bytes_raw_written: int = 0  # pre-encoding bytes (x replication)
    bytes_decompressed: int = 0  # raw bytes materialized by reads
    bytes_deleted: int = 0  # encoded bytes reclaimed by deletes (x repl.)
    failovers: int = 0

    def reset(self):
        self.reads = self.writes = self.n_deletes = 0
        self.bytes_read = self.bytes_written = 0
        self.bytes_raw_written = self.bytes_decompressed = 0
        self.bytes_deleted = 0
        self.failovers = 0


class DeltaStore:
    """m storage nodes, replication r, mem or file backend.  ``fmt``
    selects the on-disk block format ("TGI2" compressed columnar by
    default, "TGI1" raw); reads MAGIC-dispatch, so a store can read
    blobs of either format regardless of its write format."""

    def __init__(self, m: int = 4, r: int = 1, backend: str = "mem",
                 root: Optional[str] = None, fmt: Optional[str] = None):
        assert 1 <= r <= m
        self.m, self.r = m, r
        self.backend = backend
        self.fmt = fmt or serialize.DEFAULT_FORMAT
        self.down: set = set()
        self.stats = StoreStats()
        # per-DeltaKey (raw, encoded) bytes of the last write — the
        # storage-accounting source for TGI.storage_report()
        self.key_sizes: Dict[DeltaKey, Tuple[int, int]] = {}
        self._lock = threading.Lock()
        if backend == "mem":
            self._mem: List[Dict] = [dict() for _ in range(m)]
        else:
            assert root is not None
            self.root = Path(root)
            for i in range(m):
                (self.root / f"node{i}").mkdir(parents=True, exist_ok=True)

    # ---- placement ----
    def replicas(self, key: DeltaKey) -> List[int]:
        tsid, sid = key.placement
        h = (tsid * 0x9E3779B1 + sid * 0x85EBCA77) % self.m
        return [(h + j) % self.m for j in range(self.r)]

    # ---- failure injection ----
    def fail_node(self, i: int):
        self.down.add(i)

    def heal_node(self, i: int):
        self.down.discard(i)

    # ---- io ----
    def _chunk_path(self, node: int, placement) -> Path:
        tsid, sid = placement
        return self.root / f"node{node}" / f"ts{tsid}_s{sid}.tgi"

    def put(self, key: DeltaKey, arrays: Dict[str, np.ndarray]):
        # eventlists ('E:*') are the replay hot path — dozens of blobs
        # per snapshot — so they encode under the latency-biased profile;
        # hierarchy deltas and aux replicas (the bulk of the bytes, a few
        # blobs per query) maximize compression
        profile = "speed" if key.did.startswith("E:") else "size"
        blob = serialize.dumps(arrays, fmt=self.fmt, profile=profile)
        raw_bytes = sum(np.asarray(a).nbytes for a in arrays.values())
        wrote = False
        for node in self.replicas(key):
            if node in self.down:
                continue
            if self.backend == "mem":
                self._mem[node][key] = blob
            else:
                # chunk file per placement key: micro-deltas clustered by
                # delta key (append-style record: key line + length + blob)
                path = self._chunk_path(node, key.placement)
                rec_key = f"{key.did}|{key.pid}".encode()
                with self._lock, open(path, "ab") as f:
                    f.write(len(rec_key).to_bytes(4, "little"))
                    f.write(rec_key)
                    f.write(len(blob).to_bytes(8, "little"))
                    f.write(blob)
            wrote = True
        if not wrote:
            raise StorageNodeDown(f"all replicas down for {key}")
        with self._lock:
            self.stats.writes += 1
            self.stats.bytes_written += len(blob) * self.r
            self.stats.bytes_raw_written += raw_bytes * self.r
            self.key_sizes[key] = (raw_bytes, len(blob))

    def _read_node(self, node: int, key: DeltaKey) -> bytes:
        if self.backend == "mem":
            if key not in self._mem[node]:
                raise KeyMissing(key)
            return self._mem[node][key]
        path = self._chunk_path(node, key.placement)
        if not path.exists():
            raise KeyMissing(key)
        want = f"{key.did}|{key.pid}".encode()
        with open(path, "rb") as f:
            data = f.read()
        off = 0
        found = None
        while off < len(data):
            klen = int.from_bytes(data[off : off + 4], "little")
            off += 4
            k = data[off : off + klen]
            off += klen
            blen = int.from_bytes(data[off : off + 8], "little")
            off += 8
            if blen == _TOMBSTONE:  # deletion marker, no blob follows
                if k == want:
                    found = None
                continue
            if k == want:
                found = data[off : off + blen]  # last write wins
            off += blen
        if found is None:
            raise KeyMissing(key)
        return found

    def delete(self, key: DeltaKey) -> bool:
        """GC one micro-delta (span compaction's cleanup path): drops the
        key from every live replica — the mem backend pops, the file
        backend appends a tombstone record — and reverses the write
        accounting (``key_sizes`` forgets the key, so ``size_report`` and
        ``TGI.storage_report`` shrink; ``stats.bytes_deleted`` tracks the
        reclaimed encoded bytes).  Returns whether the key was live."""
        for node in self.replicas(key):
            if node in self.down:
                continue
            if self.backend == "mem":
                self._mem[node].pop(key, None)
            else:
                path = self._chunk_path(node, key.placement)
                if not path.exists():
                    continue
                rec_key = f"{key.did}|{key.pid}".encode()
                with self._lock, open(path, "ab") as f:
                    f.write(len(rec_key).to_bytes(4, "little"))
                    f.write(rec_key)
                    f.write(_TOMBSTONE.to_bytes(8, "little"))
        with self._lock:
            sizes = self.key_sizes.pop(key, None)
            if sizes is None:
                return False
            self.stats.n_deletes += 1
            self.stats.bytes_deleted += sizes[1] * self.r
        return True

    def live_bytes(self) -> int:
        """Encoded bytes currently live on the store (x replication) —
        unlike ``stats.bytes_written`` this shrinks after GC."""
        with self._lock:
            return sum(enc for _, enc in self.key_sizes.values()) * self.r

    def get(self, key: DeltaKey,
            fields: Optional[Iterable[str]] = None,
            sizes: Optional[Dict[DeltaKey, Tuple[int, int]]] = None,
            ) -> Dict[str, np.ndarray]:
        """Read one micro-delta.  ``fields`` projects the read to the named
        arrays: unrequested columns are seeked over via the block directory
        (never decompressed or materialized) and only the projected bytes
        count toward ``stats.bytes_read`` (the storage end of the
        planner's projection pushdown).  ``sizes``, if given, is filled
        with this key's ``(encoded_read, raw_decompressed)`` byte counts
        — the FetchCost accounting side-channel."""
        last_err: Exception = KeyMissing(key)
        for j, node in enumerate(self.replicas(key)):
            if node in self.down:
                with self._lock:
                    self.stats.failovers += j > 0 or self.r == 1
                continue
            try:
                blob = self._read_node(node, key)
            except KeyMissing as e:
                last_err = e
                continue
            arrays, enc_read, raw_read = serialize.loads_sized(blob, fields=fields)
            with self._lock:
                self.stats.reads += 1
                self.stats.bytes_read += enc_read
                self.stats.bytes_decompressed += raw_read
                if j > 0:
                    self.stats.failovers += 1
            if sizes is not None:
                sizes[key] = (enc_read, raw_read)
            return arrays
        if isinstance(last_err, KeyMissing):
            raise last_err
        raise StorageNodeDown(f"no live replica for {key}")

    def multiget(self, keys: Iterable[DeltaKey], c: int = 1,
                 fields: Optional[Iterable[str]] = None,
                 missing_ok: bool = False,
                 sizes: Optional[Dict[DeltaKey, Tuple[int, int]]] = None,
                 ) -> Dict[DeltaKey, Dict]:
        """Parallel fetch with c clients (paper Fig. 11/12's c parameter).
        Keys are routed per storage node so each client drains distinct
        nodes — the paper's direct QP->storage parallelism.  With
        ``missing_ok`` absent keys are skipped instead of raising (sparse
        key spaces like per-shard eventlists); node failures still raise."""
        keys = list(keys)
        out: Dict[DeltaKey, Dict] = {}
        if c <= 1:
            for k in keys:
                try:
                    out[k] = self.get(k, fields=fields, sizes=sizes)
                except KeyMissing:
                    if not missing_ok:
                        raise
            return out
        with cf.ThreadPoolExecutor(max_workers=c) as ex:
            futs = {ex.submit(self.get, k, fields, sizes): k for k in keys}
            for fut in cf.as_completed(futs):
                try:
                    out[futs[fut]] = fut.result()
                except KeyMissing:
                    if not missing_ok:
                        raise
        return out

    def size_report(self) -> Dict[str, Dict[str, int]]:
        """Raw vs. encoded bytes per did component, from the per-key
        write accounting (one entry per logical key — multiply by ``r``
        for on-disk bytes).  Components are the did prefixes: ``E``
        eventlists, ``S`` hierarchy deltas, ``X`` aux replicas, and the
        literal did for anything else (checkpoint blocks, manifests)."""
        out: Dict[str, Dict[str, int]] = {}
        with self._lock:
            items = list(self.key_sizes.items())
        for key, (raw, enc) in items:
            comp = key.did.split(":", 1)[0]
            row = out.setdefault(comp, {"raw": 0, "encoded": 0, "count": 0})
            row["raw"] += raw
            row["encoded"] += enc
            row["count"] += 1
        return out

    def keys_for_placement(self, tsid: int, sid: int) -> List[DeltaKey]:
        """Enumerate stored micro-delta keys under one placement chunk."""
        if self.backend == "mem":
            ks = set()
            for node in range(self.m):
                for k in self._mem[node]:
                    if k.placement == (tsid, sid):
                        ks.add(k)
            return sorted(ks)
        ks = set()
        for node in range(self.m):
            path = self._chunk_path(node, (tsid, sid))
            if not path.exists():
                continue
            with open(path, "rb") as f:
                data = f.read()
            off = 0
            while off < len(data):
                klen = int.from_bytes(data[off : off + 4], "little")
                off += 4
                k = data[off : off + klen].decode()
                off += klen
                blen = int.from_bytes(data[off : off + 8], "little")
                off += 8
                did, pid = k.rsplit("|", 1)
                if blen == _TOMBSTONE:  # deleted (last record wins)
                    ks.discard(DeltaKey(tsid, sid, did, int(pid)))
                    continue
                off += blen
                ks.add(DeltaKey(tsid, sid, did, int(pid)))
        return sorted(ks)
