"""Delta KV store (the paper's Cassandra role, §4.4).

Keys are ``DeltaKey(tsid, sid, did, pid)``; the **placement key**
``(tsid, sid)`` maps a chunk to a storage node, so any large fetch
(snapshot = all sids of one tsid; node version = one sid across tsids)
spreads over the whole cluster — the paper's equitable-distribution
property.  Within a chunk, micro-deltas are clustered by the full delta
key, i.e. all ``pid`` of one ``did`` stored contiguously (paper layout
point 5): the FileBackend writes one blob per placement key.

Replication factor r places a chunk on r consecutive storage nodes;
``fail_node``/``heal_node`` inject failures — reads fall over to live
replicas, writes raise only if *all* replicas are down.  A thread-pooled
``multiget`` models the paper's parallel fetch factor ``c``.

Read-path fast layers (both on by default):

* **Decoded-block buffer pool** (``BlockPool``): a byte-budgeted LRU of
  *decoded* columns keyed ``(key, column)``.  Repeated hierarchy-path
  and eventlist reads — the inner loop of snapshot retrieval and
  compaction — skip storage I/O AND decompression entirely.  Pool hits
  are accounted separately from physical decodes (``StoreStats.
  pool_hits`` / ``bytes_pool_served`` vs ``bytes_decompressed``;
  ``ReadSizes`` carries the per-key split) so FetchCost stays truthful.
  Writers (``put``/``delete``) invalidate per key.
* **Range-seek file backend** (``seek=True``): every put appends the
  blob's (offset, length) extent to a ``.tgx`` sidecar next to the chunk
  file; reads seek straight to the blob, parse the TGI2 directory from a
  small prefix, and pread only the *requested* columns' byte ranges —
  a ``fields=`` projection saves real disk I/O, not just decode time
  (``StoreStats.bytes_io`` counts the physical file bytes actually
  read; compare with ``seek=False``, which slurps whole chunk files).
"""
from __future__ import annotations

import collections
import concurrent.futures as cf
import dataclasses
import os
import threading
from collections import defaultdict
from pathlib import Path
from typing import Dict, Iterable, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.core import faultpoints
from repro.storage import serialize
from repro.storage.serialize import BlockCorruption  # re-export  # noqa: F401


class DeltaKey(NamedTuple):
    tsid: int
    sid: int
    did: str  # e.g. 'E:<bucket>' eventlist, 'S:<level>:<idx>' derived snapshot
    pid: int  # micro-delta partition id (== sid-local partition index)

    @property
    def placement(self) -> Tuple[int, int]:
        return (self.tsid, self.sid)


def replica_nodes(tsid: int, sid: int, m: int, r: int) -> List[int]:
    """The placement function, shared by every party that must agree on
    it: ``DeltaStore`` (local reads/writes), ``RemoteDeltaStore``
    (routing), and ``StorageCell`` (feed catch-up filters peer records
    to the keys whose replica chain includes this cell).  A placement
    key hashes to a primary node; replicas live on the next ``r - 1``
    consecutive nodes (the paper's equitable-distribution layout)."""
    h = (tsid * 0x9E3779B1 + sid * 0x85EBCA77) % m
    return [(h + j) % m for j in range(r)]


# ---------------------------------------------------------------------------
# versioned sequence numbers: (epoch, seq) packed into one u64
# ---------------------------------------------------------------------------

# A write's version is ``(epoch, seq)``: ``epoch`` is the writer's
# fencing epoch (one per writer-lease incarnation, granted by cell
# quorum, strictly monotonic cluster-wide) and ``seq`` is that lane's
# local counter starting at 1.  Packing epoch into the high bits makes
# the numeric order of the u64 exactly the lexicographic (epoch, seq)
# order — the cluster-wide total order that every per-key conflict
# (concurrent writers, replays, redeliveries arriving in any
# permutation) is resolved by.  Epoch 0 is the legacy unleased lane
# (direct ``StorageCell.apply`` callers, pre-lease feeds).
SEQ_BITS = 44
SEQ_MASK = (1 << SEQ_BITS) - 1
MAX_EPOCH = (1 << (64 - SEQ_BITS)) - 1


def make_vseq(epoch: int, seq: int) -> int:
    assert 0 <= epoch <= MAX_EPOCH and 0 <= seq <= SEQ_MASK
    return (epoch << SEQ_BITS) | seq


def split_vseq(vseq: int) -> Tuple[int, int]:
    return vseq >> SEQ_BITS, vseq & SEQ_MASK


class StorageNodeDown(RuntimeError):
    pass


class WriteUnavailable(StorageNodeDown):
    """The write plane is degraded: this writer holds no live lease and
    cannot reach a cell quorum to acquire one, so writes fail *fast*
    (no network attempt, no hang) while reads keep failing over.  The
    client re-acquires automatically in the background; writes flow
    again, under a fresh fencing epoch, once a quorum returns."""


class NodeUnavailable(RuntimeError):
    """One replica could not be reached (remote cell down, connect or
    request timeout).  Read paths treat it exactly like a down node:
    fail over to the next replica; only when every replica is
    unavailable does the error surface as ``StorageNodeDown``.  Local
    backends never raise it."""


# file-backend deletion marker: a record whose length field holds this
# sentinel carries no blob and tombstones every earlier write of its key
# (reads are last-record-wins, so append-only chunk files stay valid)
_TOMBSTONE = (1 << 64) - 1


class KeyMissing(KeyError):
    pass


@dataclasses.dataclass
class StoreStats:
    reads: int = 0
    writes: int = 0
    n_deletes: int = 0  # keys GC'd (span compaction)
    bytes_read: int = 0  # encoded bytes touched off storage
    bytes_written: int = 0  # encoded bytes on disk (x replication)
    bytes_raw_written: int = 0  # pre-encoding bytes (x replication)
    bytes_decompressed: int = 0  # raw bytes physically decoded by reads
    bytes_deleted: int = 0  # encoded bytes reclaimed by deletes (x repl.)
    failovers: int = 0
    # multiget batch redirects: keys routed straight to a fallback
    # replica because their node was known-unavailable at batch start
    # (hedged as a group, not rediscovered per key)
    hedged_reads: int = 0
    # replica writes that failed (or were skipped on a suspect node) and
    # were later delivered from the client's per-node redelivery queue —
    # the live repair that closes interior feed gaps (remote store only)
    redelivered: int = 0
    # decoded-block pool accounting — pool hits are NEVER counted as
    # physical decodes (bytes_decompressed), so FetchCost stays truthful
    pool_hits: int = 0  # columns served from the pool
    pool_misses: int = 0  # columns physically read + decoded (pool on)
    bytes_pool_served: int = 0  # raw bytes served from the pool
    bytes_io: int = 0  # physical file-backend bytes read (0 for mem)
    # wire-transport round trips (remote store only): a request submitted
    # while its node's connection already had >= 1 reply outstanding rode
    # the pipeline; one submitted to an idle connection paid a serial
    # round trip.  Deadline cancels expired client-side without poisoning
    # the connection; reconnects are transparent re-dials of a mux socket
    rt_pipelined: int = 0
    rt_serial: int = 0
    rt_deadline_cancels: int = 0
    rt_reconnects: int = 0
    # writer-lease lifecycle (remote store only): epochs acquired by
    # quorum grant, quorum-confirmed renewals, writes refused by a cell
    # because their lane was fenced (sealed under a newer epoch), and
    # queued redeliveries dropped because redelivering them is forever
    # futile (their lane sealed below them — restart catch-up repairs)
    lease_acquires: int = 0
    lease_renewals: int = 0
    lease_fenced: int = 0
    fence_drops: int = 0
    # encoded serve cache (file backend): projected blocks assembled once
    # and re-served byte-identical while their extent record is unmoved
    serve_hits: int = 0
    serve_misses: int = 0

    def reset(self):
        self.reads = self.writes = self.n_deletes = 0
        self.bytes_read = self.bytes_written = 0
        self.bytes_raw_written = self.bytes_decompressed = 0
        self.bytes_deleted = 0
        self.failovers = self.hedged_reads = self.redelivered = 0
        self.pool_hits = self.pool_misses = self.bytes_pool_served = 0
        self.bytes_io = 0
        self.rt_pipelined = self.rt_serial = 0
        self.rt_deadline_cancels = self.rt_reconnects = 0
        self.lease_acquires = self.lease_renewals = 0
        self.lease_fenced = self.fence_drops = 0
        self.serve_hits = self.serve_misses = 0


class ReadSizes(NamedTuple):
    """Per-key byte accounting of one ``get`` (the ``sizes=`` out-param):
    what physically crossed storage vs what the decoded-block pool
    served.  ``enc + raw`` describe the physical read; ``pool`` raw
    bytes (over ``pool_cols`` columns) came from the pool and must never
    be reported as decompression."""

    enc: int  # encoded bytes physically read off storage
    raw: int  # raw bytes physically materialized by decode
    pool: int = 0  # raw bytes served from the decoded-block pool
    pool_cols: int = 0  # pooled columns in this read


# default decoded-block pool budget per store (bytes); 0 disables
DEFAULT_POOL_BYTES = 48 << 20


class BlockPool:
    """Byte-budgeted LRU of *decoded* columns keyed ``(DeltaKey, column)``.

    The buffer-pool-over-compressed-deltas design (Khurana & Deshpande):
    snapshot retrieval and compaction re-read the same hierarchy-path
    and eventlist blocks over and over; caching their decoded arrays
    turns those repeats into dictionary lookups — no storage I/O, no
    decompression, no checksum pass.  Entries are copied on insert and
    stored read-only: the cold-read caller keeps its own (possibly
    writeable) array, so no mutation can reach the pool, and a pooled
    column never pins the blob buffer it was decoded from.  Warm reads
    hand the read-only array out without copying (callers already
    tolerate read-only arrays — raw/zlib decodes are ``frombuffer``
    views).  The parsed per-key directory rides along so a fully pooled
    key is served with zero backend touches.
    """

    def __init__(self, budget_bytes: int):
        self.budget = int(budget_bytes)
        self._lock = threading.Lock()
        self._cols: "collections.OrderedDict" = collections.OrderedDict()
        self._dirs: Dict[DeltaKey, List[serialize.ColumnMeta]] = {}
        self._by_key: Dict[DeltaKey, set] = defaultdict(set)
        # per-key write-version counter, monotonic for the pool's
        # lifetime (never reset, even on delete — a re-put must not
        # collide with a token captured before the delete).  Writers bump
        # it AFTER mutating the backend and BEFORE invalidating; readers
        # capture it BEFORE their physical read and pass it to ``put``/
        # ``dir_put``, which reject the fill on mismatch.  That closes
        # the read/invalidate race: a fill computed from pre-write bytes
        # can never land after the writer's invalidation.
        self._wver: Dict[DeltaKey, int] = {}
        self.bytes_cached = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.inserts = 0
        self.invalidations = 0
        self.stale_rejects = 0

    def get(self, key: DeltaKey, col: str) -> Optional[np.ndarray]:
        with self._lock:
            a = self._cols.get((key, col))
            if a is None:
                self.misses += 1
                return None
            self._cols.move_to_end((key, col))
            self.hits += 1
            return a

    def peek(self, key: DeltaKey, col: str) -> bool:
        """Residency probe without LRU promotion or hit/miss accounting
        (the planner's cost model asks, it doesn't read)."""
        with self._lock:
            return (key, col) in self._cols

    def write_version(self, key: DeltaKey) -> int:
        """Current write version of ``key`` — capture BEFORE a physical
        read, hand back to ``put``/``dir_put`` as ``ver=``."""
        with self._lock:
            return self._wver.get(key, 0)

    def bump_version(self, key: DeltaKey) -> None:
        """Writer-side: record that the backend bytes of ``key`` changed.
        Must happen after the backend mutation and before ``invalidate``."""
        with self._lock:
            self._wver[key] = self._wver.get(key, 0) + 1

    def put(self, key: DeltaKey, col: str, arr: np.ndarray,
            ver: Optional[int] = None) -> None:
        nb = int(arr.nbytes)
        if nb > self.budget:
            return  # larger than the whole pool: not cacheable
        # own copy, marked read-only: (a) a caller mutating its cold-read
        # array can never poison the pooled one, and (b) frombuffer views
        # into a whole blob would otherwise pin the entire encoded blob
        # while bytes_cached only counted the column
        arr = np.array(arr, copy=True)
        arr.flags.writeable = False
        with self._lock:
            if ver is not None and ver != self._wver.get(key, 0):
                self.stale_rejects += 1  # decoded from superseded bytes
                return
            k = (key, col)
            old = self._cols.pop(k, None)
            if old is not None:
                self.bytes_cached -= old.nbytes
            self._cols[k] = arr
            self._by_key[key].add(col)
            self.bytes_cached += nb
            self.inserts += 1
            while self.bytes_cached > self.budget and self._cols:
                (ek, ecol), ea = self._cols.popitem(last=False)
                self.bytes_cached -= ea.nbytes
                cols = self._by_key.get(ek)
                if cols is not None:
                    cols.discard(ecol)
                    if not cols:
                        del self._by_key[ek]
                        self._dirs.pop(ek, None)
                self.evictions += 1

    def dir_get(self, key: DeltaKey) -> Optional[List[serialize.ColumnMeta]]:
        with self._lock:
            return self._dirs.get(key)

    def dir_put(self, key: DeltaKey, entries: List[serialize.ColumnMeta],
                ver: Optional[int] = None) -> None:
        with self._lock:
            if ver is not None and ver != self._wver.get(key, 0):
                self.stale_rejects += 1  # directory of superseded bytes
                return
            self._dirs[key] = entries
            self._by_key.setdefault(key, set())

    def invalidate(self, key: DeltaKey) -> None:
        """Drop every pooled column (and the directory) of one key —
        called by ``put``/``delete`` so ingest and GC can never leave
        stale decoded blocks behind."""
        with self._lock:
            cols = self._by_key.pop(key, None)
            self._dirs.pop(key, None)
            if not cols:
                return
            for c in cols:
                a = self._cols.pop((key, c), None)
                if a is not None:
                    self.bytes_cached -= a.nbytes
            self.invalidations += 1

    def clear(self) -> None:
        with self._lock:
            self._cols.clear()
            self._dirs.clear()
            self._by_key.clear()
            self.bytes_cached = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "budget_bytes": self.budget,
                "bytes_cached": self.bytes_cached,
                "entries": len(self._cols),
                "keys": len(self._by_key),
                "hits": self.hits,
                "misses": self.misses,
                "inserts": self.inserts,
                "evictions": self.evictions,
                "invalidations": self.invalidations,
                "stale_rejects": self.stale_rejects,
            }


class DeltaStore:
    """m storage nodes, replication r, mem or file backend.  ``fmt``
    selects the on-disk block format ("TGI2" compressed columnar by
    default, "TGI1" raw); reads MAGIC-dispatch, so a store can read
    blobs of either format regardless of its write format.

    ``pool_bytes`` budgets the decoded-block buffer pool (0 disables);
    ``seek`` selects range-seek reads on the file backend (extent
    sidecars + per-column preads) vs whole-chunk-file slurps."""

    def __init__(self, m: int = 4, r: int = 1, backend: str = "mem",
                 root: Optional[str] = None, fmt: Optional[str] = None,
                 pool_bytes: int = DEFAULT_POOL_BYTES, seek: bool = True,
                 serve_cache_bytes: int = 8 << 20):
        assert 1 <= r <= m
        self.m, self.r = m, r
        self.backend = backend
        self.fmt = fmt or serialize.DEFAULT_FORMAT
        self.seek = seek
        self.pool: Optional[BlockPool] = (
            BlockPool(pool_bytes) if pool_bytes else None)
        self.down: set = set()
        self.stats = StoreStats()
        # per-DeltaKey (raw, encoded) bytes of the last write — the
        # storage-accounting source for TGI.storage_report()
        self.key_sizes: Dict[DeltaKey, Tuple[int, int]] = {}
        self._lock = threading.Lock()
        # epoch-tagged deferred GC: (publish_epoch, [keys]) batches from
        # MVCC maintenance, deletable only once every reader pinned below
        # publish_epoch has drained (TGI drives gc_drain on guard exit)
        self._gc_queue: List[Tuple[int, List[DeltaKey]]] = []
        # file-backend vacuum: generation counter bumped on every chunk
        # rewrite; lock-free readers holding a pre-rewrite extent table
        # retry once when they fail and the generation moved
        self._vacuum_gen = 0
        self._vacuum_lock = threading.Lock()
        # per-read pool-version token (set by ``get`` around its physical
        # read so the dir-fill deep in the read path can version-check)
        self._rd_tls = threading.local()
        # file backend: per-(node, placement) extent tables, lazily
        # loaded from the .tgx sidecars (or one legacy chunk scan)
        self._ext_cache: Dict[Tuple[int, Tuple[int, int]],
                              Dict[bytes, Tuple[int, int]]] = {}
        # file backend: cached read handles per chunk, shared between
        # reader threads via positioned reads (os.pread — no seek state).
        # Invalidation pops the handle WITHOUT closing it: in-flight
        # readers keep their reference alive (refcounting closes the old
        # inode once the last one returns), so an fd number can never be
        # recycled under a concurrent pread.
        self._fh_lock = threading.Lock()
        self._fh_cache: Dict[Tuple[int, Tuple[int, int]], object] = {}
        # encoded serve cache: assembled projected blocks keyed by
        # (node, placement, record, projection), validated against the
        # CURRENT extent record and vacuum generation on every hit —
        # appends move a rewritten key's extent (miss), vacuum bumps the
        # generation (wholesale miss) — so a stale blob is unservable
        self._serve_lock = threading.Lock()
        self._serve_cache: "collections.OrderedDict" = collections.OrderedDict()
        self._serve_bytes = 0
        self.serve_cache_bytes = int(serve_cache_bytes)
        if backend == "mem":
            self._mem: List[Dict] = [dict() for _ in range(m)]
        else:
            assert root is not None
            self.root = Path(root)
            for i in range(m):
                (self.root / f"node{i}").mkdir(parents=True, exist_ok=True)

    # ---- placement ----
    def replicas(self, key: DeltaKey) -> List[int]:
        return replica_nodes(key.tsid, key.sid, self.m, self.r)

    def transport_stats(self) -> Dict:
        """Wire-transport view (in-flight depth, pipelined vs serial
        round trips).  Local backends have no transport: empty dict.
        ``RemoteDeltaStore`` overrides with live per-node mux state."""
        return {}

    # ---- failure injection / node health ----
    def fail_node(self, i: int):
        self.down.add(i)

    def heal_node(self, i: int):
        self.down.discard(i)

    def _node_ok(self, i: int) -> bool:
        """Whether node ``i`` is currently worth sending a request to.
        The local store only knows injected failures; RemoteDeltaStore
        additionally tracks cells whose last request failed (suspects,
        with a re-probe TTL)."""
        return i not in self.down

    def _mark_unavailable(self, i: int) -> None:
        """Health feedback from a failed read — no-op locally (injected
        failures are authoritative); the remote store marks the cell
        suspect so the next batch hedges straight to replicas."""

    def node_status(self) -> Dict:
        """Per-node health and live-data report, shared by local and
        remote stores (chaos tests assert cluster health through one
        shape): for each of the ``m`` nodes, whether it is up and the
        live keys / encoded bytes it hosts (replicas counted on every
        node holding them, from the write-time ``key_sizes``)."""
        with self._lock:
            items = list(self.key_sizes.items())
        return self._node_status_from(items)

    def _node_status_from(self, items) -> Dict:
        """``node_status`` computed from one caller-supplied snapshot of
        ``key_sizes.items()`` (so ``report_snapshot`` can derive every
        section from a single point-in-time copy)."""
        keys_per = [0] * self.m
        bytes_per = [0] * self.m
        for key, (_, enc) in items:
            for n in self.replicas(key):
                keys_per[n] += 1
                bytes_per[n] += enc
        nodes = [
            {"node": i, "up": self._node_ok(i), "live_keys": keys_per[i],
             "live_bytes": bytes_per[i]}
            for i in range(self.m)
        ]
        return {"m": self.m, "r": self.r, "backend": self.backend,
                "n_down": sum(1 for n in nodes if not n["up"]),
                "nodes": nodes}

    # ---- io ----
    def _chunk_path(self, node: int, placement) -> Path:
        tsid, sid = placement
        return self.root / f"node{node}" / f"ts{tsid}_s{sid}.tgi"

    def _extent_path(self, node: int, placement) -> Path:
        tsid, sid = placement
        return self.root / f"node{node}" / f"ts{tsid}_s{sid}.tgx"

    def _ext_record(self, node: int, placement, rec_key: bytes,
                    off: int, length: int) -> None:
        """Append one (key -> blob offset, length) extent to the sidecar
        and mirror it into the in-memory table.  A ``_TOMBSTONE`` length
        marks deletion.  Caller holds ``self._lock``."""
        with open(self._extent_path(node, placement), "ab") as f:
            f.write(len(rec_key).to_bytes(4, "little"))
            f.write(rec_key)
            f.write(off.to_bytes(8, "little"))
            f.write(length.to_bytes(8, "little"))
        cache = self._ext_cache.get((node, placement))
        if cache is not None:
            if length == _TOMBSTONE:
                cache.pop(rec_key, None)
            else:
                cache[rec_key] = (off, length)

    def _extents(self, node: int, placement) -> Dict[bytes, Tuple[int, int]]:
        """Extent table of one chunk: rec_key -> (blob offset, length),
        last record wins.  Loaded once from the ``.tgx`` sidecar — or,
        for a legacy chunk written without one, rebuilt by a single full
        scan — then kept current inline by put/delete."""
        ck = (node, placement)
        with self._lock:
            cache = self._ext_cache.get(ck)
            if cache is not None:
                return cache
            cache = {}
            epath = self._extent_path(node, placement)
            cpath = self._chunk_path(node, placement)
            if epath.exists():
                data = epath.read_bytes()
                self.stats.bytes_io += len(data)
                off = 0
                while off < len(data):
                    klen = int.from_bytes(data[off : off + 4], "little")
                    off += 4
                    k = bytes(data[off : off + klen])
                    off += klen
                    boff = int.from_bytes(data[off : off + 8], "little")
                    blen = int.from_bytes(data[off + 8 : off + 16], "little")
                    off += 16
                    if blen == _TOMBSTONE:
                        cache.pop(k, None)
                    else:
                        cache[k] = (boff, blen)
            elif cpath.exists():
                data = cpath.read_bytes()
                self.stats.bytes_io += len(data)
                off = 0
                while off < len(data):
                    klen = int.from_bytes(data[off : off + 4], "little")
                    off += 4
                    k = bytes(data[off : off + klen])
                    off += klen
                    blen = int.from_bytes(data[off : off + 8], "little")
                    off += 8
                    if blen == _TOMBSTONE:
                        cache.pop(k, None)
                        continue
                    cache[k] = (off, blen)
                    off += blen
            self._ext_cache[ck] = cache
            return cache

    def _chunk_file(self, node: int, placement):
        """Cached read handle of one chunk file (unbuffered, read via
        ``os.pread`` so concurrent readers never race a shared file
        position).  Raises ``FileNotFoundError`` when the chunk does not
        exist — callers translate to ``KeyMissing``."""
        ck = (node, placement)
        with self._fh_lock:
            f = self._fh_cache.get(ck)
        if f is not None:
            return f
        f = open(self._chunk_path(node, placement), "rb", buffering=0)
        with self._fh_lock:
            cur = self._fh_cache.setdefault(ck, f)
        if cur is not f:
            f.close()
        return cur

    @staticmethod
    def _pread_exact(fd: int, n: int, off: int) -> bytes:
        """Positioned read of exactly ``n`` bytes (short reads looped;
        a true EOF returns what exists, like ``file.read``)."""
        out = os.pread(fd, n, off)
        if len(out) == n or not out:
            return out
        parts = [out]
        got = len(out)
        while got < n:
            chunk = os.pread(fd, n - got, off + got)
            if not chunk:
                break
            parts.append(chunk)
            got += len(chunk)
        return b"".join(parts)

    def drop_chunk_caches(self, node: int, placement) -> None:
        """Invalidate every read-side cache over one chunk after its
        file was replaced wholesale (state transfer installs, external
        rewrites): extent table, read handle, and — via the generation
        bump — every encoded serve-cache entry sourced from it."""
        with self._lock:
            self._ext_cache.pop((node, placement), None)
            self._vacuum_gen += 1
        with self._fh_lock:
            self._fh_cache.pop((node, placement), None)

    def _serve_cache_get(self, node: int, placement, rec_key: bytes,
                         wkey, rec: Tuple[int, int]) -> Optional[bytes]:
        """Serve-cache hit iff the entry was assembled from the record
        the extent table points at RIGHT NOW (same offset/length, same
        vacuum generation) — anything else misses and re-reads."""
        k = (node, placement, rec_key, wkey)
        with self._serve_lock:
            ent = self._serve_cache.get(k)
            if ent is None:
                return None
            gen, erec, blob = ent
            if gen != self._vacuum_gen or erec != rec:
                del self._serve_cache[k]
                self._serve_bytes -= len(blob)
                return None
            self._serve_cache.move_to_end(k)
            return blob

    def _serve_cache_put(self, node: int, placement, rec_key: bytes,
                         wkey, rec: Tuple[int, int], blob: bytes) -> None:
        if len(blob) * 4 > self.serve_cache_bytes:
            return  # one giant block must not wipe the whole cache
        k = (node, placement, rec_key, wkey)
        with self._serve_lock:
            old = self._serve_cache.pop(k, None)
            if old is not None:
                self._serve_bytes -= len(old[2])
            self._serve_cache[k] = (self._vacuum_gen, rec, blob)
            self._serve_bytes += len(blob)
            while self._serve_bytes > self.serve_cache_bytes:
                _, (_, _, evicted) = self._serve_cache.popitem(last=False)
                self._serve_bytes -= len(evicted)

    def encode_payload(self, key: DeltaKey,
                       arrays: Dict[str, np.ndarray]) -> Tuple[bytes, int]:
        """Serialize one micro-delta to its stored block: ``(blob,
        raw_bytes)``.  Eventlists ('E:*') are the replay hot path —
        dozens of blobs per snapshot — so they encode under the
        latency-biased profile; hierarchy deltas and aux replicas (the
        bulk of the bytes, a few blobs per query) maximize compression.
        Split out of ``put`` so the remote client encodes ONCE and fans
        the same bytes out to every replica cell."""
        profile = "speed" if key.did.startswith("E:") else "size"
        blob = serialize.dumps(arrays, fmt=self.fmt, profile=profile)
        raw_bytes = sum(np.asarray(a).nbytes for a in arrays.values())
        return blob, raw_bytes

    def put(self, key: DeltaKey, arrays: Dict[str, np.ndarray]):
        blob, raw_bytes = self.encode_payload(key, arrays)
        self.put_encoded(key, blob, raw_bytes)

    def put_encoded(self, key: DeltaKey, blob: bytes, raw_bytes: int):
        """Store an already-encoded block verbatim.  This is the write
        primitive a StorageCell applies for wire PUTs and change-feed
        replay: because the bytes land untouched, every replica's chunk
        and extent files stay byte-identical to the writer's encoding —
        the property feed-based catch-up converges on."""
        wrote = False
        for node in self.replicas(key):
            if node in self.down:
                continue
            if self.backend == "mem":
                self._mem[node][key] = blob
            else:
                # chunk file per placement key: micro-deltas clustered by
                # delta key (append-style record: key line + length + blob)
                path = self._chunk_path(node, key.placement)
                rec_key = f"{key.did}|{key.pid}".encode()
                # chunk record + extent append under ONE lock hold, so
                # concurrent puts of a key can't leave the sidecar
                # pointing at a superseded blob.  Sidecars are written
                # regardless of this store's read mode so a later
                # seek=True open of the same root sees a complete
                # extent history.
                with self._lock:
                    with open(path, "ab") as f:
                        base = f.tell()
                        f.write(len(rec_key).to_bytes(4, "little"))
                        f.write(rec_key)
                        f.write(len(blob).to_bytes(8, "little"))
                        f.write(blob)
                    self._ext_record(node, key.placement, rec_key,
                                     base + 4 + len(rec_key) + 8, len(blob))
            wrote = True
        if not wrote:
            raise StorageNodeDown(f"all replicas down for {key}")
        if self.pool is not None:  # a rewrite must never serve stale blocks
            # bump-then-invalidate: the bump fences out in-flight readers
            # (their captured version no longer matches, so their decoded
            # pre-write blocks can't re-fill the pool after this
            # invalidation), the invalidation drops what's already cached
            self.pool.bump_version(key)
            self.pool.invalidate(key)
        with self._lock:
            self.stats.writes += 1
            self.stats.bytes_written += len(blob) * self.r
            self.stats.bytes_raw_written += raw_bytes * self.r
            self.key_sizes[key] = (raw_bytes, len(blob))

    def _read_node(self, node: int, key: DeltaKey) -> bytes:
        if self.backend == "mem":
            if key not in self._mem[node]:
                raise KeyMissing(key)
            return self._mem[node][key]
        path = self._chunk_path(node, key.placement)
        if not path.exists():
            raise KeyMissing(key)
        want = f"{key.did}|{key.pid}".encode()
        with open(path, "rb") as f:
            data = f.read()
        with self._lock:  # the whole-file slurp: every byte of the chunk
            self.stats.bytes_io += len(data)
        off = 0
        found = None
        while off < len(data):
            klen = int.from_bytes(data[off : off + 4], "little")
            off += 4
            k = data[off : off + klen]
            off += klen
            blen = int.from_bytes(data[off : off + 8], "little")
            off += 8
            if blen == _TOMBSTONE:  # deletion marker, no blob follows
                if k == want:
                    found = None
                continue
            if k == want:
                found = data[off : off + blen]  # last write wins
            off += blen
        if found is None:
            raise KeyMissing(key)
        return found

    def delete(self, key: DeltaKey) -> bool:
        """GC one micro-delta (span compaction's cleanup path): drops the
        key from every live replica — the mem backend pops, the file
        backend appends a tombstone record — and reverses the write
        accounting (``key_sizes`` forgets the key, so ``size_report`` and
        ``TGI.storage_report`` shrink; ``stats.bytes_deleted`` tracks the
        reclaimed encoded bytes).  Returns whether the key was live."""
        for node in self.replicas(key):
            if node in self.down:
                continue
            if self.backend == "mem":
                self._mem[node].pop(key, None)
            else:
                path = self._chunk_path(node, key.placement)
                if not path.exists():
                    continue
                rec_key = f"{key.did}|{key.pid}".encode()
                with self._lock:
                    with open(path, "ab") as f:
                        f.write(len(rec_key).to_bytes(4, "little"))
                        f.write(rec_key)
                        f.write(_TOMBSTONE.to_bytes(8, "little"))
                    self._ext_record(node, key.placement, rec_key,
                                     0, _TOMBSTONE)
        if self.pool is not None:  # GC'd blocks must never be served
            self.pool.bump_version(key)  # fence in-flight reader re-fills
            self.pool.invalidate(key)
        with self._lock:
            sizes = self.key_sizes.pop(key, None)
            if sizes is None:
                return False
            self.stats.n_deletes += 1
            self.stats.bytes_deleted += sizes[1] * self.r
        return True

    # ---- epoch-deferred GC (MVCC maintenance) ----

    def delete_deferred(self, keys: Iterable[DeltaKey], epoch: int) -> int:
        """Queue superseded keys for GC, tagged with the epoch at which
        they stopped being reachable (the maintenance pass's post-publish
        ``read_epoch``).  They stay readable until ``gc_drain`` proves no
        pinned reader can still reach them."""
        keys = list(keys)
        if not keys:
            return 0
        with self._lock:
            self._gc_queue.append((int(epoch), keys))
        return len(keys)

    def gc_pending(self) -> int:
        """Keys queued for GC but not yet reclaimed (pinned readers, or
        no drain since the last publish)."""
        with self._lock:
            return sum(len(ks) for _, ks in self._gc_queue)

    def gc_drain(self, min_pinned_epoch: Optional[int] = None,
                 ) -> Tuple[int, int]:
        """Reclaim every queued batch whose tag epoch is safe: a batch
        tagged E was superseded by the publish that bumped the epoch *to*
        E, so a reader pinned at E or later only sees the replacement
        layout — the batch is deletable once ``min_pinned_epoch >= E``
        (or nothing is pinned at all).  Batches are epoch-ordered (the
        queue is append-only under a monotonic epoch), so the drain stops
        at the first unsafe batch.  Returns ``(keys_deleted,
        encoded_bytes_deleted)``.  A crash mid-batch (``compact.mid_gc``
        fault point) re-queues the undeleted remainder, so a retried
        drain converges instead of leaking."""
        deleted, freed = 0, 0
        while True:
            with self._lock:
                if not self._gc_queue:
                    break
                epoch, keys = self._gc_queue[0]
                if min_pinned_epoch is not None and min_pinned_epoch < epoch:
                    break  # a pinned reader may still reach this batch
                self._gc_queue.pop(0)
            idx = 0
            try:
                for idx, k in enumerate(keys):
                    faultpoints.fire("compact.mid_gc")
                    with self._lock:
                        sz = self.key_sizes.get(k)
                    if self.delete(k):
                        deleted += 1
                        freed += (sz[1] * self.r) if sz else 0
            except BaseException:
                with self._lock:  # keys[idx] was not deleted: keep it
                    self._gc_queue.insert(0, (epoch, keys[idx:]))
                raise
        return deleted, freed

    def live_bytes(self) -> int:
        """Encoded bytes currently live on the store (x replication) —
        unlike ``stats.bytes_written`` this shrinks after GC."""
        with self._lock:
            return sum(enc for _, enc in self.key_sizes.values()) * self.r

    def _dir_ver(self, key: DeltaKey) -> Optional[int]:
        """The pool write-version ``get`` captured before this thread's
        in-flight physical read of ``key`` (None when the read did not
        come through ``get`` — then the fill is unchecked, matching the
        callers that never race a writer)."""
        cur = getattr(self._rd_tls, "cur", None)
        if cur is not None and cur[0] == key:
            return cur[1]
        return None

    def _pool_dir_fill(self, key: DeltaKey, blob: bytes) -> None:
        if self.pool is not None and self.pool.dir_get(key) is None:
            self.pool.dir_put(key, serialize.walk(blob),
                              ver=self._dir_ver(key))

    def _read_columns(self, node: int, key: DeltaKey,
                      fields: Optional[Tuple[str, ...]],
                      ) -> Tuple[Dict[str, np.ndarray], int, int]:
        """Physically read + decode the requested columns from one
        replica; returns ``(arrays, enc_read, raw_read)`` and caches the
        block directory in the pool."""
        if self.backend == "file" and self.seek:
            return self._read_columns_seek(node, key, fields)
        blob = self._read_node(node, key)
        arrays, enc_read, raw_read = serialize.loads_sized(blob, fields=fields)
        self._pool_dir_fill(key, blob)
        return arrays, enc_read, raw_read

    # prefix read size for range-seek blob reads: one pread that covers
    # the whole TGI2 directory for any realistic column count (~40 bytes
    # per entry), grown geometrically for the rare block that overflows
    _DIR_PREFIX = 4096

    def _read_columns_seek(self, node: int, key: DeltaKey,
                           fields: Optional[Tuple[str, ...]],
                           ) -> Tuple[Dict[str, np.ndarray], int, int]:
        """Range-seek read with one vacuum retry: readers are lock-free
        against ``vacuum()``'s chunk rewrites, so a reader holding a
        pre-rewrite extent table can seek into relocated bytes — every
        such landing fails loudly (crc32 mismatch -> BlockCorruption,
        short read -> truncated directory, dropped extent -> KeyMissing).
        If the vacuum generation moved during the read, retry once
        against the refreshed extents; a failure with an unmoved
        generation is a real error and propagates."""
        gen0 = self._vacuum_gen
        try:
            return self._read_columns_seek_raw(node, key, fields)
        except (KeyMissing, BlockCorruption, ValueError, OSError):
            if self._vacuum_gen == gen0:
                raise
            return self._read_columns_seek_raw(node, key, fields)

    def _read_columns_seek_raw(self, node: int, key: DeltaKey,
                               fields: Optional[Tuple[str, ...]],
                               ) -> Tuple[Dict[str, np.ndarray], int, int]:
        """Range-seek read: extent lookup -> directory prefix pread ->
        one pread per requested column.  Unrequested columns cost zero
        file bytes (``stats.bytes_io`` counts exactly what was read)."""
        ext = self._extents(node, key.placement)
        rec = ext.get(f"{key.did}|{key.pid}".encode())
        if rec is None:
            raise KeyMissing(key)
        off, blen = rec
        io_bytes = 0
        try:
            fd = self._chunk_file(node, key.placement).fileno()
        except FileNotFoundError:
            raise KeyMissing(key) from None
        prefix = self._pread_exact(fd, min(blen, self._DIR_PREFIX), off)
        io_bytes += len(prefix)
        if bytes(prefix[:4]) == serialize.MAGIC:
            # TGI1 interleaves headers with payloads: no seekable
            # directory — fall back to reading this blob in full
            blob = prefix + self._pread_exact(
                fd, blen - len(prefix), off + len(prefix))
            io_bytes += max(blen - len(prefix), 0)
            arrays, enc_read, raw_read = serialize.loads_sized(
                blob, fields=fields)
            self._pool_dir_fill(key, blob)
            with self._lock:
                self.stats.bytes_io += io_bytes
            return arrays, enc_read, raw_read
        entries = serialize.parse_directory(prefix)
        while entries is None and len(prefix) < blen:
            more = self._pread_exact(
                fd, min(blen - len(prefix), len(prefix)),
                off + len(prefix))
            if not more:
                break
            prefix += more
            io_bytes += len(more)
            entries = serialize.parse_directory(prefix)
        if entries is None:
            raise BlockCorruption(f"truncated TGI2 directory for {key}")
        if self.pool is not None and self.pool.dir_get(key) is None:
            self.pool.dir_put(key, entries, ver=self._dir_ver(key))
        want = None if fields is None else set(fields)
        arrays: Dict[str, np.ndarray] = {}
        enc_read, raw_read = 8, 0
        view = memoryview(prefix)
        for e in entries:
            if want is not None and e.name not in want:
                continue
            if e.off + e.length <= len(prefix):
                payload = view[e.off : e.off + e.length]
            else:
                payload = self._pread_exact(fd, e.length, off + e.off)
                io_bytes += e.length
            arrays[e.name] = serialize.decode_entry(e, payload)
            enc_read += e.length
            raw_read += arrays[e.name].nbytes
        with self._lock:
            self.stats.bytes_io += io_bytes
        return arrays, enc_read, raw_read

    def get(self, key: DeltaKey,
            fields: Optional[Iterable[str]] = None,
            sizes: Optional[Dict[DeltaKey, "ReadSizes"]] = None,
            ) -> Dict[str, np.ndarray]:
        """Read one micro-delta.  ``fields`` projects the read to the named
        arrays: unrequested columns are seeked over via the block directory
        (never decompressed or materialized — and on the range-seek file
        backend never even read off disk); only the projected bytes count
        toward ``stats.bytes_read`` (the storage end of the planner's
        projection pushdown).

        Columns resident in the decoded-block pool are served from it:
        no storage I/O, no decode, no checksum pass.  ``sizes``, if
        given, is filled with this key's ``ReadSizes`` — the physical
        (enc, raw) bytes vs the pool-served bytes, the FetchCost
        accounting side-channel (pool hits are never reported as
        physical decodes)."""
        want = None if fields is None else tuple(fields)
        pooled: Dict[str, np.ndarray] = {}
        pool_raw = 0
        need = want
        if self.pool is not None:
            entries = self.pool.dir_get(key)
            if entries is not None:
                wset = None if want is None else set(want)
                targets = [e.name for e in entries
                           if wset is None or e.name in wset]
                missing = []
                for n in targets:
                    a = self.pool.get(key, n)
                    if a is None:
                        missing.append(n)
                    else:
                        pooled[n] = a
                        pool_raw += a.nbytes
                if not missing:  # fully pooled: zero backend touches
                    with self._lock:
                        self.stats.reads += 1
                        self.stats.pool_hits += len(pooled)
                        self.stats.bytes_pool_served += pool_raw
                    if sizes is not None:
                        sizes[key] = ReadSizes(0, 0, pool_raw, len(pooled))
                    return dict(pooled)
                need = tuple(missing)
        last_err: Exception = KeyMissing(key)
        # version token captured BEFORE the physical read: if a writer
        # rewrites/deletes this key while we read, the pool rejects our
        # (now stale) fill instead of resurrecting superseded blocks
        tok = self.pool.write_version(key) if self.pool is not None else None
        self._rd_tls.cur = (key, tok)
        try:
            for j, node in enumerate(self.replicas(key)):
                if not self._node_ok(node):
                    with self._lock:
                        self.stats.failovers += j > 0 or self.r == 1
                    continue
                try:
                    arrays, enc_read, raw_read = self._read_columns(
                        node, key, need)
                except KeyMissing as e:
                    last_err = e
                    continue
                except BlockCorruption as e:
                    # a corrupt replica is as dead as a down one: fail over
                    # to the next copy (the error surfaces only when every
                    # replica is corrupt or missing)
                    last_err = e
                    with self._lock:
                        self.stats.failovers += 1
                    continue
                except NodeUnavailable as e:
                    # an unreachable cell (remote backend): mark it suspect
                    # so the rest of the batch hedges, and fail over
                    last_err = e
                    self._mark_unavailable(node)
                    with self._lock:
                        self.stats.failovers += 1
                    continue
                with self._lock:
                    self.stats.reads += 1
                    self.stats.bytes_read += enc_read
                    self.stats.bytes_decompressed += raw_read
                    if self.pool is not None:
                        self.stats.pool_hits += len(pooled)
                        self.stats.pool_misses += len(arrays)
                        self.stats.bytes_pool_served += pool_raw
                    if j > 0:
                        self.stats.failovers += 1
                if self.pool is not None:
                    for n, a in arrays.items():
                        self.pool.put(key, n, a, ver=tok)
                if sizes is not None:
                    sizes[key] = ReadSizes(enc_read, raw_read, pool_raw,
                                           len(pooled))
                if pooled:
                    arrays = {**pooled, **arrays}
                return arrays
        finally:
            self._rd_tls.cur = None
        if isinstance(last_err, (KeyMissing, BlockCorruption)):
            raise last_err
        raise StorageNodeDown(f"no live replica for {key}")

    def clear_pool(self) -> None:
        """Drop every decoded block (``TGI.invalidate_caches()`` full
        path and cold-read benchmarking)."""
        if self.pool is not None:
            self.pool.clear()

    def pool_stats(self) -> Dict[str, int]:
        return self.pool.stats() if self.pool is not None else {}

    def pool_residency(self, key: DeltaKey) -> float:
        """Fraction of ``key``'s columns currently pooled (0.0 when the
        key has never been read) — the planner's pool-awareness hook for
        discounting warm blocks in fetch-cost estimates."""
        if self.pool is None:
            return 0.0
        entries = self.pool.dir_get(key)
        if not entries:
            return 0.0
        present = sum(1 for e in entries if self.pool.peek(key, e.name))
        return present / len(entries)

    def multiget(self, keys: Iterable[DeltaKey], c: int = 1,
                 fields: Optional[Iterable[str]] = None,
                 missing_ok: bool = False,
                 sizes: Optional[Dict[DeltaKey, "ReadSizes"]] = None,
                 ) -> Dict[DeltaKey, Dict]:
        """Parallel fetch with c clients (paper Fig. 11/12's c parameter).
        Keys are grouped by their primary replica node and each group is
        drained as one batch, so concurrent clients hit distinct nodes —
        the paper's direct QP->storage parallelism (keys sharing a
        primary share the whole replica chain, so a group fails over as
        a unit).  A group whose primary is known-unavailable at batch
        start is *hedged*: every key goes straight to the fallback
        replicas in one batch instead of rediscovering the dead node per
        key (``StoreStats.hedged_reads`` counts them).  With
        ``missing_ok`` absent keys are skipped instead of raising (sparse
        key spaces like per-shard eventlists); node failures still raise."""
        keys = list(keys)
        groups: Dict[int, List[DeltaKey]] = {}
        for k in keys:
            groups.setdefault(self.replicas(k)[0], []).append(k)
        out: Dict[DeltaKey, Dict] = {}
        if c <= 1 or len(groups) == 1:
            for primary, gkeys in groups.items():
                out.update(self._group_fetch(primary, gkeys, fields,
                                             missing_ok, sizes))
            return out
        with cf.ThreadPoolExecutor(max_workers=c) as ex:
            futs = [
                ex.submit(self._group_fetch, primary, gkeys, fields,
                          missing_ok, sizes)
                for primary, gkeys in groups.items()
            ]
            for fut in cf.as_completed(futs):
                out.update(fut.result())
        return out

    def _group_fetch(self, primary: int, gkeys: List[DeltaKey],
                     fields: Optional[Iterable[str]], missing_ok: bool,
                     sizes: Optional[Dict[DeltaKey, "ReadSizes"]],
                     ) -> Dict[DeltaKey, Dict]:
        """Fetch one primary-node group of a multiget.  The base store
        reads key by key (``get`` already fails over); the remote store
        overrides this with one wire MULTIGET frame per replica tier.
        Either way, an unavailable primary is detected once for the
        whole group — the keys are hedged to the replicas as a batch."""
        if not self._node_ok(primary):
            with self._lock:
                self.stats.hedged_reads += len(gkeys)
        out: Dict[DeltaKey, Dict] = {}
        for k in gkeys:
            try:
                out[k] = self.get(k, fields=fields, sizes=sizes)
            except KeyMissing:
                if not missing_ok:
                    raise
        return out

    # ---- encoded (no-decode) reads: the service plane's serving path ----

    def get_encoded(self, key: DeltaKey,
                    fields: Optional[Iterable[str]] = None) -> bytes:
        """Projected block read *without decoding*: returns a TGI2 block
        whose directory lists every column of the stored blob but whose
        payload section carries only the requested columns' encoded
        bytes, copied verbatim.  This is what a StorageCell serves for a
        wire GET — the cell never decompresses, per-column crc32s ride
        along unchanged (the client verifies on decode), and on the
        range-seek file backend only the projected columns' byte ranges
        are read off disk (``stats.bytes_io`` measures exactly that).
        Assembled blocks land in the encoded serve cache, so a cell
        re-serving a hot key skips file io AND re-assembly — the cached
        bytes are only ever served while the key's extent record (and
        the vacuum generation) are exactly what they were at assembly
        time, so a rewrite or compaction can never serve stale bytes."""
        want = None if fields is None else set(fields)
        wkey = None if want is None else frozenset(want)
        seekable = self.backend == "file" and self.seek
        rec_key = f"{key.did}|{key.pid}".encode() if seekable else b""
        last_err: Exception = KeyMissing(key)
        for j, node in enumerate(self.replicas(key)):
            if not self._node_ok(node):
                with self._lock:
                    self.stats.failovers += j > 0 or self.r == 1
                continue
            rec = None
            if seekable:
                rec = self._extents(node, key.placement).get(rec_key)
                if rec is not None:
                    blob = self._serve_cache_get(
                        node, key.placement, rec_key, wkey, rec)
                    if blob is not None:
                        with self._lock:
                            self.stats.reads += 1
                            self.stats.bytes_read += len(blob)
                            self.stats.serve_hits += 1
                            if j > 0:
                                self.stats.failovers += 1
                        return blob
            try:
                entries, payloads, enc_read = self._read_encoded(
                    node, key, want)
            except KeyMissing as e:
                last_err = e
                continue
            except BlockCorruption as e:
                last_err = e
                with self._lock:
                    self.stats.failovers += 1
                continue
            with self._lock:
                self.stats.reads += 1
                self.stats.bytes_read += enc_read
                self.stats.serve_misses += seekable
                if j > 0:
                    self.stats.failovers += 1
            blob = serialize.assemble_block(entries, payloads)
            if rec is not None:
                self._serve_cache_put(
                    node, key.placement, rec_key, wkey, rec, blob)
            return blob
        if isinstance(last_err, (KeyMissing, BlockCorruption)):
            raise last_err
        raise StorageNodeDown(f"no live replica for {key}")

    def _read_encoded(self, node: int, key: DeltaKey,
                      want: Optional[set],
                      ) -> Tuple[List[serialize.ColumnMeta],
                                 Dict[str, bytes], int]:
        """Read one replica's directory plus the wanted columns' encoded
        payload bytes — no decode, no checksum pass (the reader
        verifies).  Returns ``(all entries, {name: payload}, enc_read)``."""
        if self.backend == "file" and self.seek:
            return self._read_encoded_seek(node, key, want)
        blob = memoryview(self._read_node(node, key))
        entries = serialize.walk(blob)
        payloads = {
            e.name: bytes(blob[e.off : e.off + e.length])
            for e in entries if want is None or e.name in want
        }
        enc_read = 8 + sum(len(p) for p in payloads.values())
        return entries, payloads, enc_read

    def _read_encoded_seek(self, node: int, key: DeltaKey,
                           want: Optional[set],
                           ) -> Tuple[List[serialize.ColumnMeta],
                                      Dict[str, bytes], int]:
        """Range-seek twin of ``_read_encoded`` with the same one-shot
        vacuum retry as ``_read_columns_seek``."""
        gen0 = self._vacuum_gen
        try:
            return self._read_encoded_seek_raw(node, key, want)
        except (KeyMissing, BlockCorruption, ValueError, OSError):
            if self._vacuum_gen == gen0:
                raise
            return self._read_encoded_seek_raw(node, key, want)

    def _read_encoded_seek_raw(self, node: int, key: DeltaKey,
                               want: Optional[set],
                               ) -> Tuple[List[serialize.ColumnMeta],
                                          Dict[str, bytes], int]:
        """Range-seek twin of ``_read_encoded``: extent lookup ->
        directory prefix pread -> one pread per wanted column.
        Unrequested columns cost zero file bytes."""
        ext = self._extents(node, key.placement)
        rec = ext.get(f"{key.did}|{key.pid}".encode())
        if rec is None:
            raise KeyMissing(key)
        off, blen = rec
        io_bytes = 0
        try:
            fd = self._chunk_file(node, key.placement).fileno()
        except FileNotFoundError:
            raise KeyMissing(key) from None
        prefix = self._pread_exact(fd, min(blen, self._DIR_PREFIX), off)
        io_bytes += len(prefix)
        if bytes(prefix[:4]) == serialize.MAGIC:
            # TGI1: headers interleave with payloads — full read
            blob = prefix + self._pread_exact(
                fd, blen - len(prefix), off + len(prefix))
            io_bytes += max(blen - len(prefix), 0)
            with self._lock:
                self.stats.bytes_io += io_bytes
            blob_v = memoryview(blob)
            entries = serialize.walk(blob_v)
            payloads = {
                e.name: bytes(blob_v[e.off : e.off + e.length])
                for e in entries if want is None or e.name in want
            }
            return entries, payloads, 8 + sum(
                len(p) for p in payloads.values())
        entries = serialize.parse_directory(prefix)
        while entries is None and len(prefix) < blen:
            more = self._pread_exact(
                fd, min(blen - len(prefix), len(prefix)),
                off + len(prefix))
            if not more:
                break
            prefix += more
            io_bytes += len(more)
            entries = serialize.parse_directory(prefix)
        if entries is None:
            raise BlockCorruption(f"truncated TGI2 directory for {key}")
        view = memoryview(prefix)
        payloads: Dict[str, bytes] = {}
        for e in entries:
            if want is not None and e.name not in want:
                continue
            if e.off + e.length <= len(prefix):
                payloads[e.name] = bytes(view[e.off : e.off + e.length])
            else:
                payloads[e.name] = self._pread_exact(
                    fd, e.length, off + e.off)
                io_bytes += e.length
        with self._lock:
            self.stats.bytes_io += io_bytes
        return entries, payloads, 8 + sum(len(p) for p in payloads.values())

    def size_report(self) -> Dict[str, Dict[str, int]]:
        """Raw vs. encoded bytes per did component, from the per-key
        write accounting (one entry per logical key — multiply by ``r``
        for on-disk bytes).  Components are the did prefixes: ``E``
        eventlists, ``S`` hierarchy deltas, ``X`` aux replicas, and the
        literal did for anything else (checkpoint blocks, manifests)."""
        with self._lock:
            items = list(self.key_sizes.items())
        return self._size_report_from(items)

    @staticmethod
    def _size_report_from(items) -> Dict[str, Dict[str, int]]:
        out: Dict[str, Dict[str, int]] = {}
        for key, (raw, enc) in items:
            comp = key.did.split(":", 1)[0]
            row = out.setdefault(comp, {"raw": 0, "encoded": 0, "count": 0})
            row["raw"] += raw
            row["encoded"] += enc
            row["count"] += 1
        return out

    def report_snapshot(self) -> Dict:
        """Every storage-accounting section — per-component sizes, per-
        node live data, total live bytes, GC backlog — derived from ONE
        point-in-time copy of the write accounting taken under the store
        lock.  ``TGI.storage_report`` builds on this so a report taken
        mid-compaction is internally consistent: its sections can never
        mix pre- and post-publish states of ``key_sizes``."""
        with self._lock:
            items = list(self.key_sizes.items())
            gc_pending = sum(len(ks) for _, ks in self._gc_queue)
        return {
            "size_report": self._size_report_from(items),
            "node_status": self._node_status_from(items),
            "live_bytes": sum(enc for _, (_, enc) in items) * self.r,
            "gc_pending_keys": gc_pending,
        }

    def keys_for_placement(self, tsid: int, sid: int) -> List[DeltaKey]:
        """Enumerate stored micro-delta keys under one placement chunk."""
        if self.backend == "mem":
            ks = set()
            for node in range(self.m):
                for k in self._mem[node]:
                    if k.placement == (tsid, sid):
                        ks.add(k)
            return sorted(ks)
        ks = set()
        for node in range(self.m):
            path = self._chunk_path(node, (tsid, sid))
            if not path.exists():
                continue
            with open(path, "rb") as f:
                data = f.read()
            off = 0
            while off < len(data):
                klen = int.from_bytes(data[off : off + 4], "little")
                off += 4
                k = data[off : off + klen].decode()
                off += klen
                blen = int.from_bytes(data[off : off + 8], "little")
                off += 8
                did, pid = k.rsplit("|", 1)
                if blen == _TOMBSTONE:  # deleted (last record wins)
                    ks.discard(DeltaKey(tsid, sid, did, int(pid)))
                    continue
                off += blen
                ks.add(DeltaKey(tsid, sid, did, int(pid)))
        return sorted(ks)

    def vacuum(self, canonical: bool = False) -> Dict[str, int]:
        """File-backend chunk compaction: rewrite each chunk with only
        its live (non-tombstoned, non-superseded) records, dropping the
        garbage that append-only puts and tombstone deletes accumulate.
        This is the maintenance a StorageCell runs in the background on a
        MAINT request — it must not refuse traffic, so each chunk is
        rewritten under ONE hold of the store lock (writers queue behind
        it briefly); lock-free readers that raced the rename retry once
        via the vacuum-generation check in the seek readers.  The rewrite
        goes through a temp file + ``os.replace`` so a crash mid-vacuum
        (``cell.vacuum`` fault point) leaves every chunk either fully old
        or fully new — both readable.  Returns rewrite counters.

        ``canonical=True`` additionally orders each rewritten chunk's
        live records by record key instead of preserving their append
        offsets, making the chunk bytes a pure function of the live
        record *set* — the byte-identical-convergence anchor when N
        concurrent writer lanes interleave differently per replica (the
        default arrival-order rewrite is only deterministic under a
        single writer).  Idempotent: a chunk already in canonical form
        is left untouched."""
        out = {"chunks_scanned": 0, "chunks_rewritten": 0,
               "chunks_removed": 0, "bytes_before": 0, "bytes_after": 0}
        if self.backend != "file":
            return out
        with self._vacuum_lock:  # one vacuum at a time
            for node in range(self.m):
                ndir = self.root / f"node{node}"
                for cpath in sorted(ndir.glob("ts*_s*.tgi")):
                    stem = cpath.stem  # ts{tsid}_s{sid}
                    try:
                        tsid_s, sid_s = stem[2:].split("_s")
                        placement = (int(tsid_s), int(sid_s))
                    except ValueError:
                        continue
                    faultpoints.fire("cell.vacuum")
                    self._extents(node, placement)  # ensure table loaded
                    with self._lock:
                        out["chunks_scanned"] += 1
                        cache = self._ext_cache.get((node, placement), {})
                        try:
                            data = cpath.read_bytes()
                        except OSError:
                            continue
                        out["bytes_before"] += len(data)
                        epath = self._extent_path(node, placement)
                        if not cache:  # fully dead: drop chunk + sidecar
                            cpath.unlink(missing_ok=True)
                            epath.unlink(missing_ok=True)
                            self._ext_cache.pop((node, placement), None)
                            with self._fh_lock:
                                self._fh_cache.pop((node, placement), None)
                            self._vacuum_gen += 1
                            out["chunks_removed"] += 1
                            continue
                        parts: List[bytes] = []
                        new_cache: Dict[bytes, Tuple[int, int]] = {}
                        pos = 0
                        order = (sorted(cache.items())  # by record key
                                 if canonical else
                                 sorted(cache.items(), key=lambda kv: kv[1][0]))
                        for rec_key, (boff, blen) in order:
                            blob = data[boff:boff + blen]
                            if len(blob) != blen:
                                continue  # torn extent: drop the record
                            rec = (len(rec_key).to_bytes(4, "little")
                                   + rec_key
                                   + blen.to_bytes(8, "little") + blob)
                            new_cache[rec_key] = (
                                pos + 4 + len(rec_key) + 8, blen)
                            parts.append(rec)
                            pos += len(rec)
                        new_data = b"".join(parts)
                        if new_data == data:
                            out["bytes_after"] += len(new_data)
                            continue  # already exact: leave untouched
                        tmp_c = cpath.parent / (cpath.name + ".tmp")
                        tmp_c.write_bytes(new_data)
                        ext_parts = []
                        for rec_key, (boff, blen) in new_cache.items():
                            ext_parts.append(
                                len(rec_key).to_bytes(4, "little") + rec_key
                                + boff.to_bytes(8, "little")
                                + blen.to_bytes(8, "little"))
                        tmp_e = epath.parent / (epath.name + ".tmp")
                        tmp_e.write_bytes(b"".join(ext_parts))
                        os.replace(tmp_c, cpath)
                        os.replace(tmp_e, epath)
                        self._ext_cache[(node, placement)] = new_cache
                        with self._fh_lock:
                            self._fh_cache.pop((node, placement), None)
                        self._vacuum_gen += 1
                        out["chunks_rewritten"] += 1
                        out["bytes_after"] += len(new_data)
                        self.stats.bytes_io += len(data) + len(new_data)
        return out
