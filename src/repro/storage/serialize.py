"""Typed, versioned binary (de)serialization for delta payloads.

Two wire formats live behind one ``dumps``/``loads`` API, dispatched on
the 4-byte MAGIC (see docs/storage_format.md for the byte-level spec):

* **TGI1** — fixed-layout header + raw little-endian arrays.  mmap
  friendly, zero-copy on read, byte-stable.  Still written on request
  (``dumps(..., fmt="TGI1")``) and always readable: old blobs keep
  loading byte-identically (golden-blob tested).

* **TGI2** — compressed columnar blocks.  A per-column directory
  (name, dtype, shape, encoding, encoded length) precedes the payloads,
  so a ``fields=`` projection *seeks over* unread columns without
  decompressing them.  Encodings are chosen per column at write time by
  actual encoded size:

      0 RAW           verbatim little-endian bytes (also every column at
                      or below RAW_KEEP_BYTES — decode-latency floor)
      1 DELTA_VARINT  first value as fixed int64, then LEB128 varints of
                      the deltas — sorted int columns (event times,
                      packed edge keys, slot ids) shrink to ~1 byte/value
                      and decode as one cast + cumsum
      2 BITPACK       booleans at 1 bit/value (np.packbits)
      3 DICT          low-cardinality columns: sorted uniques +
                      bit-packed codes ({1,2,4,8} bits/value, LUT decode)
      4 ZLIB          zlib of the raw bytes — the fallback for
                      everything else (floats, high-entropy columns)
      5 NARROW        frame-of-reference: min + offsets cast to the
                      smallest unsigned width — bounded-range int
                      columns (node ids, attr values) at memcpy-like
                      decode speed
      6 DELTA_NARROW  delta + frame-of-reference: sorted columns whose
                      diffs overflow 7 bits, one branch-free cumsum pass

The chooser weighs candidate sizes by decode-speed class under a per-
block *profile*: "size" for cold blocks (hierarchy, checkpoints),
"speed" for the replay hot path (eventlists), where an encoding must
buy roughly an order of magnitude before displacing raw.  The codecs
are numpy-vectorized (no per-value Python on either hot path);
``loads_sized`` additionally reports (encoded bytes touched, raw bytes
materialized) so the kvstore/FetchCost layers can account compression.

Every TGI2 directory entry carries a crc32 of its encoded payload,
verified on decode (``BlockCorruption`` on mismatch), and the absolute
payload offsets make the directory a *range map*: ``parse_directory``
parses it from a byte prefix and ``decode_entry`` decodes one column
from its own payload bytes — the kvstore's range-seek file backend and
decoded-block buffer pool are built on these two hooks.
"""
from __future__ import annotations

import io
import math
import struct
import zlib
from typing import Dict, Iterable, List, NamedTuple, Optional, Tuple

import numpy as np

MAGIC = b"TGI1"
MAGIC2 = b"TGI2"
DEFAULT_FORMAT = "TGI2"
# high bit of the TGI2 column-count word: directory entries carry a
# trailing u32 crc32 (the pre-checksum layout has the bit clear, and
# its 17-byte entry tail keeps loading — no rewrite needed)
DIR_HAS_CRC = 0x80000000
ZLIB_LEVEL = 6
RAW_KEEP_BYTES = 128  # columns at or below this stay raw (decode-latency floor)
DICT_MAX_ELEMS = 1 << 16  # skip np.unique-based dict probing above this
DELTA_MAX_ELEMS = 1 << 17  # skip the sortedness scan / delta coding above this
ZLIB_PROBE_BYTES = 1 << 16  # above this, probe a 4 KiB prefix before zlib-6

_DT_CODE = {
    np.dtype(np.bool_): 0, np.dtype(np.int8): 1, np.dtype(np.int16): 2,
    np.dtype(np.int32): 3, np.dtype(np.int64): 4, np.dtype(np.float32): 5,
    np.dtype(np.float64): 6, np.dtype(np.uint8): 7, np.dtype(np.uint32): 8,
    np.dtype(np.bfloat16) if hasattr(np, "bfloat16") else np.dtype(np.void): 9,
    # TGI2 additions (new codes only — existing TGI1 bytes are unchanged)
    np.dtype(np.uint16): 10, np.dtype(np.uint64): 11, np.dtype(np.float16): 12,
}
_CODE_DT = {v: k for k, v in _DT_CODE.items()}

# TGI2 column encodings
(ENC_RAW, ENC_DELTA_VARINT, ENC_BITPACK, ENC_DICT, ENC_ZLIB,
 ENC_NARROW, ENC_DELTA_NARROW) = range(7)
ENC_NAME = {0: "raw", 1: "delta_varint", 2: "bitpack", 3: "dict",
            4: "zlib", 5: "narrow", 6: "delta_narrow"}
# decode-speed weights: the chooser minimizes stored_bytes * weight, so
# a slower-decoding encoding must buy proportionally more compression to
# take the column (raw/narrow decode at memcpy speed; dict is one table
# lookup; delta-varint pays a cumsum + varint scan; zlib a full inflate).
# The "size" profile (hierarchy deltas, checkpoints — fetched a few
# blobs per query) leans toward compression; the "speed" profile
# (eventlists — the replay hot path reads dozens of blobs per snapshot)
# keeps a column raw unless an encoding pays for its decode with roughly
# an order of magnitude of compression — which the killers (delta-coded
# event times, extreme dictionaries) still clear.
ENC_WEIGHTS = {
    "size": {ENC_RAW: 1.0, ENC_NARROW: 1.0, ENC_BITPACK: 1.0,
             ENC_DICT: 1.25, ENC_DELTA_VARINT: 1.8, ENC_ZLIB: 4.0,
             ENC_DELTA_NARROW: 1.1},
    "speed": {ENC_RAW: 1.0, ENC_NARROW: 12.0, ENC_BITPACK: 4.0,
              ENC_DICT: 12.0, ENC_DELTA_VARINT: 5.0, ENC_ZLIB: 24.0,
              ENC_DELTA_NARROW: 1.5},
}

# int dtypes safe to round-trip through int64 delta/narrow coding
_VARINTABLE = {np.dtype(np.int8), np.dtype(np.int16), np.dtype(np.int32),
               np.dtype(np.int64), np.dtype(np.uint8), np.dtype(np.uint16),
               np.dtype(np.uint32)}


class BlockCorruption(RuntimeError):
    """A stored column failed its crc32 check: the payload bytes on
    storage do not match what the writer recorded.  Raised *before* any
    decode, so corruption surfaces as a clear error instead of silently
    mis-decoded arrays."""


class ColumnMeta(NamedTuple):
    """One directory entry: everything needed to locate, verify, and
    decode a single column without touching the rest of the block.
    ``off``/``length`` are byte positions relative to the block start;
    ``crc`` is the crc32 of the *encoded* payload (None for TGI1 blocks,
    which predate checksums)."""

    name: str
    dtype: np.dtype
    shape: Tuple[int, ...]
    enc: int
    off: int
    length: int
    crc: Optional[int]


# ---------------------------------------------------------------------------
# varint codec (vectorized LEB128)
# ---------------------------------------------------------------------------


def _uvarint_encode(vals: np.ndarray) -> bytes:
    """LEB128-encode a uint64 array (one unrolled pass per byte position)."""
    v = np.ascontiguousarray(vals, np.uint64)
    if v.size == 0:
        return b""
    nb = np.ones(v.shape, np.int64)
    x = v >> np.uint64(7)
    while x.any():
        nb += x != 0
        x >>= np.uint64(7)
    offs = np.zeros(v.size + 1, np.int64)
    np.cumsum(nb, out=offs[1:])
    out = np.zeros(int(offs[-1]), np.uint8)
    for i in range(int(nb.max())):
        sel = nb > i
        byte = ((v[sel] >> np.uint64(7 * i)) & np.uint64(0x7F)).astype(np.uint8)
        cont = (nb[sel] - 1 > i).astype(np.uint8) << 7
        out[offs[:-1][sel] + i] = byte | cont
    return out.tobytes()


def _uvarint_decode(buf, count: int) -> np.ndarray:
    """Decode ``count`` LEB128 values.  Delta streams are dominated by
    1-byte values, so the decoder treats multi-byte values as the
    exception: the terminator byte of every value lands in one
    vectorized gather (for 1-byte values that IS the value), then the
    few multi-byte values are patched — scalar when they are rare,
    one fancy-indexed pass per byte position when they are not."""
    if count == 0:
        return np.zeros(0, np.uint64)
    b = np.frombuffer(buf, np.uint8)
    if len(b) == count:  # every value fits 7 bits
        return b.astype(np.uint64)
    ends = np.flatnonzero(b < 0x80)  # terminator byte of each value
    assert len(ends) == count, "varint stream/count mismatch"
    vals = b[ends].astype(np.uint64)  # terminators have the high bit clear
    n_cont = len(b) - count
    if n_cont <= 8:
        # rare multi-byte values: find each continuation run's start and
        # rebuild just those values in Python (bounded tiny loop)
        cont = np.flatnonzero(b & 0x80)
        run_starts = cont[np.diff(cont, prepend=-2) > 1]
        raw = bytes(buf) if not isinstance(buf, bytes) else buf
        for s in run_starts:
            v, shift, j = 0, 0, int(s)
            while raw[j] & 0x80:
                v |= (raw[j] & 0x7F) << shift
                shift += 7
                j += 1
            v |= raw[j] << shift
            vals[np.searchsorted(ends, j)] = v
        return vals
    starts = np.empty(count, np.int64)
    starts[0] = 0
    starts[1:] = ends[:-1] + 1
    nb = ends - starts + 1
    vals = (b[starts] & 0x7F).astype(np.uint64)
    for i in range(1, int(nb.max())):
        sel = np.flatnonzero(nb > i)
        vals[sel] |= (b[starts[sel] + i] & np.uint8(0x7F)).astype(np.uint64) \
            << np.uint64(7 * i)
    return vals


# ---------------------------------------------------------------------------
# per-column encoders
# ---------------------------------------------------------------------------


def _enc_delta_varint(arr: np.ndarray) -> bytes:
    v = arr.astype(np.int64).ravel()
    # first value fixed-width, out of the varint stream: diff streams are
    # mostly 1-byte values, and keeping the (large) first value out lets
    # the decoder's single-cast fast path fire
    diffs = np.diff(v).astype(np.uint64)  # non-decreasing -> diffs >= 0
    return struct.pack("<q", int(v[0])) + _uvarint_encode(diffs)


def _dec_delta_varint(payload, count: int, dt: np.dtype) -> np.ndarray:
    (first,) = struct.unpack_from("<q", payload, 0)
    b = np.frombuffer(payload, np.uint8, offset=8)
    out = np.empty(count, np.int64)
    out[0] = first
    if len(b) == count - 1:  # all diffs fit 7 bits: cumsum straight off
        np.add(np.cumsum(b, dtype=np.int64), first, out=out[1:])
    else:
        diffs = _uvarint_decode(b, count - 1).astype(np.int64)
        np.cumsum(diffs, out=diffs)
        np.add(diffs, first, out=out[1:])
    return out if dt == np.int64 else out.astype(dt)


# code widths are restricted to {1, 2, 4, 8} bits so a packed byte holds
# a whole number of codes and decodes through one 256-entry table lookup
_CODE_LUT: Dict[int, np.ndarray] = {}


def _code_lut(bits: int) -> np.ndarray:
    lut = _CODE_LUT.get(bits)
    if lut is None:
        byte = np.arange(256, dtype=np.uint8)
        per = 8 // bits
        lut = np.stack(
            [(byte >> (i * bits)) & ((1 << bits) - 1) for i in range(per)], 1
        )
        _CODE_LUT[bits] = lut
    return lut


def _enc_delta_narrow(arr: np.ndarray) -> Optional[bytes]:
    """Delta + frame-of-reference: fixed int64 first value, then the
    (non-negative) diffs min-subtracted and cast to the smallest
    unsigned width.  Slightly larger than delta+varint but decodes in
    one branch-free frombuffer+cumsum pass — the hot-profile choice for
    sorted columns whose diffs overflow 7 bits."""
    v = arr.astype(np.int64).ravel()
    body = _enc_narrow(np.diff(v))
    if body is None:
        return None
    return struct.pack("<q", int(v[0])) + body


def _dec_delta_narrow(payload, count: int, dt: np.dtype) -> np.ndarray:
    (first,) = struct.unpack_from("<q", payload, 0)
    diffs = _dec_narrow(payload[8:], count - 1, np.dtype(np.int64))
    out = np.empty(count, np.int64)
    out[0] = first
    np.cumsum(diffs, out=diffs)
    np.add(diffs, first, out=out[1:])
    return out if dt == np.int64 else out.astype(dt)


def _enc_dict(arr: np.ndarray) -> Optional[bytes]:
    flat = arr.ravel()
    uniq, codes = np.unique(flat, return_inverse=True)
    if len(uniq) > 256:
        return None
    n_bits = max(1, int(len(uniq) - 1).bit_length())
    bits = next(b for b in (1, 2, 4, 8) if b >= n_bits)
    per = 8 // bits
    pad = (-len(codes)) % per
    codes = np.concatenate([codes, np.zeros(pad, codes.dtype)]).astype(np.uint8)
    grouped = codes.reshape(-1, per) << (np.arange(per, dtype=np.uint8) * bits)
    packed = np.bitwise_or.reduce(grouped, 1).astype(np.uint8)
    return (struct.pack("<HB", len(uniq), bits)
            + np.ascontiguousarray(uniq).tobytes() + packed.tobytes())


def _dec_dict(payload, count: int, dt: np.dtype) -> np.ndarray:
    n_uniq, bits = struct.unpack_from("<HB", payload, 0)
    uniq = np.frombuffer(payload, dt, count=n_uniq, offset=3)
    if n_uniq == 1:  # constant column (all-unset attrs, all-alive flags)
        return np.full(count, uniq[0], dt)
    off = 3 + n_uniq * dt.itemsize
    codes = np.frombuffer(payload, np.uint8, count=count if bits == 8 else -1,
                          offset=off)
    if bits != 8:
        codes = _code_lut(bits)[codes].ravel()[:count]
    return uniq[codes]


def _enc_narrow(arr: np.ndarray) -> Optional[bytes]:
    """Frame-of-reference: subtract the min, cast to the smallest
    unsigned width.  Near-varint compression for bounded-range columns
    (node ids, attr values) at a fraction of the decode cost."""
    flat = arr.astype(np.int64).ravel()
    mn = int(flat.min())
    rng = int(flat.max()) - mn
    width = next((w for w, lim in ((1, 1 << 8), (2, 1 << 16), (4, 1 << 32))
                  if rng < lim and w < arr.dtype.itemsize), None)
    if width is None:
        return None
    offs = (flat - mn).astype({1: np.uint8, 2: np.uint16, 4: np.uint32}[width])
    return struct.pack("<Bq", width, mn) + offs.tobytes()


def _dec_narrow(payload, count: int, dt: np.dtype) -> np.ndarray:
    width, mn = struct.unpack_from("<Bq", payload, 0)
    offs = np.frombuffer(payload, {1: np.uint8, 2: np.uint16, 4: np.uint32}[width],
                         count=count, offset=9)
    # offs + mn is an original value, so it fits dt: one fused add+cast
    return np.add(offs, dt.type(mn), dtype=dt)


def _encode_column(arr: np.ndarray, profile: str = "size") -> Tuple[int, bytes]:
    """Pick the encoding for one column (write-time choice).  Candidates
    are actually encoded and compared by size — the blocks are small
    (KBs), so paying encode cost per candidate at write time buys an
    exact choice instead of a heuristic one.  Candidates compete on
    stored_bytes x weight (decode-speed class, per ``profile``), so a
    slow decoder must buy proportionally more compression to take the
    column."""
    weights = ENC_WEIGHTS[profile]
    raw = arr.tobytes()
    if len(raw) <= RAW_KEEP_BYTES:
        # tiny columns: a fancy decode costs more wall time than the
        # handful of bytes it saves — keep them verbatim
        return ENC_RAW, raw
    if arr.dtype == np.bool_:
        return ENC_BITPACK, np.packbits(arr.ravel(), bitorder="little").tobytes()
    cands = [(ENC_RAW, raw)]
    if arr.dtype in _VARINTABLE:
        flat = arr.ravel()
        probes = [(ENC_NARROW, _enc_narrow(arr))]
        if arr.size <= DICT_MAX_ELEMS:  # np.unique is too costly above
            probes.append((ENC_DICT, _enc_dict(arr)))
        for enc, payload in probes:
            if payload is not None:
                cands.append((enc, payload))
        if arr.ndim == 1 and 1 < arr.size <= DELTA_MAX_ELEMS and (
                np.diff(flat.astype(np.int64)) >= 0).all():
            cands.append((ENC_DELTA_VARINT, _enc_delta_varint(arr)))
            cand = _enc_delta_narrow(arr)
            if cand is not None:
                cands.append((ENC_DELTA_NARROW, cand))
    if len(raw) > ZLIB_PROBE_BYTES:
        # big blocks (checkpoint tensors, pre-compressed payloads): only
        # pay a full zlib-6 pass if a cheap prefix probe shows compression
        probe = zlib.compress(raw[:4096], 1)
        try_zlib = len(probe) < int(0.9 * 4096)
    else:
        try_zlib = True
    if try_zlib:
        z = zlib.compress(raw, ZLIB_LEVEL)
        if len(z) < len(raw):
            cands.append((ENC_ZLIB, z))
    return min(cands, key=lambda c: len(c[1]) * weights[c[0]])


def _decode_column(enc: int, payload, shape, dt: np.dtype) -> np.ndarray:
    count = math.prod(shape)
    if enc == ENC_RAW:
        out = np.frombuffer(payload, dtype=dt, count=count)
    elif enc == ENC_BITPACK:
        out = np.unpackbits(
            np.frombuffer(payload, np.uint8), count=count, bitorder="little",
        ).astype(np.bool_)
    elif enc == ENC_DELTA_VARINT:
        out = _dec_delta_varint(payload, count, dt)
    elif enc == ENC_DICT:
        out = _dec_dict(payload, count, dt)
    elif enc == ENC_ZLIB:
        out = np.frombuffer(zlib.decompress(payload), dtype=dt, count=count)
    elif enc == ENC_NARROW:
        out = _dec_narrow(payload, count, dt)
    elif enc == ENC_DELTA_NARROW:
        out = _dec_delta_narrow(payload, count, dt)
    else:
        raise ValueError(f"unknown TGI2 column encoding {enc}")
    return out if len(shape) == 1 else out.reshape(shape)


# ---------------------------------------------------------------------------
# block writers
# ---------------------------------------------------------------------------


def _coerce(arr: np.ndarray) -> np.ndarray:
    arr = np.ascontiguousarray(arr)
    if np.dtype(arr.dtype) not in _DT_CODE:  # e.g. ml_dtypes.bfloat16
        arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
    return arr


def _dumps_v1(arrays: Dict[str, np.ndarray]) -> bytes:
    """The original fixed-layout writer — kept byte-identical (golden)."""
    buf = io.BytesIO()
    buf.write(MAGIC)
    buf.write(struct.pack("<I", len(arrays)))
    for name, arr in sorted(arrays.items()):
        arr = _coerce(arr)
        nb = name.encode()
        buf.write(struct.pack("<H", len(nb)))
        buf.write(nb)
        buf.write(struct.pack("<BB", _DT_CODE[np.dtype(arr.dtype)], arr.ndim))
        buf.write(struct.pack(f"<{arr.ndim}q", *arr.shape))
        buf.write(arr.tobytes())
    return buf.getvalue()


def _dumps_v2(arrays: Dict[str, np.ndarray], profile: str = "size") -> bytes:
    cols = []
    dir_len = 8  # MAGIC + column count
    for name, arr in sorted(arrays.items()):
        arr = _coerce(arr)
        enc, payload = _encode_column(arr, profile)
        nb = name.encode()
        cols.append((nb, arr, enc, payload))
        dir_len += 2 + len(nb) + 2 + 8 * arr.ndim + 21
    buf = io.BytesIO()
    buf.write(MAGIC2)
    buf.write(struct.pack("<I", len(cols) | DIR_HAS_CRC))
    off = dir_len
    for nb, arr, enc, payload in cols:  # directory, absolute payload offsets
        buf.write(struct.pack("<H", len(nb)))
        buf.write(nb)
        buf.write(struct.pack("<BB", _DT_CODE[np.dtype(arr.dtype)], arr.ndim))
        buf.write(struct.pack(f"<{arr.ndim}q", *arr.shape))
        buf.write(struct.pack("<BQQI", enc, len(payload), off,
                              zlib.crc32(payload) & 0xFFFFFFFF))
        off += len(payload)
    for _, _, _, payload in cols:  # payloads, directory order
        buf.write(payload)
    return buf.getvalue()


def assemble_block(entries: List["ColumnMeta"],
                   payloads: Dict[str, bytes]) -> bytes:
    """Re-emit a TGI2 block from *already-encoded* columns — the service
    plane's projected-read path.  A StorageCell copies the requested
    columns' payload bytes verbatim (no decode, no re-encode) into a
    fresh block whose directory still lists EVERY column of the source
    blob, so the client learns the blob's full column set from a
    projected reply (its decoded-block pool needs the complete
    directory).  Entries without a payload keep their stored length but
    point at offset 0: decoding one fails its crc check loudly instead
    of silently returning garbage — readers must project to the supplied
    columns.  Columns sourced from a TGI1 blob (crc None) get a fresh
    crc32, so every reply is checksummed end to end."""
    dir_len = 8
    for e in entries:
        dir_len += 2 + len(e.name.encode()) + 2 + 8 * len(e.shape) + 21
    parts = [MAGIC2, struct.pack("<I", len(entries) | DIR_HAS_CRC)]
    tail = []
    off = dir_len
    for e in entries:
        nb = e.name.encode()
        payload = payloads.get(e.name)
        if payload is None:
            poff, crc = 0, (e.crc if e.crc is not None else 0)
        else:
            poff = off
            off += len(payload)
            crc = (e.crc if e.crc is not None
                   else zlib.crc32(payload) & 0xFFFFFFFF)
            tail.append(payload)
        parts.append(struct.pack(
            f"<H{len(nb)}sBB{len(e.shape)}qBQQI", len(nb), nb,
            _DT_CODE[np.dtype(e.dtype)], len(e.shape), *e.shape,
            e.enc, e.length, poff, crc))
    return b"".join(parts + tail)


def dumps(arrays: Dict[str, np.ndarray], fmt: Optional[str] = None,
          profile: str = "size") -> bytes:
    """Serialize a dict of ndarrays (``fmt`` in {"TGI1", "TGI2"}; default
    ``DEFAULT_FORMAT``).  ``profile`` biases the TGI2 per-column encoding
    choice: "size" (cold blocks) or "speed" (hot replay blocks)."""
    fmt = fmt or DEFAULT_FORMAT
    if fmt == "TGI1":
        return _dumps_v1(arrays)
    if fmt == "TGI2":
        return _dumps_v2(arrays, profile)
    raise ValueError(f"unknown serialization format {fmt!r}")


# ---------------------------------------------------------------------------
# readers (MAGIC-dispatched)
# ---------------------------------------------------------------------------


def _walk_v1(buf) -> List[ColumnMeta]:
    """TGI1 directory: headers interleave with payloads, so this is pure
    shape arithmetic over the whole blob.  Every column reads as ENC_RAW
    with no checksum (the format predates them)."""
    (n,) = struct.unpack_from("<I", buf, 4)
    off = 8
    out = []
    for _ in range(n):
        (ln,) = struct.unpack_from("<H", buf, off)
        off += 2
        name = bytes(buf[off : off + ln]).decode()
        off += ln
        code, ndim = struct.unpack_from("<BB", buf, off)
        off += 2
        shape = struct.unpack_from(f"<{ndim}q", buf, off)
        off += 8 * ndim
        dt = _CODE_DT[code]
        nbytes = math.prod(shape) * dt.itemsize
        out.append(ColumnMeta(name, dt, tuple(shape), ENC_RAW, off, nbytes, None))
        off += nbytes
    return out


def parse_directory(prefix) -> Optional[List[ColumnMeta]]:
    """Parse a TGI2 directory from a byte *prefix* of the block.

    Returns the column list, or None when the prefix is too short to
    hold the whole directory — the range-seek backend reads a small
    prefix first, and grows it only for the rare block whose directory
    overflows it.  Raises on a non-TGI2 magic (the caller dispatches
    TGI1 blobs to a full read first)."""
    buf = memoryview(prefix)
    if len(buf) < 8:
        return None
    if bytes(buf[:4]) != MAGIC2:
        raise ValueError("not a TGI2 block")
    (raw_n,) = struct.unpack_from("<I", buf, 4)
    has_crc = bool(raw_n & DIR_HAS_CRC)
    n = raw_n & ~DIR_HAS_CRC
    tail = 21 if has_crc else 17  # enc + len + off (+ crc32)
    off = 8
    out = []
    for _ in range(n):
        if off + 4 > len(buf):
            return None
        (ln,) = struct.unpack_from("<H", buf, off)
        if off + 2 + ln + 2 > len(buf):
            return None
        name = bytes(buf[off + 2 : off + 2 + ln]).decode()
        off += 2 + ln
        code, ndim = struct.unpack_from("<BB", buf, off)
        if off + 2 + 8 * ndim + tail > len(buf):
            return None
        shape = struct.unpack_from(f"<{ndim}q", buf, off + 2)
        if has_crc:
            enc, plen, poff, crc = struct.unpack_from(
                "<BQQI", buf, off + 2 + 8 * ndim)
        else:  # pre-checksum directory layout: no crc to verify
            enc, plen, poff = struct.unpack_from("<BQQ", buf, off + 2 + 8 * ndim)
            crc = None
        off += 2 + 8 * ndim + tail
        out.append(ColumnMeta(name, _CODE_DT[code], tuple(shape), enc,
                              poff, plen, crc))
    return out


def walk(data) -> List[ColumnMeta]:
    """Directory of a complete block, MAGIC-dispatched (TGI1 or TGI2).
    The ONE implementation of both directory byte layouts — loads_sized,
    block_info, and the kvstore read paths all go through it."""
    buf = memoryview(data)
    magic = bytes(buf[:4])
    if magic == MAGIC:
        return _walk_v1(buf)
    if magic == MAGIC2:
        out = parse_directory(buf)
        assert out is not None, "bad TGI2 block (truncated directory)"
        return out
    raise AssertionError("bad TGI block (unknown MAGIC)")


def decode_entry(meta: ColumnMeta, payload) -> np.ndarray:
    """Decode one column from its encoded payload bytes, verifying the
    directory's crc32 first (TGI2): corruption raises ``BlockCorruption``
    *before* any decode instead of silently mis-decoding."""
    if meta.crc is not None and zlib.crc32(payload) & 0xFFFFFFFF != meta.crc:
        raise BlockCorruption(
            f"column {meta.name!r}: payload crc32 mismatch "
            f"(stored {meta.crc:#010x}, computed "
            f"{zlib.crc32(payload) & 0xFFFFFFFF:#010x})")
    return _decode_column(meta.enc, payload, meta.shape, meta.dtype)


def loads_sized(data: bytes, fields: Optional[Iterable[str]] = None,
                ) -> Tuple[Dict[str, np.ndarray], int, int]:
    """Deserialize a block; returns ``(arrays, encoded_read, raw_read)``.

    ``fields`` projects the read: only the named columns are decoded —
    the rest are *seeked over* via the directory offsets (TGI2) or shape
    arithmetic (TGI1), never decompressed or copied.  ``encoded_read``
    counts header + the projected columns' stored bytes (what actually
    crossed storage); ``raw_read`` counts the materialized bytes (the
    FetchCost bytes-decompressed dimension).  TGI2 payload checksums are
    verified on every decode (``BlockCorruption`` on mismatch)."""
    buf = memoryview(data)
    want = None if fields is None else set(fields)
    out: Dict[str, np.ndarray] = {}
    enc_read = 8  # MAGIC + count (per-column headers are ~free)
    raw_read = 0
    for meta in walk(buf):
        if want is None or meta.name in want:
            out[meta.name] = decode_entry(
                meta, buf[meta.off : meta.off + meta.length])
            enc_read += meta.length
            raw_read += out[meta.name].nbytes
    return out, enc_read, raw_read


def loads(data: bytes, fields: Optional[Iterable[str]] = None) -> Dict[str, np.ndarray]:
    """Deserialize a block (MAGIC-dispatched TGI1/TGI2).  ``fields``
    projects the read: only the named arrays are materialized."""
    return loads_sized(data, fields)[0]


def block_info(data: bytes) -> Dict[str, Dict]:
    """Per-column metadata of a stored block (no payload decode):
    ``{name: {dtype, shape, encoding, stored_bytes, raw_bytes, crc}}``."""
    info: Dict[str, Dict] = {}
    for meta in walk(data):
        info[meta.name] = {
            "dtype": str(meta.dtype), "shape": tuple(meta.shape),
            "encoding": ENC_NAME[meta.enc], "stored_bytes": meta.length,
            "raw_bytes": math.prod(meta.shape) * meta.dtype.itemsize,
            "crc": meta.crc,
        }
    return info
