"""Fixed-layout binary (de)serialization for delta payloads.

The paper pickles python objects into Cassandra blobs; we use a typed,
versioned header + raw little-endian arrays — mmap-friendly, zero-copy on
read, and byte-stable (required by the checkpoint-store integrity hashes).
"""
from __future__ import annotations

import io
import struct
from typing import Dict, Iterable, Optional

import numpy as np

MAGIC = b"TGI1"
_DT_CODE = {
    np.dtype(np.bool_): 0, np.dtype(np.int8): 1, np.dtype(np.int16): 2,
    np.dtype(np.int32): 3, np.dtype(np.int64): 4, np.dtype(np.float32): 5,
    np.dtype(np.float64): 6, np.dtype(np.uint8): 7, np.dtype(np.uint32): 8,
    np.dtype(np.bfloat16) if hasattr(np, "bfloat16") else np.dtype(np.void): 9,
}
_CODE_DT = {v: k for k, v in _DT_CODE.items()}


def dumps(arrays: Dict[str, np.ndarray]) -> bytes:
    """Serialize a dict of ndarrays."""
    buf = io.BytesIO()
    buf.write(MAGIC)
    buf.write(struct.pack("<I", len(arrays)))
    for name, arr in sorted(arrays.items()):
        arr = np.ascontiguousarray(arr)
        nb = name.encode()
        dt = np.dtype(arr.dtype)
        if dt not in _DT_CODE:  # e.g. ml_dtypes.bfloat16 — raw-byte fallback
            raw = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
            dt = raw.dtype
            arr = raw
        buf.write(struct.pack("<H", len(nb)))
        buf.write(nb)
        buf.write(struct.pack("<BB", _DT_CODE[dt], arr.ndim))
        buf.write(struct.pack(f"<{arr.ndim}q", *arr.shape))
        buf.write(arr.tobytes())
    return buf.getvalue()


def loads(data: bytes, fields: Optional[Iterable[str]] = None) -> Dict[str, np.ndarray]:
    """Deserialize a block.  ``fields`` projects the read: only the named
    arrays are materialized (others are seeked over without a copy) — the
    storage half of the query planner's attribute-projection pushdown."""
    buf = memoryview(data)
    assert bytes(buf[:4]) == MAGIC, "bad TGI block"
    want = None if fields is None else set(fields)
    (n,) = struct.unpack_from("<I", buf, 4)
    off = 8
    out: Dict[str, np.ndarray] = {}
    for _ in range(n):
        (ln,) = struct.unpack_from("<H", buf, off)
        off += 2
        name = bytes(buf[off : off + ln]).decode()
        off += ln
        code, ndim = struct.unpack_from("<BB", buf, off)
        off += 2
        shape = struct.unpack_from(f"<{ndim}q", buf, off)
        off += 8 * ndim
        dt = _CODE_DT[code]
        count = int(np.prod(shape)) if ndim else 1
        nbytes = count * dt.itemsize
        if want is None or name in want:
            out[name] = np.frombuffer(buf, dtype=dt, count=count, offset=off).reshape(shape)
        off += nbytes
    return out
