from repro.train.steps import (
    make_loss_fn,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

__all__ = ["make_loss_fn", "make_prefill_step", "make_serve_step", "make_train_step"]
