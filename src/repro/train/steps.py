"""Train / prefill / decode step factories.

These close over (cfg, shd) and are the functions the launchers jit with
explicit in/out shardings.  They are deliberately free of host logic —
everything inside is traceable, so the same function serves the real
training loop, the smoke tests, and the multi-pod dry-run lowering.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.sharding import Sharder
from repro.optim import adamw

MOE_AUX_COEF = 0.01


def make_loss_fn(cfg, shd: Sharder, skip_masked_blocks: bool = False):
    def loss_fn(params, batch):
        logits, aux = lm.forward(params, batch, cfg, shd, skip_masked_blocks)
        n_img = cfg.n_img_tokens or 0
        if n_img:
            logits = logits[:, n_img:]
        loss = lm.lm_loss(logits, batch["labels"], batch.get("weights"))
        total = loss + MOE_AUX_COEF * aux
        return total, {"loss": loss, "aux_loss": aux}

    return loss_fn


def make_train_step(cfg, shd: Sharder, ocfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                    skip_masked_blocks: bool = False):
    loss_fn = make_loss_fn(cfg, shd, skip_masked_blocks)

    def train_step(params, opt_state, batch):
        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state, opt_metrics = adamw.update(grads, opt_state, params, ocfg)
        metrics = dict(metrics, total_loss=total, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, shd: Sharder, model_axis: int, cache_len: int = 0):
    def prefill_step(params, batch):
        logits, cache = lm.prefill(
            params, batch, cfg, shd, model_axis=model_axis, cache_len=cache_len
        )
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def make_serve_step(cfg, shd: Sharder):
    """One greedy decode step: (params, cache, tokens (B,1), pos (B,)) ->
    (next_token (B,), logits, new_cache)."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = lm.decode_step(params, cache, tokens, pos, cfg, shd)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, logits, cache

    return serve_step
