"""Assemble the §Dry-run / §Roofline tables from experiments/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.roofline_table [--markdown]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import ARCH_IDS, SHAPES

DRY = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(mesh_tag: str):
    recs = {}
    for f in DRY.glob(f"*__{mesh_tag}.json"):
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_ms(s):
    return f"{s * 1e3:.1f}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="singlepod")
    args = ap.parse_args()
    recs = load(args.mesh)
    hdr = ("| arch | shape | status | mem/dev GiB | compute ms | memory ms | "
           "coll ms | dominant | useful | MFU | note |")
    print(hdr)
    print("|" + "---|" * 11)
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = recs.get((arch, shape))
            if r is None:
                print(f"| {arch} | {shape} | MISSING |  |  |  |  |  |  |  |  |")
                continue
            if r["status"] == "SKIP":
                print(f"| {arch} | {shape} | SKIP |  |  |  |  |  |  |  | "
                      f"{r['reason'][:60]} |")
                continue
            if r["status"] == "FAIL":
                print(f"| {arch} | {shape} | FAIL |  |  |  |  |  |  |  | "
                      f"{r['error'][:60]} |")
                continue
            rf = r.get("roofline", {})
            mem = r["memory"]["peak_bytes_est"] / 2**30
            note = rf.get("source", "")[:40]
            print(
                f"| {arch} | {shape} | OK | {mem:.1f} | {fmt_ms(rf['compute_s'])} | "
                f"{fmt_ms(rf['memory_s'])} | {fmt_ms(rf['collective_s'])} | "
                f"{rf['dominant']} | {rf['useful_ratio']:.2f} | {rf['mfu']:.3f} | {note} |"
            )
    # aggregate
    ok = [r for r in recs.values() if r["status"] == "OK" and "roofline" in r]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["mfu"])
        coll = max(ok, key=lambda r: r["roofline"]["collective_s"]
                   / max(r["roofline"]["step_time_s"], 1e-12))
        print(f"\nworst MFU: {worst['arch']} x {worst['shape']} "
              f"({worst['roofline']['mfu']:.4f})")
        print(f"most collective-bound: {coll['arch']} x {coll['shape']}")


if __name__ == "__main__":
    main()
