"""§Perf hillclimbing: lower/compile variants of chosen (arch x shape)
cells, re-derive the roofline, and append hypothesis->change->before->
after records to experiments/perf_log.json.

Variants are *rule/config* deltas on the same production mesh:

  skip_causal        band-limited blockwise attention (visits only valid
                     kv blocks): attention FLOPs ~halve for causal train,
                     ~S/window for SWA prefill.  FLOP delta is analytic
                     (the cost probe's direct-attention path cannot see
                     block skipping); memory/collectives measured.
  remat_dots         checkpoint policy full->dots: layer FLOPs 4x->3x fwd
                     at the cost of saved matmul outputs.
  remat_none         no remat (memory permitting).
  fsdp256            pure ZeRO-3: batch and weight-embed over BOTH mesh
                     axes, no tensor parallelism — removes the seq-
                     parallel residual gathers; weights gathered per
                     layer instead (wins when weight bytes << activation
                     traffic, i.e. small models / big batches).
  resident_ffn       decode: FFN inputs' d_model sharded over 'data' so
                     the contraction aligns with the weights' FSDP shards
                     — per-step psum of (B,F/16) activations instead of
                     per-step all-gather of the full FFN weights.
  ep_experts         expert dim of MoE weights sharded over 'model'
                     (divisibility permitting: phi3.5's 16 experts).

  PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen3-1.7b:train_4k \
      --variant fsdp256 --hypothesis "..."
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
PERF_LOG = ROOT / "experiments" / "perf_log.json"

VARIANTS = {
    "pad_heads": dict(cfg_overrides={"pad_heads_multiple": 16}),
    "pad_heads_skip": dict(cfg_overrides={"pad_heads_multiple": 16},
                           skip_masked_blocks=True),
    "skip_causal": dict(skip_masked_blocks=True),
    "bf16_params": dict(cfg_overrides={"param_dtype": "bfloat16"}),
    "remat_dots": dict(cfg_overrides={"remat": "dots"}),
    "remat_none": dict(cfg_overrides={"remat": "none"}),
    "fsdp256": dict(extra_rules={
        "batch": (("data", "model"),),
        "embed": (("data", "model"), ("data",)),
        "vocab": (),
        "heads": (),
        "kv_heads": (),
        "head_dim": (),
        "mlp": (),
        "rnn": (),
        "rnn_in": (),
        "res_seq": (),
        "act_heads": (),
        "act_mlp": (),
    }),
    "resident_ffn": dict(extra_rules={
        "ffn_batch": (),
        "ffn_embed": (("data",),),
    }),
    "ep_experts": dict(extra_rules={
        "expert": (("model",),),
        "mlp": (("data",),),  # TP moves to data; experts own the model axis
    }),
    "fsdp256_skip": dict(skip_masked_blocks=True, extra_rules={
        "batch": (("data", "model"),),
        "embed": (("data", "model"), ("data",)),
        "vocab": (), "heads": (), "kv_heads": (), "head_dim": (),
        "mlp": (), "rnn": (), "rnn_in": (), "res_seq": (),
        "act_heads": (), "act_mlp": (),
    }),
}


def run_variant(arch: str, shape: str, variant: str, hypothesis: str = "",
                probe: bool = True):
    from repro.launch import dryrun as dr
    from repro.configs import SHAPES, get_config
    from repro.roofline import analytic, compute_roofline, model_flops

    spec = VARIANTS[variant]
    kw = dict(
        extra_rules=spec.get("extra_rules"),
        cfg_overrides=spec.get("cfg_overrides"),
        skip_masked_blocks=spec.get("skip_masked_blocks", False),
    )
    t0 = time.time()
    rec = dr.lower_cell(arch, shape, multi_pod=False, **kw)
    shape_cfg = SHAPES[shape]
    cfg = get_config(arch)
    if spec.get("cfg_overrides"):
        cfg = cfg.replace(**spec["cfg_overrides"])

    if rec["status"] == "OK":
        pattern = cfg.resolved_pattern
        analytic_only = shape_cfg.kind == "decode" or (
            any(k in ("mlstm", "slstm") for k in pattern) and shape_cfg.seq_len > 4096
        )
        skip = spec.get("skip_masked_blocks", False)
        Sk_eff = 0
        if skip and shape_cfg.kind in ("train", "prefill"):
            w = cfg.window if cfg.attn_kind in ("swa", "local") and cfg.window else 0
            Sk_eff = min((shape_cfg.seq_len + 1024) // 2,
                         (w + 1024) if w else shape_cfg.seq_len)
        if analytic_only or skip or not probe:
            f = analytic.forward_flops(cfg, shape_cfg.global_batch,
                                       shape_cfg.seq_len if shape_cfg.kind != "decode" else 1,
                                       Sk_eff=Sk_eff,
                                       decode_cache=shape_cfg.seq_len if shape_cfg.kind == "decode" else 0)
            mult = {"train": (3.0, 4.0 if cfg.remat == "full" else 3.0),
                    "prefill": (1.0, 1.0), "decode": (1.0, 1.0)}[shape_cfg.kind]
            flops = (mult[0] * f["stem"] + mult[1] * f["layers"]) / rec["n_chips"]
            src = "flops=analytic(+skip)" if skip else "flops=analytic"
        else:
            pr = dr.probe_costs(arch, shape, False,
                                extra_rules=spec.get("extra_rules"),
                                base_overrides=spec.get("cfg_overrides"))
            flops = pr["flops"]
            rec["cost_probe"] = pr
            src = "flops=probe"
        an_bytes = analytic.step_bytes(
            cfg, shape_cfg.kind, shape_cfg.global_batch, shape_cfg.seq_len,
            chips=rec["n_chips"],
            fsdp="fsdp" not in variant or True,
        )
        tokens = rec["tokens_per_step"]
        mf = model_flops(shape_cfg.kind, rec["n_active_params"], tokens)
        roof = compute_roofline(
            {"flops": flops, "bytes accessed": an_bytes["total"]},
            rec["collectives"]["wire_bytes"], mf, rec["n_chips"],
        )
        rec["roofline"] = roof.to_dict()
        rec["roofline"]["source"] = src + " bytes=analytic collectives=weighted-hlo"

    # append to the perf log
    log = json.loads(PERF_LOG.read_text()) if PERF_LOG.exists() else []
    entry = {
        "cell": f"{arch}:{shape}",
        "variant": variant,
        "hypothesis": hypothesis,
        "wall_s": round(time.time() - t0, 1),
        "status": rec["status"],
        "roofline": rec.get("roofline"),
        "memory_gib": rec.get("memory", {}).get("peak_bytes_est", 0) / 2**30,
        "collectives_wire_gb": rec.get("collectives", {}).get("wire_bytes", 0) / 1e9,
        "error": rec.get("error"),
    }
    log.append(entry)
    PERF_LOG.parent.mkdir(exist_ok=True, parents=True)
    PERF_LOG.write_text(json.dumps(log, indent=2, default=float))
    out = ROOT / "experiments" / "dryrun" / (
        f"{arch}__{shape}__singlepod__{variant}.json"
    )
    out.write_text(json.dumps(rec, indent=2, default=float))
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--no-probe", action="store_true")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    e = run_variant(arch, shape, args.variant, args.hypothesis,
                    probe=not args.no_probe)
    r = e.get("roofline") or {}
    print(json.dumps({k: e[k] for k in ("cell", "variant", "status")},), flush=True)
    if r:
        print(f"compute={r['compute_s']*1e3:.1f}ms mem={r['memory_s']*1e3:.1f}ms "
              f"coll={r['collective_s']*1e3:.1f}ms dom={r['dominant']} "
              f"mfu={r['mfu']:.4f} mem/dev={e['memory_gib']:.1f}GiB")
    else:
        print(e.get("error", "")[:300])


if __name__ == "__main__":
    main()
